"""Fail on broken relative links in the repo's markdown.

Scans ``docs/**/*.md``, every root-level ``*.md`` (ROADMAP, PAPER, ...)
and ``benchmarks/README.md`` for markdown links/images whose target is a
relative path, and verifies the target exists on disk.  External links
(``http(s)://``, ``mailto:``) and pure in-page anchors (``#...``) are
skipped; a relative target's ``#fragment`` is stripped before the
existence check.  Used by the CI ``docs`` job and wrapped by
``tests/test_docs_links.py`` so tier-1 catches a broken link before CI
does.

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) and ![alt](target); target ends at the first unescaped ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def iter_markdown(root: Path):
    """The markdown set the docs gate covers (docs tree + README-level)."""
    seen = set()
    for pattern in ("*.md", "docs/**/*.md", "benchmarks/README.md"):
        for p in sorted(root.glob(pattern)):
            if p not in seen:
                seen.add(p)
                yield p


def broken_links(root: Path) -> list[str]:
    """``"file: target"`` lines for every relative link that resolves to
    nothing on disk."""
    problems = []
    for md in iter_markdown(root):
        text = md.read_text(encoding="utf-8")
        # fenced code blocks frequently contain ``[x](y)``-shaped text
        # (regex examples, shell globs) that are not links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                problems.append(f"{md.relative_to(root)}: {target}")
    return problems


def main(argv=None) -> int:
    """CLI entry point: exit 1 listing broken links, 0 when clean."""
    args = argv if argv is not None else sys.argv[1:]
    root = Path(args[0]).resolve() if args else Path(__file__).resolve().parents[1]
    problems = broken_links(root)
    if not problems:
        print(f"check_links: all relative markdown links resolve under {root}")
        return 0
    for p in problems:
        print(f"::error::check_links: broken relative link — {p}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
