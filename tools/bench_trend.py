"""Markdown trend table over accumulated bench-result artifacts.

CI uploads every main run's ``results/bench*.json`` as a workflow
artifact (ROADMAP: "trend dashboards over the artifact history").  This
tool renders that history: point it at the downloaded artifact
directories (or individual ``bench_lanes.json`` files) and it emits a
markdown table of every gated ratio metric per run — the same metric set
``benchmarks/bench_diff.py`` gates pairwise, so the trend view and the
regression gate can never disagree about what matters.

    python tools/bench_trend.py artifacts/run-*/bench_lanes.json
    python tools/bench_trend.py --dir artifacts/ --out trend.md

Runs are ordered oldest-first (file mtime; ``--keep-order`` preserves
the argument order instead, for explicitly curated histories) and
labelled by their parent directory name.  The last row additionally
shows the delta vs the previous run per metric.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# The gated metric set is owned by bench_diff; reuse it so the trend
# table tracks exactly what CI gates.
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.bench_diff import GATED_METRICS, lookup  # noqa: E402


def collect(paths: list[str], search_dirs: list[str],
            keep_order: bool) -> list[Path]:
    """Resolve the run files: explicit paths plus ``bench_lanes.json``
    found under any ``--dir``, ordered oldest-first by mtime unless
    ``keep_order``."""
    files = [Path(p) for p in paths]
    for d in search_dirs:
        files.extend(sorted(Path(d).rglob("bench_lanes.json")))
    missing = [f for f in files if not f.is_file()]
    if missing:
        raise FileNotFoundError(f"not a file: {[str(m) for m in missing]}")
    if not keep_order:
        files.sort(key=lambda f: f.stat().st_mtime)
    return files


def label_for(path: Path) -> str:
    """A short run label: the parent directory name (artifact dirs are
    one-per-run), falling back to the file stem."""
    parent = path.resolve().parent.name
    return parent if parent not in ("", "results") else path.stem


def render(files: list[Path]) -> str:
    """The markdown trend table (one row per run, one column per gated
    metric; missing metrics — runs predating a metric — render as ``—``)."""
    metrics = list(GATED_METRICS)
    rows = []
    for f in files:
        with open(f) as fh:
            doc = json.load(fh)
        rows.append((label_for(f), [lookup(doc, m) for m in metrics]))
    head = "| run | " + " | ".join(metrics) + " |"
    sep = "|---" * (len(metrics) + 1) + "|"
    lines = [head, sep]
    for i, (label, vals) in enumerate(rows):
        cells = []
        for j, v in enumerate(vals):
            if v is None:
                cells.append("—")
                continue
            cell = f"{v:.2f}"
            if i == len(rows) - 1 and i > 0:
                prev = rows[i - 1][1][j]
                if prev:
                    cell += f" ({(v - prev) / prev:+.1%})"
            cells.append(cell)
        lines.append(f"| {label} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", help="bench_lanes.json files")
    ap.add_argument("--dir", action="append", default=[],
                    help="directory to search recursively for "
                         "bench_lanes.json (repeatable)")
    ap.add_argument("--keep-order", action="store_true",
                    help="keep the argument order instead of sorting by "
                         "file mtime")
    ap.add_argument("--out", help="write the table here instead of stdout")
    args = ap.parse_args(argv)

    files = collect(args.paths, args.dir, args.keep_order)
    if not files:
        print("bench-trend: no result files found", file=sys.stderr)
        return 1
    table = render(files)
    if args.out:
        Path(args.out).write_text(table + "\n")
        print(f"bench-trend: wrote {len(files)}-run trend to {args.out}")
    else:
        print(table)
    return 0


if __name__ == "__main__":
    sys.exit(main())
