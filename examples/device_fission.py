"""Device-level Rule A: fission of a lax.scan with per-iteration queries.

Shows the jaxpr/HLO structure before and after — the per-iteration gather
inside the loop becomes ONE batched gather outside it — plus autodiff
through the transformed loop.  Run:

    PYTHONPATH=src python examples/device_fission.py
"""
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fission import FissionReport, fission_scan
from repro.core.query import async_query, table_gather_spec


def main():
    table = jax.random.normal(jax.random.PRNGKey(0), (5000, 64))
    ids = (jnp.arange(256) * 37) % 5000

    # The model code: a loop that 'queries' an embedding table per step.
    def body(carry, i):
        row = async_query(table_gather_spec, table, i)   # blocking query
        return carry + row.sum(), row.mean()

    ref = jax.lax.scan(body, jnp.float32(0), ids)
    rep = FissionReport()
    out = fission_scan(body, jnp.float32(0), ids, report=rep)
    np.testing.assert_allclose(ref[0], out[0], rtol=1e-5)
    print(f"equivalence: OK   ({rep.n_queries_batched} query batched)")

    def structure(scan):
        f = jax.jit(lambda t, ii: scan(
            lambda c, i: (c + async_query(table_gather_spec, t, i).sum(), None),
            jnp.float32(0), ii)[0])
        hlo = f.lower(table, ids).compile().as_text()
        return {
            "gather": len(re.findall(r"[^-]gather\(", hlo)),
            "dynamic-slice": len(re.findall(r"dynamic-slice\(", hlo)),
            "while": len(re.findall(r"while\(", hlo)),
        }

    print("baseline HLO ops :", structure(jax.lax.scan))
    print("fissioned HLO ops:", structure(fission_scan),
          "   <- ONE hoisted batched gather")

    # autodiff flows through the fissioned loop
    g = jax.grad(lambda t: fission_scan(
        lambda c, i: (c + (async_query(table_gather_spec, t, i) ** 2).sum(), None),
        jnp.float32(0), ids)[0])(table)
    print("grad wrt table   :", g.shape, "nonzero rows:",
          int((jnp.abs(g).sum(-1) > 0).sum()))


if __name__ == "__main__":
    main()
