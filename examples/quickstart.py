"""Quickstart — the paper in 60 seconds.

A program written against a blocking query API (paper Example 2) is
mechanically transformed (Rule A loop fission) and executed through the
asynchronous-batching runtime.  Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.hir import Assign, Interpreter, Loop, Program, Query, transform_program
from repro.core.runtime import AsyncQueryRuntime
from repro.core.services import SimulatedDBService
from repro.core.strategies import GrowingUpperThreshold


def main():
    # -- the original program (paper Example 2) ------------------------------
    prog = Program(
        inputs=("categories", "sum"),
        body=[
            Loop(item_var="category", iter_var="categories", body=[
                Query(target="partCount", query_name="parts.count",
                      params=("category",)),
                Assign(target="sum", fn=lambda s, c: s + (c or 0),
                       args=("sum", "partCount")),
            ]),
        ],
    )
    print("original program:")
    print(prog, "\n")

    # -- transform: Rule A loop fission → producer + consumer ----------------
    tprog = transform_program(prog, overlap=True)
    print("transformed program (producer/consumer over a loop-context table):")
    print(tprog, "\n")

    # -- execute both against the same simulated database --------------------
    def service():
        return SimulatedDBService(rtt=3e-3, single_proc=1e-3, batch_proc=5e-5,
                                  batch_fixed=5e-4, concurrency=8,
                                  compute_fn=lambda q, p: p[0] * 10)

    inputs = {"categories": list(range(300)), "sum": 0}

    t0 = time.perf_counter()
    base = Interpreter(service()).run(prog, dict(inputs))
    t_sync = time.perf_counter() - t0

    rt = AsyncQueryRuntime(service(), n_threads=10,
                           strategy=GrowingUpperThreshold(initial_upper=8, bt=3))
    t0 = time.perf_counter()
    out = Interpreter(rt).run(tprog, dict(inputs))
    rt.drain()
    t_async = time.perf_counter() - t0

    assert out["sum"] == base["sum"]
    print(f"sum (both)        : {out['sum']}")
    print(f"original          : {t_sync*1e3:7.1f} ms")
    print(f"transformed       : {t_async*1e3:7.1f} ms   ({t_sync/t_async:.1f}x)")
    print(f"runtime stats     : {rt.stats.snapshot()}")
    rt.shutdown()


if __name__ == "__main__":
    main()
