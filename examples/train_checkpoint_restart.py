"""Fault-tolerant training driver: async checkpointing (the paper's
asynchronous submission applied to IO), a simulated preemption, and an
exact restart.  Run:

    PYTHONPATH=src python examples/train_checkpoint_restart.py
"""
import dataclasses
import tempfile

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import PrefetchLoader, SyntheticLMStream
from repro.models.registry import get_arch
from repro.train.optimizer import AdamWConfig, cosine_schedule
from repro.train.step import TrainStepConfig, make_train_step


def main():
    arch = get_arch("olmo-1b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    opt = AdamWConfig(lr=3e-3, schedule=cosine_schedule(3e-3, warmup=10, total=120))
    init_state, step = make_train_step(arch, opt, TrainStepConfig(donate=False))

    stream = SyntheticLMStream(arch.cfg.vocab_size, seq_len=32, batch=8)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    params = arch.init(jax.random.PRNGKey(0))
    state = init_state(params)

    with CheckpointManager(ckpt_dir, keep_last=2) as mgr:
        print("phase 1: train 60 steps, async-checkpoint every 20")
        loader = PrefetchLoader(stream, n_prefetch=4, max_steps=60)
        losses = []
        for i, batch in enumerate(loader):
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
            if (i + 1) % 20 == 0:
                mgr.save(i + 1, params, state)   # returns immediately
                print(f"  step {i+1:3d} loss {losses[-1]:.3f} (ckpt submitted)")
        print(f"  loss: {losses[0]:.3f} → {losses[-1]:.3f}")
        print("phase 2: PREEMPTED (simulated) — durable save")
        mgr.on_preempt(60, params, state)

    print("phase 3: restart from latest checkpoint")
    with CheckpointManager(ckpt_dir) as mgr2:
        restored = mgr2.restore_latest(params, state)
        assert restored is not None
        step_no, params2, state2 = restored
        print(f"  resumed at step {step_no}")
        # deterministic stream: continue from the same cursor
        loader = PrefetchLoader(stream, n_prefetch=4, start_step=step_no, max_steps=20)
        for batch in loader:
            params2, state2, m = step(params2, state2, batch)
        print(f"  step {step_no+20} loss {float(m['loss']):.3f}")
    print("done — training survived a preemption with no data reuse/skip")


if __name__ == "__main__":
    main()
