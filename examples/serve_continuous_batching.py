"""End-to-end serving driver — §5.2 as continuous batching.

Serves a reduced llama3-family model with batched requests arriving over
time; compares the paper's admission strategies on time-to-first-token and
total throughput.  Run:

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.core.lane_policy import LanePolicy
from repro.core.strategies import GrowingUpperThreshold, OneOrAll, PureAsync
from repro.models.registry import get_arch
from repro.serving.engine import HostSpillPool, InferenceEngine, proportional_shares
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler


def overlap_kv_demo(arch, params, n_requests: int = 16, verbose: bool = True):
    """Speculative prefill + per-template KV partitioning, end to end.

    LanePolicy ``lane_weights`` say which templates matter; the same
    weights derive the engine's ``kv_shares`` (proportional lane
    reservations), so a chat burst can never evict the summarize lanes.
    ``overlap=True`` dispatches the next lane's prefill on a side thread
    while the current decode tick runs, committing the staged KV at the
    next tick boundary.  Returns the finished requests + scheduler stats
    (also exercised by the tests/test_serving.py smoke test).
    """
    rng = np.random.default_rng(7)
    weights = {"chat": 2.0, "summarize": 1.0}
    shares = proportional_shares(weights, n_lanes=8, reserve=0.5)
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16,
                          max_len=48, kv_shares=shares)
    policy = LanePolicy(hot_threshold=10**9, lane_weights=weights)
    sched = ContinuousBatchingScheduler(eng, policy=policy, overlap=True)
    for i in range(n_requests):
        tmpl = "chat" if i % 2 == 0 else "summarize"
        size = 5 if tmpl == "chat" else 14
        sched.submit(Request(rid=200 + i,
                             prompt=rng.integers(1, 200, size=size).astype(np.int32),
                             max_new_tokens=8, template=tmpl))
    sched.producer_done()
    done = sched.run_until_drained()
    st = sched.stats
    if verbose:
        print(f"  kv_shares {shares} (from lane_weights {weights})")
        spec = sum(1 for r in done if r.metrics.speculative)
        print(f"  {len(done)} finished | spec prefills: "
              f"{st.spec_dispatched} dispatched, {st.spec_committed} "
              f"committed, {st.spec_aborted} aborted | "
              f"{spec} requests rode the overlapped path")
        for tmpl, trace in st.lane_admissions.items():
            sizes = [n for _, n in trace]
            print(f"  lane {tmpl:10s} admissions {sizes}")
    return done, st


def depth_spill_demo(arch, params, n_requests: int = 12, verbose: bool = True):
    """Depth-k speculation + chunked prefill + host KV spill, end to end.

    ``spec_depth=2`` keeps two speculative prefills in flight;
    ``chunk_tokens=8`` folds one oversized prompt in chunk-per-tick
    (bit-identical to the one-shot prefill); ``kv_spill`` stages evicted
    straggler KV to a host LRU whose per-template budgets come from the
    policy (``spill_budget_for``), so a re-admitted straggler RESUMES
    instead of restarting.  Returns finished requests + scheduler stats
    (smoke-tested by tests/test_serving.py).
    """
    rng = np.random.default_rng(11)
    policy = LanePolicy(hot_threshold=10**9, spill_budget=4,
                        spill_budgets={"bulk": 0})
    pool = HostSpillPool(max_entries=8, budget_for=policy.spill_budget_for)
    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                          max_len=48, kv_spill=pool)
    sched = ContinuousBatchingScheduler(eng, policy=policy, overlap=True,
                                        spec_depth=2, chunk_tokens=8,
                                        lane_timeout=6)
    # one oversized prompt (chunked), the rest short chat traffic; long
    # generations make a straggler eviction (and spill/restore) likely
    sched.submit(Request(rid=300,
                         prompt=rng.integers(1, 200, size=15).astype(np.int32),
                         max_new_tokens=4, template="doc"))
    for i in range(1, n_requests):
        sched.submit(Request(rid=300 + i,
                             prompt=rng.integers(1, 200, size=5).astype(np.int32),
                             max_new_tokens=10, template="chat"))
    sched.producer_done()
    done = sched.run_until_drained()
    st = sched.stats
    if verbose:
        print(f"  {len(done)} finished | spec: {st.spec_dispatched} "
              f"dispatched / {st.spec_committed} committed / "
              f"{st.spec_aborted} aborted | {st.spec_chunks} prefill "
              f"chunks | kv spilled {st.kv_spilled} restored "
              f"{st.kv_restored} (pool {pool.snapshot()})")
    return done, st


def main():
    arch = get_arch("llama3-8b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    params = arch.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def mk_requests(n=24):
        return [Request(rid=i,
                        prompt=rng.integers(1, 200, size=int(rng.integers(4, 12))).astype(np.int32),
                        max_new_tokens=12) for i in range(n)]

    for name, strat in (
        ("one-at-a-time (async)", PureAsync()),
        ("one-or-all", OneOrAll()),
        ("growing-upper (paper best)", GrowingUpperThreshold(initial_upper=2, bt=None)),
    ):
        eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
        # warm the jit caches so strategies are compared at steady state
        warm = ContinuousBatchingScheduler(eng, strategy=strat)
        for r in mk_requests(12):
            warm.submit(r)
        warm.producer_done()
        warm.run_until_drained()
        eng.decode_steps = eng.prefill_calls = 0
        sched = ContinuousBatchingScheduler(eng, strategy=strat)
        reqs = mk_requests()
        t0 = time.perf_counter()
        for r in reqs:
            sched.submit(r)
        sched.producer_done()
        done = sched.run_until_drained()
        dt = time.perf_counter() - t0
        ttfts = sorted(r.metrics.ttft for r in done)
        toks = sum(len(r.generated) for r in done)
        print(f"{name:28s} total {dt*1e3:7.0f} ms | {toks/dt:7.1f} tok/s | "
              f"ttft p50 {ttfts[len(ttfts)//2]*1e3:6.0f} ms | "
              f"decode steps {eng.decode_steps:3d} | prefills {eng.prefill_calls}")

    r0 = done[0]
    print("\nsample generation (request 0):", r0.generated)

    # ----------------------------------------------------------- lane demo
    # Heterogeneous traffic: two request classes (templates) interleaved.
    # The scheduler shards pending requests into one lane per template, so
    # each prefill batch is homogeneous (chat prompts bucket at 8 wide,
    # summarize prompts at 16) instead of head-of-line blocking.
    print("\nmixed-template lanes (chat ~5-tok prompts vs summarize ~14-tok):")
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=GrowingUpperThreshold(
        initial_upper=2, bt=None))
    for i in range(16):
        tmpl = "chat" if i % 2 == 0 else "summarize"
        size = 5 if tmpl == "chat" else 14
        sched.submit(Request(rid=100 + i,
                             prompt=rng.integers(1, 200, size=size).astype(np.int32),
                             max_new_tokens=8, template=tmpl))
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 16
    for tmpl, trace in sched.stats.lane_admissions.items():
        sizes = [n for _, n in trace]
        print(f"  lane {tmpl:10s} admissions {sizes} "
              f"(mean batch {sum(sizes)/len(sizes):.1f})")

    # -------------------------------------------- overlap + KV shares demo
    # Speculative prefill under decode + per-template lane reservations:
    # the serving-side version of "results already fetched by the time
    # they are consumed" (see docs/ARCHITECTURE.md for the timeline).
    print("\noverlapped serving (speculative prefill + kv_shares):")
    overlap_kv_demo(arch, params)

    # ------------------------------- depth-k + chunked prefill + KV spill
    # Two staged bets in flight, oversized prompts folded chunk-per-tick,
    # straggler KV staged to host memory and resumed on re-admission.
    print("\ndepth-2 pipeline + chunked prefill + host KV spill:")
    depth_spill_demo(arch, params)


if __name__ == "__main__":
    main()
