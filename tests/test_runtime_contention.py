"""Concurrency model of the lock-sharded runtime: multi-producer stress
(no lost/duplicated deliveries, quota invariants, service-count agreement),
CV-gated quota wakeups (no fixed-interval polling), straggler-resubmit
races, the sharding primitives themselves, and the frozen global-lock
baseline the contention benchmark compares against."""
from __future__ import annotations

import inspect
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade gracefully: property tests skip, rest run
    HAVE_HYPOTHESIS = False

from repro.core import concurrency as concurrency_mod
from repro.core import runtime as runtime_mod
from repro.core.concurrency import QuotaGate, ReadyLanes, ShardedCounter
from repro.core.lane_policy import LanePolicy
from repro.core.runtime import AsyncQueryRuntime
from repro.core.runtime_baseline import GlobalLockRuntime
from repro.core.services import TableService
from repro.core.strategies import PureBatch

N_TEMPLATES = 6
TABLES = {f"t{i}": {k: k * (i + 1) for k in range(4096)}
          for i in range(N_TEMPLATES)}


# ---------------------------------------------------------------------------
# multi-producer stress: delivery + quota + accounting invariants
# ---------------------------------------------------------------------------


def test_stress_no_lost_or_duplicated_deliveries():
    """16 producer threads x 4 tenants x 6 templates, with cross-producer
    duplicate params (dedup fan-out) and binding tenant quotas.  Every
    handle resolves to its expected value exactly once, the runtime's
    completion count matches its submission count, the tenant quota is
    never observed above its bound, and the runtime's execution counters
    agree with the service's own round-trip count."""
    n_producers, n_each, quota = 16, 150, 48
    # A small service latency keeps lanes backlogged so cross-producer
    # duplicate params actually overlap in the queues (dedup fan-out).
    svc = TableService(TABLES, latency=0.001,
                       batch_latency=lambda n: 0.002 + 0.0001 * n)
    policy = LanePolicy(hot_threshold=16, default_tenant_quota=quota)
    rt = AsyncQueryRuntime(svc, n_threads=6, policy=policy)

    results: list = [None] * n_producers
    quota_high = [0]
    stop = threading.Event()

    def monitor():
        # Samples every tenant gate's outstanding count while the stress
        # runs; the quota invariant must hold at every observed instant.
        while not stop.is_set():
            for gate in list(rt._tenant_gates.values()):
                quota_high[0] = max(quota_high[0], gate.count)
            time.sleep(0.001)

    def producer(pid: int):
        got = []
        for i in range(n_each):
            tmpl = pid % N_TEMPLATES
            # ~1/3 of params collide across producers → dedup fan-out
            key = (i % 50) if i % 3 == 0 else (1000 + pid * n_each + i)
            h = rt.submit(f"t{tmpl}.lookup", (key,), tenant=f"tn{pid % 4}")
            got.append((h, key * (tmpl + 1)))
        results[pid] = got

    mon = threading.Thread(target=monitor, daemon=True)
    mon.start()
    threads = [threading.Thread(target=producer, args=(p,), daemon=True)
               for p in range(n_producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rt.drain()
    stop.set()
    mon.join()

    for got in results:
        for h, want in got:
            assert rt.fetch(h) == want
    rt.shutdown()

    total = n_producers * n_each
    assert int(rt.stats.submitted) == total
    assert int(rt.stats.completed) == total  # nothing lost, nothing doubled
    # quota invariant: never above the bound while running, fully released
    # (back to zero) once drained — a double release would go negative.
    assert quota_high[0] <= quota
    assert all(g.count == 0 for g in rt._tenant_gates.values())
    # the runtime's execution counters must agree with the service's own
    # books: 1 round trip per single execution, 3 per batched one.
    singles = int(rt.stats.single_executions)
    batches = int(rt.stats.batch_executions)
    assert int(svc.stats.round_trips) == singles + 3 * batches
    assert int(svc.stats.single_queries) == singles
    assert int(svc.stats.batches) == batches
    # dedup collisions actually happened (the test exercised fan-out)
    assert int(rt.stats.deduped) > 0


def test_sticky_worker_cannot_starve_other_ready_lanes():
    """Bounded stickiness: a single worker draining a deep lane must
    rotate back through the ready queue after _STICKY_TAKES batches, so a
    request on another lane executes long before the deep lane drains."""
    order: list = []

    class _Recording(TableService):
        def execute(self, query_name, params):
            order.append(query_name)
            return super().execute(query_name, params)

    svc = _Recording(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=1)  # PureAsync: one take per req
    # Deep backlog on t0 first, then one request on t1.
    deep = [rt.submit("t0.lookup", (i,)) for i in range(100)]
    h1 = rt.submit("t1.lookup", (5,))
    assert rt.fetch(h1) == 10
    rt.drain()
    for i, h in enumerate(deep):
        assert rt.fetch(h) == i
    rt.shutdown()
    # t1 executed within one sticky budget of t0 takes, not after all 100
    t1_pos = order.index("t1.lookup")
    assert t1_pos <= AsyncQueryRuntime._STICKY_TAKES + 1, order[:t1_pos + 1]


def test_stress_single_lane_compat_mode():
    """The sharded=False single-queue mode keeps the same delivery
    invariants under concurrent producers (template-boundary splitting)."""
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=4, sharded=False, dedup=False)
    results: list = [None] * 8

    def producer(pid: int):
        got = []
        for i in range(80):
            tmpl = (pid + i) % N_TEMPLATES
            h = rt.submit(f"t{tmpl}.lookup", (i,))
            got.append((h, i * (tmpl + 1)))
        results[pid] = got

    threads = [threading.Thread(target=producer, args=(p,), daemon=True)
               for p in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rt.drain()
    for got in results:
        for h, want in got:
            assert rt.fetch(h) == want
    rt.shutdown()
    assert int(rt.stats.completed) == 8 * 80
    assert list(rt.stats.lane_traces) == ["__single__"]


# ---------------------------------------------------------------------------
# CV-gated quotas: wakeups come from releases, never from timers
# ---------------------------------------------------------------------------


class _GatedService(TableService):
    """execute() blocks until released; lets a test pin a call in flight."""

    def __init__(self, tables=None):
        super().__init__(tables or TABLES)
        self.started = threading.Event()
        self.release = threading.Event()

    def execute(self, query_name, params):
        self.started.set()
        assert self.release.wait(timeout=5.0)
        return super().execute(query_name, params)


def test_quota_release_wakes_blocked_submitter_promptly():
    """A submission blocked at a tenant quota must be woken by the release
    itself — well inside the 100 ms the old busy-poll would have slept."""
    svc = _GatedService()
    policy = LanePolicy(tenant_quotas={"w": 1})
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=policy)
    rt.submit("t0.lookup", (1,), tenant="w")
    assert svc.started.wait(timeout=5.0)  # tenant w at its bound

    unblocked_at = [0.0]
    entered = threading.Event()

    def second():
        entered.set()
        rt.submit("t0.lookup", (2,), tenant="w")
        unblocked_at[0] = time.perf_counter()

    t = threading.Thread(target=second, daemon=True)
    t.start()
    assert entered.wait(timeout=5.0)
    time.sleep(0.05)  # let it reach the gate's CV
    released_at = time.perf_counter()
    svc.release.set()  # first call completes -> slot freed -> CV signaled
    t.join(timeout=5.0)
    assert not t.is_alive()
    rt.drain()
    rt.shutdown()
    wake_latency = unblocked_at[0] - released_at
    assert wake_latency < 0.08, (
        f"blocked submitter took {wake_latency * 1e3:.1f} ms to wake — "
        "quota waits must be CV-signaled, not interval-polled")
    assert int(rt.stats.quota_waits) >= 1


def test_no_fixed_interval_polling_in_quota_path():
    """Source-level guard for the acceptance criterion: the runtime has no
    ``time.sleep`` anywhere, no 100 ms-style CV poll in submit, and the
    quota gate waits without a timeout."""
    runtime_src = inspect.getsource(runtime_mod)
    assert "time.sleep" not in runtime_src
    assert "wait(timeout=0.1)" not in runtime_src
    gate_src = inspect.getsource(QuotaGate)
    assert "time.sleep" not in gate_src
    assert "wait(timeout" not in gate_src  # pure signal-driven wait
    assert "wait()" in gate_src


def test_shutdown_unblocks_quota_waiter():
    svc = _GatedService()
    policy = LanePolicy(tenant_quotas={"w": 1})
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=policy)
    rt.submit("t0.lookup", (1,), tenant="w")
    assert svc.started.wait(timeout=5.0)
    errors = []
    entered = threading.Event()

    def second():
        entered.set()
        try:
            rt.submit("t0.lookup", (2,), tenant="w")
        except RuntimeError as e:
            errors.append(e)

    t = threading.Thread(target=second, daemon=True)
    t.start()
    assert entered.wait(timeout=5.0)
    time.sleep(0.05)  # let it reach the gate's CV
    # Shut down WHILE the submitter is parked on the quota CV: it must be
    # woken by the shutdown notification and raise, not sleep forever.
    shut = threading.Thread(target=rt.shutdown, daemon=True)
    shut.start()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert errors and isinstance(errors[0], RuntimeError)
    svc.release.set()  # let the stalled worker finish so shutdown can join
    shut.join(timeout=10.0)
    assert not shut.is_alive()


def test_fetch_after_shutdown_raises_instead_of_hanging():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=1)
    h = rt.fetch(rt.submit("t0.lookup", (1,)))  # normal path still works
    assert h == 1
    rt.shutdown()
    fake = runtime_mod.Handle(10**9, "t0.lookup")  # never submitted
    with pytest.raises(RuntimeError):
        rt.fetch(fake)


# ---------------------------------------------------------------------------
# straggler resubmission: deadline + delivery races
# ---------------------------------------------------------------------------


class _FirstCallStalls(TableService):
    """The first execution of each params stalls until released; retries
    (and all later calls) are instant."""

    def __init__(self, tables=None):
        super().__init__(tables or TABLES)
        self._seen: set = set()
        self._lock2 = threading.Lock()
        self.stall = threading.Event()

    def execute(self, query_name, params):
        with self._lock2:
            first = params not in self._seen
            self._seen.add(params)
        if first:
            assert self.stall.wait(timeout=5.0)
        return super().execute(query_name, params)


def test_straggler_resubmit_races_normal_delivery():
    """A resubmitted straggler and the original (slow) call race to
    deliver: exactly one wins, the handle resolves once, completion counts
    stay exact and the quota slot is released exactly once."""
    svc = _FirstCallStalls()
    policy = LanePolicy(tenant_quotas={"w": 4})
    rt = AsyncQueryRuntime(svc, n_threads=3, policy=policy,
                           straggler_timeout=0.04)
    h = rt.submit("t0.lookup", (7,), tenant="w")

    got = []
    fetcher = threading.Thread(target=lambda: got.append(rt.fetch(h)),
                               daemon=True)
    fetcher.start()
    # Let the fetch time out and resubmit while the original call is still
    # stalled, then release BOTH calls to race through delivery.
    deadline = time.monotonic() + 5.0
    while int(rt.stats.resubmissions) < 1 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert int(rt.stats.resubmissions) >= 1
    svc.stall.set()
    fetcher.join(timeout=5.0)
    assert not fetcher.is_alive()
    assert got == [7]
    rt.drain()
    rt.shutdown()
    # one submission, one completion — the racing duplicate was dropped
    assert int(rt.stats.submitted) == 1
    assert int(rt.stats.completed) == 1
    # quota slot released exactly once (a double release would go negative,
    # a missed one would leave it held)
    assert rt._tenant_gates["w"].count == 0


def test_straggler_resubmits_onto_canonical_lane():
    """A straggler submitted through a projection variant re-enqueues on
    the handle's OWN (canonical) lane and still projects at delivery."""
    rows = {k: {"name": f"u{k}"} for k in range(10)}
    svc = _FirstCallStalls({"users": rows})
    policy = LanePolicy()
    policy.share("users.lookup", {"users.sel_name": lambda r: r["name"]})
    rt = AsyncQueryRuntime(svc, n_threads=2, policy=policy,
                           straggler_timeout=0.04)
    h = rt.submit("users.sel_name", (3,))
    got = []
    fetcher = threading.Thread(target=lambda: got.append(rt.fetch(h)),
                               daemon=True)
    fetcher.start()
    deadline = time.monotonic() + 5.0
    while int(rt.stats.resubmissions) < 1 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert int(rt.stats.resubmissions) >= 1
    svc.stall.set()
    fetcher.join(timeout=5.0)
    assert got == ["u3"]
    rt.drain()
    rt.shutdown()
    # every execution (original + duplicate) ran the canonical template
    assert list(rt.stats.lane_traces) == ["users.lookup"]


# ---------------------------------------------------------------------------
# sharding primitives
# ---------------------------------------------------------------------------


def test_sharded_counter_exact_under_concurrent_adds():
    c = ShardedCounter()
    n_threads, n_each = 8, 10_000

    def bump():
        for _ in range(n_each):
            c.add()

    threads = [threading.Thread(target=bump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert int(c) == n_threads * n_each


def test_sharded_counter_hashable_with_identity_hash():
    """Regression: defining __eq__ without __hash__ made every counter
    unhashable (``hash(c)`` raised TypeError), so stats counters could not
    be dict keys or set members.  Identity hashing is restored — and it
    must stay identity-based (value hashing would break when add() mutates
    the value after insertion)."""
    c = ShardedCounter()
    h0 = hash(c)  # must not raise
    c.add(5)
    assert hash(c) == h0  # stable across mutation (identity, not value)
    d = ShardedCounter()
    d.add(5)
    assert c == d  # equal by value...
    registry = {c: "first", d: "second"}
    assert len(registry) == 2  # ...but distinct as keys (identity hash)
    assert registry[c] == "first" and registry[d] == "second"
    assert {c, d} == {c, d} and len({c, d}) == 2


def test_sharded_counter_behaves_like_a_number():
    c = ShardedCounter()
    c.add(3)
    c.add(0.5)
    assert c == 3.5 and c >= 3 and c < 4 and bool(c)
    assert c + 1 == 4.5 and 1 + c == 4.5
    assert c - 1 == 2.5 and 10 - c == 6.5
    assert c * 2 == 7.0 and c / 7 == 0.5
    d = ShardedCounter()
    assert d == 0 and not bool(d)
    assert c != d and c > d and d <= c


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(adds=st.lists(st.integers(min_value=-100, max_value=100),
                         max_size=200))
    def test_property_sharded_counter_sums_any_sequence(adds):
        c = ShardedCounter()
        for n in adds:
            c.add(n)
        assert int(c) == sum(adds)
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_property_sharded_counter_sums_any_sequence():
        """Placeholder so the dropped property test surfaces as a SKIP
        instead of silently disappearing from collection."""


def test_ready_lanes_dedups_and_orders():
    r = ReadyLanes()
    r.push("a")
    r.push("b")
    r.push("a")  # suppressed duplicate
    assert len(r) == 2 and "a" in r
    # a select callable (the policy's weighted-fair lane_min) picks the pop
    assert r.pop(select=max) == "b"
    assert r.pop() == "a"
    assert r.pop(block=False) is None
    r.push("c")
    r.close()
    assert r.pop() == "c"   # drained even after close...
    assert r.pop() is None  # ...then signals shutdown


def test_ready_lanes_peek_without_pop():
    """peek returns what pop would, never blocks, and leaves the queue
    untouched — the serving scheduler's speculation primitive."""
    r = ReadyLanes()
    assert r.peek() is None  # empty: no block, no None-pop confusion
    r.push("a")
    r.push("b")
    assert r.peek() == "a"
    assert r.peek() == "a"          # idempotent: nothing was removed
    assert len(r) == 2
    assert r.peek(select=max) == "b"  # weighted-fair style select applies
    assert "b" in r                   # ...but the winner stays queued
    assert r.pop() == "a"             # FIFO pop still sees the peeked head
    assert r.pop(select=max) == "b"


def test_ready_lanes_push_all_and_blocking_pop():
    r = ReadyLanes()
    got = []

    def worker():
        while True:
            k = r.pop()
            if k is None:
                return
            got.append(k)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    r.push_all(["x", "y", "x"])
    deadline = time.monotonic() + 5.0
    while len(got) < 2 and time.monotonic() < deadline:
        time.sleep(0.005)
    r.close()
    t.join(timeout=5.0)
    assert sorted(got) == ["x", "y"]


def test_sharded_counter_caps_cells_under_thread_churn():
    """Short-lived writer threads must not leak a cell each: past
    MAX_CELLS the counter falls back to one shared overflow cell, and the
    total stays exact."""
    c = ShardedCounter()
    n_threads = ShardedCounter.MAX_CELLS + 40

    def one_shot():
        c.add(2)

    for _ in range(n_threads):
        t = threading.Thread(target=one_shot)
        t.start()
        t.join()
    assert int(c) == 2 * n_threads
    assert len(c._cells) <= ShardedCounter.MAX_CELLS


def test_idle_quota_gates_are_swept_under_churn():
    """High-cardinality tenant churn must not grow the gate registries
    without bound: idle gates are retired once the registry crosses the
    sweep threshold, and quota accounting stays exact throughout."""
    svc = TableService(TABLES)
    policy = LanePolicy(default_tenant_quota=4)
    rt = AsyncQueryRuntime(svc, n_threads=2, policy=policy)
    old_sweep = AsyncQueryRuntime._GATE_SWEEP_AT
    AsyncQueryRuntime._GATE_SWEEP_AT = 32
    try:
        handles = []
        for i in range(400):  # 400 one-shot tenants
            handles.append((rt.submit("t0.lookup", (i,), tenant=f"one{i}"), i))
        rt.drain()
        for h, want in handles:
            assert rt.fetch(h) == want
        # the registry never grew to one gate per tenant ever seen: sweeps
        # (amortized over creations) kept it near threshold + concurrently
        # outstanding tenants
        assert len(rt._tenant_gates) < 400
        # once drained every gate is idle, so the next creation sweeps the
        # registry down to a handful
        assert rt.fetch(rt.submit("t0.lookup", (7,), tenant="fresh")) == 7
        assert len(rt._tenant_gates) <= 33
    finally:
        AsyncQueryRuntime._GATE_SWEEP_AT = old_sweep
    rt.shutdown()
    assert int(rt.stats.completed) == 401
    assert all(g.count == 0 for g in rt._tenant_gates.values())


def test_retired_gate_never_strands_a_waiter():
    g = QuotaGate()
    assert g.try_acquire(1)
    woke = threading.Event()

    def waiter():
        g.wait_below(1, should_stop=lambda: False)
        woke.set()

    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not g.try_gc()  # a waiter is parked: not idle, must not retire
    g.release()
    assert woke.wait(timeout=5.0)
    t.join(timeout=5.0)
    assert g.try_gc() and g.dead  # idle now: retired
    # a stale waiter arriving after retirement returns immediately
    t0 = time.perf_counter()
    g.count = 5  # simulate a stale over-limit view
    g.wait_below(1, should_stop=lambda: False)
    assert time.perf_counter() - t0 < 1.0


def test_quota_gate_counts_and_signals():
    g = QuotaGate()
    assert g.try_acquire(2) and g.try_acquire(2)
    assert not g.try_acquire(2)
    assert g.try_acquire(None)  # unbounded always admits
    woke = threading.Event()

    def waiter():
        g.wait_below(3, should_stop=lambda: False)
        woke.set()

    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not woke.is_set()
    g.release()  # 3 -> 2: below the limit, waiter signaled
    assert woke.wait(timeout=5.0)
    t.join(timeout=5.0)


# ---------------------------------------------------------------------------
# frozen global-lock baseline (the Part 5 A/B must not rot)
# ---------------------------------------------------------------------------


def test_global_lock_baseline_still_completes_workloads():
    svc = TableService(TABLES)
    rt = GlobalLockRuntime(svc, n_threads=4, strategy=PureBatch())
    handles = []
    for k in range(40):
        for i in range(N_TEMPLATES):
            handles.append((rt.submit(f"t{i}.lookup", (k,)), k * (i + 1)))
    rt.drain()
    for h, want in handles:
        assert rt.fetch(h) == want
    rt.shutdown()
    assert rt.stats.completed == rt.stats.submitted == 40 * N_TEMPLATES
    # it hands out the SAME handle type as the sharded runtime, so the
    # contention driver can swap the two classes
    assert isinstance(handles[0][0], runtime_mod.Handle)


def test_baseline_module_is_importable_from_bench():
    # the contention benchmark imports both sides; keep that path alive
    from benchmarks.bench_lanes import run_contention  # noqa: F401
    src = inspect.getsource(concurrency_mod)
    assert "time.sleep" not in src  # primitives are signal-driven, too


# ---------------------------------------------------------------------------
# seeded chaos: the multi-producer invariants must survive injected faults
# (REPRO_CHAOS_SEED selects the schedule; the CI chaos job runs two seeds)
# ---------------------------------------------------------------------------


def test_stress_invariants_hold_under_seeded_chaos():
    from repro.core.faults import ChaosPlan, ChaosService, chaos_seed
    from repro.core.faults import InjectedParamError
    from repro.core.resilience import Resilience

    plan = ChaosPlan(seed=chaos_seed(0), fail_rate=0.08, transient_rate=0.15,
                     transient_repeats=1, latency_rate=0.05, latency=0.0005)
    svc = ChaosService(TableService(TABLES), plan)
    policy = LanePolicy(tenant_quotas={f"w{i}": 8 for i in range(8)})
    rt = AsyncQueryRuntime(svc, n_threads=4, policy=policy,
                           resilience=Resilience())
    results: dict = {}
    lock = threading.Lock()

    def producer(w: int):
        handles = []
        for j in range(24):
            t, k = (w + j) % N_TEMPLATES, (w * 24 + j) % 4096
            handles.append((t, k, rt.submit(f"t{t}.lookup", (k,),
                                            tenant=f"w{w}")))
        for t, k, h in handles:
            try:
                out = ("ok", rt.fetch(h))
            except InjectedParamError as e:
                out = ("poisoned", e.params)
            with lock:
                results[(w, t, k)] = out

    threads = [threading.Thread(target=producer, args=(w,), daemon=True)
               for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
        assert not t.is_alive(), "a producer hung under chaos"
    rt.drain()
    rt.shutdown()
    # no lost/duplicated deliveries; every failure is its own injection
    assert len(results) == 8 * 24
    assert int(rt.stats.completed) == int(rt.stats.submitted)
    for (w, t, k), (kind, val) in results.items():
        if plan.poisoned(f"t{t}.lookup", (k,)):
            assert kind == "poisoned" and val == (k,), (w, t, k, kind, val)
        else:
            assert kind == "ok" and val == k * (t + 1), (w, t, k, kind, val)
    # every admission slot returned: quota gates read zero
    for gate in rt._tenant_gates.values():
        assert gate.count == 0
    for gate in rt._lane_gates.values():
        assert gate.count == 0
