"""Optimizer, train-step builder, microbatching, gradient compression, and
a small end-to-end LM training run (loss must drop)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import PrefetchLoader, SyntheticLMStream
from repro.models.registry import get_arch
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    _dequantize,
    _quantize,
)
from repro.train.step import TrainStepConfig, cross_entropy, make_train_step


def test_quantize_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (37, 19)) * 3
    q = _quantize(x)
    y = _dequantize(q)
    assert y.shape == x.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=float(jnp.abs(x).max()) / 100)


def test_adamw_fp32_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_int8_matches_fp32_approximately():
    key = jax.random.PRNGKey(1)
    w0 = jax.random.normal(key, (64, 8))
    tgt = jax.random.normal(jax.random.PRNGKey(2), (64, 8))

    def run(moments):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, moments_dtype=moments)
        params = {"w": w0}
        state = adamw_init(cfg, params)
        for _ in range(50):
            grads = {"w": params["w"] - tgt}
            params, state, _ = adamw_update(cfg, grads, state, params)
        return float(jnp.mean((params["w"] - tgt) ** 2))

    f32, i8 = run("float32"), run("int8")
    assert i8 < 2.5 * f32 + 0.05  # 8-bit moments track fp32 optimization


def test_clip_and_schedule():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    sched = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.int32(100))) < 2e-4


def _tiny_arch():
    arch = get_arch("olmo-1b")
    return dataclasses.replace(arch, cfg=arch.cfg.reduced())


def test_microbatch_equals_fullbatch_grads():
    arch = _tiny_arch()
    key = jax.random.PRNGKey(0)
    params = arch.init(key)
    toks = jax.random.randint(key, (8, 16), 0, arch.cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    outs = {}
    for n in (1, 4):
        init_state, step = make_train_step(
            arch, AdamWConfig(lr=1e-3),
            TrainStepConfig(microbatches=n, donate=False, fission=False))
        state = init_state(params)
        p2, _, m = step(params, state, batch)
        outs[n] = (p2, float(m["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-4
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), outs[1][0], outs[4][0])
    assert max(jax.tree_util.tree_leaves(d)) < 1e-4


def test_microbatch_fission_equals_plain():
    """Device Rule A applied to the microbatch scan (query_embedding=True)
    computes identical gradients."""
    arch = _tiny_arch()
    cfg_q = dataclasses.replace(arch.cfg, query_embedding=True, remat=False)
    arch_q = dataclasses.replace(arch, cfg=cfg_q)
    key = jax.random.PRNGKey(3)
    params = arch_q.init(key)
    toks = jax.random.randint(key, (8, 16), 0, cfg_q.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    outs = {}
    for fission in (False, True):
        init_state, step = make_train_step(
            arch_q, AdamWConfig(lr=1e-3),
            TrainStepConfig(microbatches=4, donate=False, fission=fission))
        state = init_state(params)
        p2, _, m = step(params, state, batch)
        outs[fission] = (p2, float(m["loss"]))
    assert abs(outs[True][1] - outs[False][1]) < 1e-4
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), outs[True][0], outs[False][0])
    assert max(jax.tree_util.tree_leaves(d)) < 1e-4


def test_grad_compression_error_feedback_converges():
    arch = _tiny_arch()
    key = jax.random.PRNGKey(0)
    stream = SyntheticLMStream(arch.cfg.vocab_size, seq_len=16, batch=8)
    params = arch.init(key)
    init_state, step = make_train_step(
        arch, AdamWConfig(lr=3e-3),
        TrainStepConfig(grad_compression="int8_ef", donate=False))
    state = init_state(params)
    losses = []
    for i in range(30):
        b = stream.batch_at(i)
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_training_loss_decreases_with_prefetch_loader():
    arch = _tiny_arch()
    key = jax.random.PRNGKey(0)
    params = arch.init(key)
    init_state, step = make_train_step(arch, AdamWConfig(lr=3e-3),
                                       TrainStepConfig(donate=False))
    state = init_state(params)
    stream = SyntheticLMStream(arch.cfg.vocab_size, seq_len=16, batch=8)
    loader = PrefetchLoader(stream, n_prefetch=2, max_steps=40)
    losses = []
    for batch in loader:
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert len(losses) == 40
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 11))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 11)
    ce = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    manual = -jnp.mean(jnp.take_along_axis(p, labels[..., None], -1))
    np.testing.assert_allclose(float(ce), float(manual), rtol=1e-6)
