"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes on CPU exactly as it would on the TPU grid)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.batched_gather.kernel import batched_gather
from repro.kernels.batched_gather.ref import gather_ref
from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.PRNGKey(0)


def _tol(dt):
    return (3e-2, 3e-2) if dt == jnp.bfloat16 else (2e-5, 2e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", [
    (1, 4, 2, 128, 64, 32, 32),
    (2, 8, 2, 256, 64, 64, 64),
    (1, 2, 1, 64, 128, 64, 16),
    (2, 4, 4, 96, 32, 32, 32),   # MHA, non-pow2 seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, hq, hkv, s, d, bq, bk, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


@pytest.mark.parametrize("b,hq,hkv,t,d,bk", [
    (2, 4, 2, 128, 64, 32),
    (3, 8, 2, 256, 64, 64),
    (1, 16, 4, 512, 32, 128),
    (2, 4, 1, 64, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, hq, hkv, t, d, bk, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, d), dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, t + 1)
    out = decode_attention_kernel(q, k, v, lengths, bk=bk, interpret=True)
    ref = decode_ref(q, k, v, lengths)
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


def test_decode_attention_length_edge_cases():
    """length=1 and length=T (full cache)."""
    b, hq, hkv, t, d = 2, 4, 2, 64, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, t, hkv, d))
    v = jax.random.normal(ks[2], (b, t, hkv, d))
    for lengths in [jnp.array([1, 1]), jnp.array([t, t]), jnp.array([1, t])]:
        out = decode_attention_kernel(q, k, v, lengths, bk=16, interpret=True)
        ref = decode_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("v,d,n,bn", [
    (64, 16, 32, 8), (128, 32, 64, 16), (100, 8, 40, 40), (256, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_batched_gather_sweep(v, d, n, bn, dtype):
    if dtype == jnp.int32:
        table = jax.random.randint(KEY, (v, d), 0, 1000)
    else:
        table = jax.random.normal(KEY, (v, d), dtype)
    ids = jax.random.randint(KEY, (n,), 0, v)
    out = batched_gather(table, ids, bn=bn, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gather_ref(table, ids)))


def test_gather_duplicate_and_boundary_ids():
    table = jax.random.normal(KEY, (32, 8))
    ids = jnp.array([0, 0, 31, 31, 5, 5, 0, 31])
    out = batched_gather(table, ids, bn=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gather_ref(table, ids)))


@pytest.mark.parametrize("b,c,h,p,n", [
    (2, 8, 4, 16, 32), (1, 16, 2, 8, 8), (3, 4, 5, 32, 16), (1, 32, 1, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, c, h, p, n, dtype):
    from repro.kernels.ssd_scan.kernel import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    ks = jax.random.split(KEY, 2)
    states = jax.random.normal(ks[0], (b, c, h, p, n), dtype)
    decay = jax.nn.sigmoid(jax.random.normal(ks[1], (b, c, h))).astype(jnp.float32)
    prev, fin = ssd_scan(states, decay, interpret=True)
    rprev, rfin = ssd_scan_ref(states, decay)
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(prev, np.float32),
                               np.asarray(rprev, np.float32), rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(rfin),
                               rtol=rtol, atol=atol)


def test_ssd_scan_matches_model_ssd_chunked():
    """The kernel's semantics == the inter-chunk lax.scan inside
    models.ssm.ssd_chunked (state entering each chunk + final state)."""
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    b, c, h, p, n = 2, 6, 3, 8, 16
    ks = jax.random.split(KEY, 2)
    states = jax.random.normal(ks[0], (b, c, h, p, n))
    decay = jax.nn.sigmoid(jax.random.normal(ks[1], (b, c, h)))

    def model_scan(states, decay):
        s0 = jnp.zeros((b, h, p, n), jnp.float32)

        def step(carry, inp):
            st_c, dec_c = inp
            return carry * dec_c[:, :, None, None] + st_c, carry

        final, prev = jax.lax.scan(
            step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decay, 1, 0)))
        return jnp.moveaxis(prev, 0, 1), final

    p1, f1 = ssd_scan_ref(states, decay)
    p2, f2 = model_scan(states, decay)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-6)


def test_ops_wrappers_fall_back_on_cpu():
    from repro.kernels.batched_gather.ops import gather_op
    from repro.kernels.decode_attention.ops import decode_op
    from repro.kernels.flash_attention.ops import attention_op

    q = jax.random.normal(KEY, (1, 4, 64, 32))
    k = jax.random.normal(KEY, (1, 2, 64, 32))
    v = jax.random.normal(KEY, (1, 2, 64, 32))
    out = attention_op(q, k, v, use_kernel=False)
    # jit vs eager: XLA CPU fuses softmax differently → small numeric drift
    np.testing.assert_allclose(np.asarray(out), np.asarray(attention_ref(q, k, v)),
                               rtol=2e-3, atol=2e-3)
    qd = jax.random.normal(KEY, (2, 4, 32))
    kd = jax.random.normal(KEY, (2, 64, 2, 32))
    vd = jax.random.normal(KEY, (2, 64, 2, 32))
    lens = jnp.array([10, 60])
    np.testing.assert_allclose(
        np.asarray(decode_op(qd, kd, vd, lens, use_kernel=False)),
        np.asarray(decode_ref(qd, kd, vd, lens)), rtol=2e-3, atol=2e-3)
    t = jax.random.normal(KEY, (100, 16))
    ids = jnp.arange(50) % 100
    np.testing.assert_array_equal(np.asarray(gather_op(t, ids, use_kernel=False)),
                                  np.asarray(gather_ref(t, ids)))

# --------------------------------------------------------------- registry

def test_registry_facade_exports():
    """`import repro.kernels` populates the registry and re-exports every
    public wrapper — the one entry point callers need."""
    import repro.kernels as K

    assert set(K.registry.names()) == {
        "batched_gather", "decode_attention", "flash_attention",
        "paged_decode_attention", "ssd_scan"}
    for name in K.__all__:
        assert getattr(K, name) is not None


@pytest.mark.parametrize("name", [
    "batched_gather", "decode_attention", "flash_attention",
    "paged_decode_attention", "ssd_scan"])
def test_registry_parity_sweep(name):
    """Registry-driven ref-vs-kernel parity: every registered op's sample
    agrees between its Pallas kernel (interpret mode) and its jnp oracle —
    registering an op automatically buys it this gate."""
    import repro.kernels as K

    op = K.registry.get(name)
    assert op.sample is not None, f"{name} registered without a parity sample"
    for seed in (0, 1):
        s = op.sample(jax.random.PRNGKey(seed))
        ref = op.ref(*s.args, **s.common)
        out = op.kernel(*s.args, **s.common, **s.kernel, interpret=True)
        for r, o in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(out)):
            if s.tol is None:
                np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
            else:
                np.testing.assert_allclose(
                    np.asarray(o, np.float32), np.asarray(r, np.float32),
                    rtol=s.tol[0], atol=s.tol[1])


def test_registry_dispatch_policy():
    """dispatch() falls back to the ref off-TPU without interpret, runs the
    kernel under interpret, and respects the supports gate."""
    from repro.kernels import registry
    from repro.kernels.batched_gather.ref import gather_ref

    table = jax.random.normal(KEY, (64, 16))
    ids = jax.random.randint(KEY, (24,), 0, 64)
    # 24 % min(16, 24) != 0 → supports rejects → ref even under interpret
    out = registry.dispatch("batched_gather", (table, ids),
                            kernel_kwargs={"bn": 16}, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(gather_ref(table, ids)))
    with pytest.raises(KeyError, match="unknown kernel op"):
        registry.get("nope")
    # conflicting re-registration is an error; identical one is a no-op
    op = registry.get("batched_gather")
    registry.register("batched_gather", ref=op.ref, kernel=op.kernel,
                      supports=op.supports, sample=op.sample)
    with pytest.raises(ValueError, match="already registered"):
        registry.register("batched_gather", ref=lambda *a: None,
                          kernel=lambda *a, **k: None)


# --------------------------------------------------------- paged attention

@pytest.mark.parametrize("b,hq,hkv,np_,ps,d", [
    (2, 4, 2, 8, 16, 64),
    (1, 8, 2, 4, 32, 64),
    (3, 4, 4, 6, 8, 32),   # MHA, non-pow2 page count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(b, hq, hkv, np_, ps, d, dtype):
    from repro.kernels.paged_attention.kernel import paged_decode_attention_kernel
    from repro.kernels.paged_attention.ref import paged_decode_ref

    n_pages = b * np_ + 1
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k_pages = jax.random.normal(ks[1], (n_pages, ps, hkv, d), dtype)
    v_pages = jax.random.normal(ks[2], (n_pages, ps, hkv, d), dtype)
    tables = jax.random.permutation(ks[3], jnp.arange(1, n_pages)
                                    ).reshape(b, np_).astype(jnp.int32)
    lengths = jax.random.randint(ks[4], (b,), 1, np_ * ps + 1)
    out = paged_decode_attention_kernel(q, k_pages, v_pages, tables, lengths,
                                        interpret=True)
    ref = paged_decode_ref(q, k_pages, v_pages, tables, lengths)
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


def test_paged_decode_matches_dense_decode():
    """A paged cache whose tables are a permutation of a dense cache's
    pages attends identically to the dense split-KV kernel — paging is a
    layout change, not a numeric one."""
    b, hq, hkv, t, d, ps = 2, 4, 2, 128, 64, 16
    np_ = t // ps
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, t, hkv, d))
    v = jax.random.normal(ks[2], (b, t, hkv, d))
    lengths = jnp.array([37, 128])
    # scatter the dense rows into a shuffled page pool
    perm = np.asarray(jax.random.permutation(ks[3], np.arange(b * np_)))
    k_pages = jnp.reshape(k, (b * np_, ps, hkv, d))[jnp.asarray(perm)]
    v_pages = jnp.reshape(v, (b * np_, ps, hkv, d))[jnp.asarray(perm)]
    inv = np.empty_like(perm)
    inv[perm] = np.arange(b * np_)
    tables = jnp.asarray(inv.reshape(b, np_), jnp.int32)
    from repro.kernels.paged_attention.kernel import paged_decode_attention_kernel

    paged = paged_decode_attention_kernel(q, k_pages, v_pages, tables, lengths,
                                          interpret=True)
    dense = decode_attention_kernel(q, k, v, lengths, bk=ps, interpret=True)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_padded_table_slots_unread():
    """Pages past ceil(length/ps) may alias ANY page (here: page 0 vs a
    poison page) without changing the output — the masking guarantee
    page-granular spill/restore relies on."""
    from repro.kernels.paged_attention.kernel import paged_decode_attention_kernel

    b, hq, hkv, np_, ps, d = 1, 4, 2, 4, 16, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k_pages = jax.random.normal(ks[1], (np_ + 2, ps, hkv, d))
    v_pages = jax.random.normal(ks[2], (np_ + 2, ps, hkv, d))
    poison = np_ + 1
    k_pages = k_pages.at[poison].set(1e9)
    v_pages = v_pages.at[poison].set(1e9)
    lengths = jnp.array([2 * ps - 3])  # two valid pages
    t_pad0 = jnp.array([[1, 2, 0, 0]], jnp.int32)
    t_poison = jnp.array([[1, 2, poison, poison]], jnp.int32)
    out0 = paged_decode_attention_kernel(q, k_pages, v_pages, t_pad0, lengths,
                                         interpret=True)
    out1 = paged_decode_attention_kernel(q, k_pages, v_pages, t_poison,
                                         lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))
