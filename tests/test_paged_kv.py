"""Paged KV: block-table pool units, page-granular spill/restore, and
paged-vs-dense bit-identity through the scheduler's eviction path."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import OneOrAll
from repro.models.registry import get_arch
from repro.serving.engine import HostSpillPool, InferenceEngine, KVPartition
from repro.serving.kv import KVView
from repro.serving.paged_kv import PagedInferenceEngine, PagedKVPool, PagedKVView
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler


@pytest.fixture(scope="module")
def setup():
    arch = get_arch("llama3-8b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


# ------------------------------------------------------------- pool units

def test_pool_alloc_free_round_trip():
    pool = PagedKVPool(8, page_size=4)
    assert pool.pages_for(0) == 0
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    got = pool.alloc_table("a", n=3)
    assert len(got) == 3 and pool.n_free_pages == 5
    pool.extend_table("a", n=2)
    assert len(pool.table("a")) == 5 and pool.n_free_pages == 3
    pool.free_table("a")
    assert pool.n_free_pages == 8 and not pool.has_table("a")


def test_pool_explicit_pages_and_conflicts():
    pool = PagedKVPool(6, page_size=4)
    pool.alloc_table("a", pages=[2, 3])
    assert pool.table("a") == (2, 3)
    with pytest.raises(ValueError, match="not free"):
        pool.alloc_table("b", pages=[3])
    with pytest.raises(ValueError, match="already allocated"):
        pool.alloc_table("a", n=1)
    with pytest.raises(ValueError, match="exactly one"):
        pool.alloc_table("c")
    pool.free_table("a")
    assert pool.n_free_pages == 6


def test_pool_refcounted_prefix_sharing():
    """share() aliases pages without copying; a page frees only when its
    LAST owner drops it."""
    pool = PagedKVPool(4, page_size=4)
    src = pool.alloc_table("src", n=2)
    shared = pool.share("src", "dst")
    assert shared == src and pool.n_free_pages == 2  # no new pages taken
    pool.free_table("src")
    assert pool.n_free_pages == 2  # dst still owns them
    pool.free_table("dst")
    assert pool.n_free_pages == 4


def test_pool_oom_evicts_lru_unpinned_to_host():
    pool = PagedKVPool(4, page_size=4)
    pool.alloc_table("old", n=2)
    pool.alloc_table("new", n=2)
    pool.pin("new")
    pool.table("old")  # touch: old is now MRU...
    pool.alloc_table("big", n=2)  # ...but still the only evictable table
    assert pool.host_tables.keys() == {"old"}
    assert pool.evicted == 1 and not pool.has_table("old")
    # every remaining table pinned → OOM is an error, not a spin
    pool.pin("big")
    with pytest.raises(RuntimeError, match="pinned"):
        pool.alloc_table("doomed", n=1)


def test_pool_block_table_padding():
    pool = PagedKVPool(8, page_size=4)
    pool.alloc_table("a", pages=[5, 2, 7])
    bt = pool.block_table("a", max_pages=6)
    assert bt.dtype == np.int32
    np.testing.assert_array_equal(bt, [5, 2, 7, 0, 0, 0])


def test_paged_kv_view_page_budget_bound():
    """The view is the partition min-bounded by whole-lane page budgets;
    an under-provisioned pool admits less, a full one changes nothing."""
    part = KVPartition(4, {"x": 1})
    pool = PagedKVPool(2 * 4, page_size=4)  # only 2 lanes' worth of pages
    view = PagedKVView(part, pool, pages_per_lane=4)
    assert isinstance(view, KVView) and isinstance(part, KVView)
    assert view.n_free == 2 and view.n_free_for("x") == 2
    pool.alloc_table("r0", n=4)
    assert view.n_free == 1
    lane = view.alloc("x")
    view.release(lane)
    assert view.benefits(lane, "x") and not view.benefits(lane, "y")


# ------------------------------------------------------------ paged engine

def _run_sched(eng, prompts, max_new=8, **kw):
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    sched.run_until_drained()
    return reqs, sched


def test_paged_matches_dense_through_straggler_spill(setup):
    """The acceptance gate: paged and dense engines produce bit-identical
    outputs per request through a spill/restore-heavy scheduler run, while
    the paged engine moves strictly fewer KV bytes."""
    arch, params = setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 200, size=n).astype(np.int32)
               for n in (5, 9, 13, 7)]

    dense = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                            max_len=48, kv_spill=HostSpillPool(8))
    d_reqs, d_sched = _run_sched(dense, prompts, lane_timeout=2)

    paged = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                                 max_len=48, kv_spill=HostSpillPool(8),
                                 page_size=8, prefetch_pages=1)
    p_reqs, p_sched = _run_sched(paged, prompts, lane_timeout=2)

    assert d_sched.stats.kv_spilled >= 1  # the scenario actually evicts
    assert p_sched.stats.kv_spilled == d_sched.stats.kv_spilled
    for dr, pr in zip(d_reqs, p_reqs):
        assert dr.generated == pr.generated, (dr.rid, dr.generated, pr.generated)
    assert paged.kv_bytes_moved < dense.kv_bytes_moved
    assert paged.kv_bytes_moved <= 0.5 * dense.kv_bytes_moved


def test_paged_spill_restore_round_trip_and_prefetch_tail(setup):
    """Restore splices prefetch_pages synchronously and queues the tail;
    the tail lands before the next decode step, so generation resumes
    exactly where the eviction stopped."""
    arch, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, 200, size=14).astype(np.int32)

    eng = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                               max_len=32, kv_spill=HostSpillPool(4),
                               page_size=8, prefetch_pages=1)
    r = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.admit([r], None)
    for _ in range(3):
        out = eng.decode_tick()
        r.generated.append(out[r.lane])
    before = list(r.generated)
    assert eng.spill(r.lane, r.rid, None)
    lane = eng.try_restore(r.rid, None)
    assert lane is not None
    # 14 prompt tokens + 3 decodes = 17 rows = 3 pages > 1 prefetched page
    assert lane in eng._pending_restore
    r.lane = lane
    out = eng.decode_tick()  # flushes the tail, then decodes
    assert not eng._pending_restore
    r.generated.append(out[lane])

    ref_eng = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                                   max_len=32, page_size=8)
    ref = Request(rid=1, prompt=prompt, max_new_tokens=6)
    ref_eng.admit([ref], None)
    for _ in range(4):
        ref.generated.append(ref_eng.decode_tick()[ref.lane])
    assert r.generated == ref.generated and r.generated[:3] == before[:3]


def test_paged_block_tables_grow_with_decode(setup):
    """A lane's block table starts at the prompt's pages and gains one
    page each time decode crosses a page boundary."""
    arch, params = setup
    eng = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                               max_len=32, page_size=8)
    r = Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                max_new_tokens=16)
    eng.admit([r], None)
    assert len(eng.pool.table(r.lane)) == 1  # 6 rows + next write < 8
    for _ in range(3):
        eng.decode_tick()
    # length 9: decode wrote position 8 → page 1 must be in the table
    assert len(eng.pool.table(r.lane)) == 2
    for _ in range(8):
        eng.decode_tick()
    assert len(eng.pool.table(r.lane)) == 3
    eng.retire(r.lane)
    assert not eng.pool.has_table(r.lane)
    assert eng.pool.n_free_pages == eng.n_lanes * eng.pages_per_lane


def test_batched_oversized_prompts_admit_together(setup):
    """Carried-over fix: a burst of oversized prompts goes through the
    chunk pipeline as ONE batched dispatch (per-request resumable parts),
    not one prompt per speculation bet — outputs still exact."""
    arch, params = setup
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, 200, size=13).astype(np.int32)
               for _ in range(3)]

    ref_eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                              max_len=48)
    ref_reqs, _ = _run_sched(ref_eng, prompts, max_new=4)

    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                          max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(),
                                        overlap=True, chunk_tokens=4)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    sched.run_until_drained()
    # one batched chunked bet, not three serialized ones
    assert sched.stats.spec_dispatched == 3
    trace = [n for _, n in sched.stats.admission_trace]
    assert 3 in trace  # the three oversized prompts landed together
    for rr, r in zip(ref_reqs, reqs):
        assert rr.generated == r.generated


def test_paged_view_feeds_paged_kernel(setup):
    """paged_view() exposes live KV as (pages, block tables); the Pallas
    paged kernel over that view agrees with the dense oracle over a DENSE
    engine's cache rows for the same requests — the end-to-end bridge from
    pool bookkeeping through page contents to the kernel."""
    from repro.kernels.decode_attention.ref import decode_ref
    from repro.kernels.paged_attention.ops import paged_decode_op

    arch, params = setup
    rng = np.random.default_rng(17)
    prompts = [rng.integers(1, 200, size=6 + 4 * i).astype(np.int32)
               for i in range(2)]
    eng = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                               max_len=32, page_size=8)
    assert eng.paged_compute  # reduced llama3 is a full-context dense stack
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng.admit(reqs, None)
    for _ in range(2):
        eng.decode_tick()
    view = eng.paged_view()
    assert view is not None and view["lanes"] == [0, 1]
    hkv, hd = view["k_pages"].shape[2], view["k_pages"].shape[3]
    q = jax.random.normal(jax.random.PRNGKey(0), (2, hkv * 2, hd))
    paged = paged_decode_op(q, view["k_pages"], view["v_pages"],
                            view["block_tables"], view["lengths"],
                            interpret=True)
    # Dense oracle: an ordinary dense engine run of the same requests —
    # its per-lane cache rows must equal what the pages hold.
    dense = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                            max_len=32)
    dreqs = [Request(rid=i, prompt=p, max_new_tokens=4)
             for i, p in enumerate(prompts)]
    dense.admit(dreqs, None)
    for _ in range(2):
        dense.decode_tick()
    lanes = jnp.asarray([r.lane for r in dreqs])
    k = dense.cache["layers"]["k"][0][lanes]
    v = dense.cache["layers"]["v"][0][lanes]
    ref = decode_ref(q, k, v, view["lengths"])
    np.testing.assert_allclose(np.asarray(paged), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
