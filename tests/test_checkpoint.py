"""Checkpoint manager: async writes, atomic layout, restore, retention,
and elastic restore (save on 1 device → restore onto an 8-device mesh,
via subprocess so the device count doesn't leak into this process)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, load_pytree, save_pytree


def tree():
    return {
        "layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step_scalar": jnp.float32(3.5),
        "embed": {"table": jnp.ones((16, 8), jnp.bfloat16)},
    }


def test_pytree_roundtrip(tmp_path):
    t = tree()
    save_pytree(t, tmp_path / "x")
    like = jax.tree_util.tree_map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    r = load_pytree(tmp_path / "x", like)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_save_restore_async(tmp_path):
    with CheckpointManager(tmp_path, keep_last=2) as mgr:
        params = tree()
        state = {"opt": jnp.zeros((4,))}
        mgr.save(3, params, state)
        mgr.wait()
        assert mgr.latest_step() == 3
        p2, s2 = mgr.restore(3, params, state)
        np.testing.assert_array_equal(np.asarray(p2["layers"]["w"]),
                                      np.asarray(params["layers"]["w"]))


def test_retention_and_latest(tmp_path):
    with CheckpointManager(tmp_path, keep_last=2) as mgr:
        for s in (1, 2, 3, 4):
            mgr.save(s, {"w": jnp.full((2,), float(s))}, blocking=True)
        assert mgr.latest_step() == 4
        kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
        assert len(kept) == 2 and kept[-1].endswith("0004")


def test_no_partial_checkpoint_visible(tmp_path):
    """Atomicity: while a write is in flight, LATEST still points at the
    previous complete checkpoint."""
    mgr = CheckpointManager(tmp_path, keep_last=3)
    mgr.save(1, {"w": jnp.zeros((2,))}, blocking=True)
    big = {"w": jnp.zeros((512, 512))}
    mgr.save(2, big)  # async
    step = mgr.latest_step()
    assert step in (1, 2)  # never a corrupt intermediate
    mgr.wait()
    assert mgr.latest_step() == 2
    mgr.close()


def test_restart_resumes_training(tmp_path):
    """Train → checkpoint → 'crash' → restore → identical continuation."""
    import dataclasses

    from repro.models.registry import get_arch
    from repro.train.optimizer import AdamWConfig
    from repro.train.step import TrainStepConfig, make_train_step
    from repro.data.pipeline import SyntheticLMStream

    arch = get_arch("olmo-1b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    key = jax.random.PRNGKey(0)
    stream = SyntheticLMStream(arch.cfg.vocab_size, 16, 4)
    init_state, step = make_train_step(arch, AdamWConfig(lr=1e-3),
                                       TrainStepConfig(donate=False))
    params = arch.init(key)
    state = init_state(params)

    # run 5 steps, checkpoint at step 3
    mgr = CheckpointManager(tmp_path)
    for i in range(5):
        params, state, _ = step(params, state, stream.batch_at(i))
        if i == 2:
            mgr.save(3, params, state, blocking=True)
    final_direct = params

    # 'crash'; restore and continue from step 3 with the same stream offsets
    p2, s2 = mgr.restore(3, params, state)
    for i in range(3, 5):
        p2, s2, _ = step(p2, s2, stream.batch_at(i))
    for a, b in zip(jax.tree_util.tree_leaves(final_direct),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6, atol=1e-6)
    mgr.close()


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

root = sys.argv[1]
mgr = CheckpointManager(root)
like = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
step, params, _ = mgr.restore_latest(like)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sharded = jax.device_put(params["w"], NamedSharding(mesh, P("data", "model")))
assert len(sharded.addressable_shards) == 8
total = float(jnp.sum(sharded))
print(json.dumps({"step": step, "sum": total, "shards": len(sharded.addressable_shards)}))
"""


def test_elastic_restore_onto_8_devices(tmp_path):
    w = jnp.arange(128, dtype=jnp.float32).reshape(16, 8)
    with CheckpointManager(tmp_path) as mgr:
        mgr.save(7, {"w": w}, blocking=True)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=Path(__file__).parents[1],
        timeout=180,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["step"] == 7 and res["shards"] == 8
    assert abs(res["sum"] - float(jnp.sum(w))) < 1e-3
