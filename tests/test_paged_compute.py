"""Paged decode compute: oversubscribed pools, mid-decode eviction with
bit-identical resume, page quotas, and the fused prefill+decode dispatch."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.strategies import OneOrAll
from repro.kernels import registry
from repro.models.registry import get_arch
from repro.serving.engine import HostSpillPool, InferenceEngine, KVPartition
from repro.serving.paged_kv import PagedInferenceEngine, PagedKVPool, PagedKVView
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler


@pytest.fixture(scope="module")
def setup():
    arch = get_arch("llama3-8b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


def _run_sched(eng, prompts, max_new=8, **kw):
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    sched.run_until_drained()
    return reqs, sched


# -------------------------------------------------------- oversubscription

def test_oversubscribed_admission_bound(setup):
    """With n_pages < n_lanes * max_len / page_size, admission is bounded
    by instantaneous whole-lane page budgets, not free lanes."""
    arch, params = setup
    eng = PagedInferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                               max_len=32, page_size=8, n_pages=8)
    assert eng.paged_compute and eng.pages_per_lane == 4
    # 4 free lanes, but only 8 pages = 2 whole-lane budgets.
    assert eng.partition.n_free == 4
    assert eng.kv.n_free == 2 and eng.kv.n_free_for(None) == 2
    r = Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                max_new_tokens=4)
    eng.admit([r], None)  # 6-token prompt: one page
    assert eng.pool.n_free_pages == 7 and eng.kv.n_free == 1


def test_oversubscribed_constructor_guards(setup):
    arch, params = setup
    with pytest.raises(ValueError, match="at least one lane"):
        PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                             max_len=32, page_size=8, n_pages=3)


def test_mid_decode_eviction_and_restore_bit_identical(setup):
    """An oversubscribed pool evicts the LRU lane mid-decode under page
    pressure; the scheduler re-queues it, the restore resumes it, and the
    final outputs are bit-identical to a fully-provisioned dense run."""
    arch, params = setup
    rng = np.random.default_rng(23)
    prompts = [rng.integers(1, 200, size=n).astype(np.int32) for n in (6, 5)]

    dense = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                            max_len=32)
    d_reqs, _ = _run_sched(dense, prompts, max_new=16)

    paged = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                                 max_len=32, page_size=8, n_pages=5,
                                 kv_spill=HostSpillPool(8), prefetch_pages=1)
    p_reqs, p_sched = _run_sched(paged, prompts, max_new=16)

    # Growth to 3 pages per lane exceeds the 5-page pool: pressure evicted
    # at least one lane mid-decode, and the restore resumed it.
    assert paged.page_evictions >= 1
    assert p_sched.stats.kv_spilled >= 1
    assert p_sched.stats.kv_restored >= 1
    for dr, pr in zip(d_reqs, p_reqs):
        assert dr.generated == pr.generated, (dr.rid, dr.generated,
                                              pr.generated)


def test_all_pinned_pressure_raises(setup):
    """When every page is held by the lanes requesting growth themselves,
    eviction has no victim and the pool raises instead of spinning."""
    arch, params = setup
    eng = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                               max_len=16, page_size=8, n_pages=2)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(1, 200, size=9)
                    .astype(np.int32), max_new_tokens=2) for i in range(2)]
    # Each 9-token prompt needs 2 pages; committing both needs 4 > 2, and
    # both lanes are in the commit's avoid set — no evictable victim.
    with pytest.raises(RuntimeError, match="pinned"):
        eng.admit(reqs, None)


def test_page_quota_reserves_pages_for_template():
    """Lane reservations translate into page quotas: a shared-pool burst
    cannot consume the pages a reserved template is owed."""
    part = KVPartition(4, {"x": 2})
    pool = PagedKVPool(16, page_size=4)
    used = {"x": 0}
    view = PagedKVView(part, pool, pages_per_lane=4,
                       page_quota={"x": 8}, used_pages=lambda t: used.get(t, 0))
    # x sees its reservation + shared; y sees the shared pool minus the
    # 8 pages still owed to x: (16 - 8) // 4 = 2 lane-equivalents.
    assert view.n_free_for("x") == 4
    assert view.n_free_for("y") == 2 and view.n_free_for(None) == 2
    used["x"] = 8  # x's lanes now hold their quota: nothing is owed
    pool.alloc_table("x0", n=8)
    assert view.n_free_for("y") == 2  # (16 - 8 free) // 4, no owed pages
    used["x"] = 0  # quota unmet again while only 8 pages remain free
    assert view.n_free_for("y") == 0


# ------------------------------------------------------------ fused dispatch

def test_fused_tick_is_one_dispatch_and_exact(setup):
    """A decode tick that folds a staged prefill chunk issues exactly ONE
    jitted device program, and both the decode lane's tokens and the
    chunked prompt's first token match the unfused engines."""
    arch, params = setup
    rng = np.random.default_rng(29)
    p0 = rng.integers(1, 200, size=6).astype(np.int32)
    pbig = rng.integers(1, 200, size=13).astype(np.int32)

    eng = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                               max_len=32, page_size=8)
    r0 = Request(rid=0, prompt=p0, max_new_tokens=12)
    eng.admit([r0], None)
    big = Request(rid=1, prompt=pbig, max_new_tokens=4)
    staged = eng.prefill_dispatch([big], template=None, chunk=4)
    assert staged.pending and not staged.complete
    fused_ticks = 0
    while not staged.complete:
        assert eng.stage_chunk(staged)
        before = eng.dispatches
        out = eng.decode_tick()
        assert eng.dispatches - before == 1  # decode + chunk, one program
        r0.generated.append(out[r0.lane])
        fused_ticks += 1
    assert eng.fused_folds == fused_ticks and fused_ticks >= 2
    assert not eng.stage_chunk(staged)  # nothing pending: fusion declines
    eng.commit_prefill(staged)

    # Unfused oracle: dense engine, same decode cadence, one-shot prefill.
    dense = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                            max_len=32)
    d0 = Request(rid=0, prompt=p0, max_new_tokens=12)
    dense.admit([d0], None)
    for _ in range(fused_ticks):
        d0.generated.append(dense.decode_tick()[d0.lane])
    dbig = Request(rid=1, prompt=pbig, max_new_tokens=4)
    dense.admit([dbig], None)
    assert r0.generated == d0.generated
    assert big.generated == dbig.generated  # == the first token each


def test_fused_overlap_scheduler_bit_identical(setup):
    """End-to-end overlap + chunked run: the paged engine folds chunks
    into decode ticks (fused megabatch) and still matches the dense
    engine's outputs bit-for-bit."""
    arch, params = setup
    rng = np.random.default_rng(31)
    prompts = [rng.integers(1, 200, size=5).astype(np.int32),
               rng.integers(1, 200, size=13).astype(np.int32),
               rng.integers(1, 200, size=7).astype(np.int32)]

    dense = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                            max_len=48)
    d_reqs, _ = _run_sched(dense, prompts, max_new=6, overlap=True,
                           chunk_tokens=4)

    paged = PagedInferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                                 max_len=48, page_size=8)
    p_reqs, _ = _run_sched(paged, prompts, max_new=6, overlap=True,
                           chunk_tokens=4)

    for dr, pr in zip(d_reqs, p_reqs):
        assert dr.generated == pr.generated, (dr.rid, dr.generated,
                                              pr.generated)


# ------------------------------------------------------- kernel dispatch path

def test_interpret_kernel_matches_ref_path(setup):
    """The Pallas paged kernel under interpret mode and the pure-jnp ref
    produce the same greedy tokens — the CI kernels job's exercise."""
    arch, params = setup
    rng = np.random.default_rng(37)
    prompts = [rng.integers(1, 200, size=n).astype(np.int32) for n in (6, 9)]

    def run(**kw):
        eng = PagedInferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                                   max_len=32, page_size=8, **kw)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=3)
                for i, p in enumerate(prompts)]
        eng.admit(reqs, None)
        for _ in range(3):
            out = eng.decode_tick()
            for r in reqs:
                r.generated.append(out[r.lane])
        return [r.generated for r in reqs]

    assert run(use_kernel=False) == run(interpret=True)


def test_interpret_default_env(setup, monkeypatch):
    """REPRO_KERNEL_INTERPRET flips the engine's default dispatch to
    interpret mode (how CI runs kernel bodies on CPU)."""
    arch, params = setup
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    assert not registry.interpret_default()
    eng = PagedInferenceEngine(arch, params, n_lanes=1, max_prompt_len=16,
                               max_len=16, page_size=8)
    assert eng._interpret is False
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    assert registry.interpret_default()
    eng = PagedInferenceEngine(arch, params, n_lanes=1, max_prompt_len=16,
                               max_len=16, page_size=8)
    assert eng._interpret is True
