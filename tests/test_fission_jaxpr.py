"""Device-level Rule A (jaxpr scan fission): semantic equivalence with
``lax.scan`` across program shapes, autodiff/vmap composition, precondition
errors, and hypothesis property tests over random scan bodies."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade gracefully: property tests skip, rest run
    HAVE_HYPOTHESIS = False
from jax import lax

from repro.core.fission import (
    FissionPreconditionError,
    FissionReport,
    count_queries,
    fission_scan,
    scan_with_queries,
)
from repro.core.query import async_query, table_gather_spec

TABLE = jax.random.normal(jax.random.PRNGKey(7), (128, 8))
IDS = (jnp.arange(24) * 5 + 3) % 128


def assert_trees_close(a, b, rtol=1e-5, atol=1e-5):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), rtol=rtol, atol=atol)


def test_basic_equivalence():
    def body(c, i):
        row = async_query(table_gather_spec, TABLE, i)
        return c + row.sum(), row[0]

    ref = lax.scan(body, jnp.float32(0), IDS)
    out = fission_scan(body, jnp.float32(0), IDS)
    assert_trees_close(ref, out)


def test_report_counts():
    def body(c, i):
        r = async_query(table_gather_spec, TABLE, i)
        return c + r.sum(), None

    rep = FissionReport()
    fission_scan(body, jnp.float32(0), IDS, report=rep)
    assert rep.n_queries_found == rep.n_queries_batched == 1
    assert count_queries(body, jnp.float32(0), IDS) == 1


def test_producer_recurrence_allowed():
    """Example 2's pattern: loop-carried dep entirely on the producer side."""

    def body(carry, i):
        acc, key = carry
        key = (key * 7 + 13) % 128
        row = async_query(table_gather_spec, TABLE, key)
        return (acc + row.mean(), key), row[:2]

    init = (jnp.float32(0), jnp.int32(3))
    assert_trees_close(lax.scan(body, init, IDS), fission_scan(body, init, IDS))


def test_consumer_recurrence_allowed():
    """Accumulator over query results: consumer-side recurrence is fine."""

    def body(carry, i):
        row = async_query(table_gather_spec, TABLE, i)
        return carry * 0.9 + row.sum(), carry

    assert_trees_close(
        lax.scan(body, jnp.float32(1), IDS), fission_scan(body, jnp.float32(1), IDS)
    )


def test_cycle_rejected():
    def body(key, i):
        row = async_query(table_gather_spec, TABLE, key)
        return jnp.argmax(row).astype(jnp.int32), row.sum()

    with pytest.raises(FissionPreconditionError):
        fission_scan(body, jnp.int32(0), IDS)


def test_two_independent_queries_both_batched():
    def body(c, i):
        r1 = async_query(table_gather_spec, TABLE, i)
        r2 = async_query(table_gather_spec, TABLE, (i + 7) % 128)
        return c + r1.sum() + r2.sum(), (r1[0], r2[1])

    rep = FissionReport()
    ref = lax.scan(body, jnp.float32(0), IDS)
    out = fission_scan(body, jnp.float32(0), IDS, report=rep)
    assert_trees_close(ref, out, rtol=1e-4)
    assert rep.n_queries_batched == 2


def test_chained_queries_both_batched():
    def body(c, i):
        r1 = async_query(table_gather_spec, TABLE, i)
        k2 = jnp.abs(r1[0] * 100).astype(jnp.int32) % 128
        r2 = async_query(table_gather_spec, TABLE, k2)
        return c + r2.sum(), r2[0]

    rep = FissionReport()
    assert_trees_close(
        lax.scan(body, jnp.float32(0), IDS),
        fission_scan(body, jnp.float32(0), IDS, report=rep),
        rtol=1e-4,
    )
    assert rep.n_queries_batched == 2


def test_nested_fission():
    def inner(c, j):
        r = async_query(table_gather_spec, TABLE, j)
        return c + r.sum(), None

    def outer_f(c, i):
        s, _ = fission_scan(inner, jnp.float32(0), (i + jnp.arange(4)) % 128)
        r = async_query(table_gather_spec, TABLE, i)
        return c + s + r[0], s

    def outer_ref(c, i):
        s, _ = lax.scan(inner, jnp.float32(0), (i + jnp.arange(4)) % 128)
        r = async_query(table_gather_spec, TABLE, i)
        return c + s + r[0], s

    assert_trees_close(
        lax.scan(outer_ref, jnp.float32(0), IDS),
        fission_scan(outer_f, jnp.float32(0), IDS),
        rtol=1e-4,
    )


def test_grad_through_fission():
    def mk(scan):
        def loss(t):
            def b(c, i):
                r = async_query(table_gather_spec, t, i)
                return c + (r ** 2).sum(), None

            return scan(b, jnp.float32(0), IDS)[0]

        return loss

    g1 = jax.grad(mk(fission_scan))(TABLE)
    g2 = jax.grad(mk(lax.scan))(TABLE)
    assert_trees_close(g1, g2)


def test_vmap_over_fission():
    def f(ii):
        def b(c, i):
            return c + async_query(table_gather_spec, TABLE, i).sum(), None

        return fission_scan(b, jnp.float32(0), ii)[0]

    batched_ids = jnp.stack([IDS, (IDS + 1) % 128, (IDS + 2) % 128])
    out = jax.vmap(f)(batched_ids)
    ref = jnp.stack([f(row) for row in batched_ids])
    assert_trees_close(out, ref)


def test_hlo_hoists_gather_out_of_loop():
    """Structural proof of the transformation in the compiled HLO: the
    fissioned program executes ONE batched gather outside every loop, while
    the baseline fetches a row per iteration inside the while body (XLA
    lowers the single-row take to a dynamic-slice in the loop — N scalar-
    driven HBM accesses; exactly what Rule A removes)."""
    import re

    def _mk(scan):
        def f(t, ii):
            return scan(
                lambda c, i: (c + async_query(table_gather_spec, t, i).sum(), None),
                jnp.float32(0), ii,
            )[0]

        return f

    def jaxpr_stats(f):
        """(top-level gathers, gathers inside scan bodies) of the jaxpr."""
        jx = jax.make_jaxpr(f)(TABLE, IDS).jaxpr

        def count(j, top):
            tg, lg = 0, 0
            for e in j.eqns:
                name = e.primitive.name
                if name in ("gather", "take", "async_query"):
                    if top:
                        tg += 1
                    else:
                        lg += 1
                elif name == "scan":
                    stg, slg = count(e.params["jaxpr"].jaxpr, False)
                    lg += stg + slg
                elif "jaxpr" in e.params:  # pjit/closed_call wrappers
                    sub = e.params["jaxpr"]
                    sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                    stg, slg = count(sub, top)
                    tg += stg
                    lg += slg
            return tg, lg

        return count(jx, True)

    fg, flg = jaxpr_stats(_mk(fission_scan))
    bg, blg = jaxpr_stats(_mk(lax.scan))
    assert fg >= 1 and flg == 0, (fg, flg)  # fission: gather hoisted out
    assert blg >= 1, (bg, blg)              # baseline: query inside the loop

    # and the compiled artifact has exactly one real gather op
    txt = jax.jit(_mk(fission_scan)).lower(TABLE, IDS).compile().as_text()
    assert len(re.findall(r"gather\(", txt)) == 1


def test_no_queries_falls_back_to_scan():
    def body(c, i):
        return c + i, c

    assert_trees_close(
        lax.scan(body, jnp.int32(0), IDS), fission_scan(body, jnp.int32(0), IDS)
    )


def test_scan_with_queries_switch():
    def body(c, i):
        return c + async_query(table_gather_spec, TABLE, i).sum(), None

    a = scan_with_queries(body, jnp.float32(0), IDS, fission=True)
    b = scan_with_queries(body, jnp.float32(0), IDS, fission=False)
    assert_trees_close(a, b)


def test_effectful_body_rejected():
    def body(c, i):
        jax.debug.print("i={i}", i=i)
        r = async_query(table_gather_spec, TABLE, i)
        return c + r.sum(), None

    with pytest.raises(FissionPreconditionError):
        fission_scan(body, jnp.float32(0), IDS)


def test_masked_conditional_query():
    """Rule B, device form: predication by masking (neutral key + select)."""

    def body(c, i):
        use = (i % 2) == 0
        key = jnp.where(use, i, 0)  # neutral key
        row = async_query(table_gather_spec, TABLE, key)
        val = jnp.where(use, row.sum(), 0.0)
        return c + val, val

    assert_trees_close(
        lax.scan(body, jnp.float32(0), IDS), fission_scan(body, jnp.float32(0), IDS)
    )


# ---------------------------------------------------------------------------
# property test: random scan bodies
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:  # CI installs hypothesis (pip install -e .[dev])
    @st.composite
    def scan_body(draw):
        """Random body: producer chain → query on derived key → consumer chain,
        with randomized carry usage."""
        n_carry = draw(st.integers(1, 3))
        use_prod_rec = draw(st.booleans())
        use_cons_rec = draw(st.booleans())
        coefs = [draw(st.floats(0.1, 1.9)) for _ in range(4)]
        emit_row = draw(st.booleans())

        def body(carry, i):
            cs = list(carry)
            if use_prod_rec:
                cs[0] = cs[0] * coefs[0] + jnp.float32(1.0)
            key = (i + jnp.int32(cs[0] * 3 if use_prod_rec else 0)) % 128
            row = async_query(table_gather_spec, TABLE, key)
            v = (row * coefs[1]).sum()
            # Never let a consumer value flow into a carry the producer reads
            # (that would be a genuine true-dependence cycle → correctly raises).
            if use_cons_rec and n_carry > 1:
                cs[1] = cs[1] * coefs[2] + v
            elif not use_prod_rec:
                cs[-1] = v + coefs[3]
            elif n_carry > 1:
                cs[-1] = v + coefs[3]
            y = row[0] if emit_row else v
            return tuple(cs), y

        init = tuple(jnp.float32(k + 1) for k in range(n_carry))
        return body, init


    @settings(max_examples=25, deadline=None)
    @given(scan_body(), st.integers(2, 24))
    def test_property_fission_equals_scan(bi, n):
        body, init = bi
        ids = (jnp.arange(n) * 11 + 2) % 128
        ref = lax.scan(body, init, ids)
        out = fission_scan(body, init, ids)
        assert_trees_close(ref, out, rtol=1e-4, atol=1e-4)
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_property_suite_requires_hypothesis():
        """Placeholder so the dropped property tests surface as a SKIP
        instead of silently disappearing from collection."""
