"""Differential equivalence harness as tier-1 tests.

The full harness (``core/equivalence.py`` + ``hir_strategies.py``) runs a
small default budget here so every environment checks it; CI's dedicated
``equivalence`` job raises the budget via ``REPRO_EQUIV_PROGRAMS`` and runs
a seed matrix via ``REPRO_EQUIV_SEED`` (mirroring the chaos-job pattern).
"""
from __future__ import annotations

import os
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade gracefully: property tests skip, rest run
    HAVE_HYPOTHESIS = False

from hir_strategies import gen_program
from repro.core.equivalence import (
    check_program,
    count_fissioned,
    run_differential,
    synthesize_async,
)
from repro.core.hir import (
    Assign,
    Call,
    DepKind,
    If,
    Loop,
    Proc,
    Program,
    Query,
    build_ddg,
    transform_program,
)

EQUIV_SEED = int(os.environ.get("REPRO_EQUIV_SEED", "0"))
EQUIV_PROGRAMS = int(os.environ.get("REPRO_EQUIV_PROGRAMS", "25"))


def _add(a, b):
    return a + b


def _inc(a):
    return a + 1


def _is_even(a):
    return int(a) % 2 == 0


# ---------------------------------------------------------------------------
# the corpus run: the acceptance-criteria assertion
# ---------------------------------------------------------------------------


def test_differential_corpus_no_violations():
    """N generated programs, zero equivalence violations, every approved
    rewrite strictly cheaper in round trips (the CI job sets N=200)."""
    rep = run_differential(EQUIV_SEED, EQUIV_PROGRAMS)
    assert rep.ok, "\n\n".join(rep.violations[:5])
    assert rep.n_programs == EQUIV_PROGRAMS
    # the corpus must actually exercise the transformer, not vacuously pass
    assert rep.n_fissioned >= EQUIV_PROGRAMS // 2
    assert rep.n_chaos > 0 and rep.n_overlap > 0
    assert rep.n_round_trip_wins >= rep.n_fissioned - rep.n_chaos


def test_generated_corpus_exercises_proc_call():
    """The generator emits Call statements and the transformer fissions
    through them (inline-then-fission) — including under chaos."""
    rng = random.Random(EQUIV_SEED + 17)
    saw_call_and_fissioned = 0
    checked_chaos = False
    for i in range(40):
        gp = gen_program(rng)
        has_call = any(isinstance(s, Call) for s in gp.program.body) or any(
            isinstance(s, Loop)
            and any(isinstance(b, Call) for b in s.body)
            for s in gp.program.body
        )
        if not has_call:
            continue
        res = check_program(gp.program, gp.inputs, gp.observe)
        assert res.equivalent, res.mismatches
        if res.fissioned:
            saw_call_and_fissioned += 1
            if not checked_chaos:
                chaos = check_program(gp.program, gp.inputs, gp.observe,
                                      chaos_seed=EQUIV_SEED * 31 + i)
                assert chaos.equivalent, chaos.mismatches
                checked_chaos = True
    assert saw_call_and_fissioned >= 3
    assert checked_chaos


# ---------------------------------------------------------------------------
# hand-written Proc/Call programs (thesis: inline-then-fission)
# ---------------------------------------------------------------------------


def _lookup_proc() -> Proc:
    return Proc(
        name="lookup",
        formals=("key",),
        body=[
            Assign(target="k2", fn=_inc, args=("key",)),
            Query(target="row", query_name="qa", params=("k2",)),
            Assign(target="out", fn=_add, args=("row", "key")),
        ],
        result="out",
    )


def _proc_loop_program() -> tuple[Program, dict]:
    """A caller loop invoking a query-bearing proc per item: fission must
    reach through the call boundary."""
    proc = _lookup_proc()
    prog = Program(
        body=[
            Assign(target="total", fn=(lambda: 0), args=()),
            Loop(item_var="it", iter_var="items", body=[
                Call(target="r", proc=proc, args=("it",)),
                Assign(target="total", fn=_add, args=("total", "r")),
            ]),
        ],
        inputs=("items",),
    )
    return prog, {"items": [2, 4, 6, 8, 10, 12]}


def test_hand_written_proc_call_fissions_with_rt_win():
    prog, inputs = _proc_loop_program()
    res = check_program(prog, inputs, ("total",))
    assert res.equivalent, res.mismatches
    assert res.fissioned >= 1
    assert res.round_trip_win
    assert res.sync_round_trips == 6  # one per item, through the call
    assert res.async_round_trips == 3  # one batch


def test_hand_written_proc_call_bit_identical_under_chaos():
    prog, inputs = _proc_loop_program()
    for chaos_seed in (EQUIV_SEED * 1000 + 1, EQUIV_SEED * 1000 + 2):
        res = check_program(prog, inputs, ("total",), chaos_seed=chaos_seed)
        assert res.equivalent, res.mismatches
        assert res.fissioned >= 1


def test_nested_proc_loop_fissions_inner():
    """Proc containing a whole query loop, called per outer item: the
    inlined inner loop fissions once per outer iteration."""
    proc = Proc(
        name="sum_rows",
        formals=("ks",),
        body=[
            Assign(target="acc", fn=(lambda: 0), args=()),
            Loop(item_var="k", iter_var="ks", body=[
                Query(target="r", query_name="qb", params=("k",)),
                Assign(target="acc", fn=_add, args=("acc", "r")),
            ]),
        ],
        result="acc",
    )
    prog = Program(
        body=[
            Assign(target="grand", fn=(lambda: 0), args=()),
            Loop(item_var="g", iter_var="groups", body=[
                Call(target="s", proc=proc, args=("rows",)),
                Assign(target="grand", fn=_add, args=("grand", "s")),
            ]),
        ],
        inputs=("groups", "rows"),
    )
    inputs = {"groups": [1, 2, 3], "rows": [10, 20, 30, 40]}
    res = check_program(prog, inputs, ("grand",))
    assert res.equivalent, res.mismatches
    assert res.fissioned >= 1
    assert res.round_trip_win


# ---------------------------------------------------------------------------
# synthesis-lite search
# ---------------------------------------------------------------------------


def test_synthesize_keeps_best_equivalent_rewrite():
    rng = random.Random(EQUIV_SEED + 5)
    gp = gen_program(rng)
    r = synthesize_async(gp.program, gp.inputs, gp.observe)
    assert r.all_equivalent
    assert r.best_round_trips <= r.sync_round_trips
    # the chosen rewrite really has that cost when re-checked
    res = check_program(gp.program, gp.inputs, gp.observe,
                        sites=r.best_sites)
    assert res.equivalent
    assert res.async_round_trips == r.best_round_trips


def test_synthesize_empty_when_nothing_fissionable():
    prog = Program(
        body=[
            Assign(target="acc", fn=(lambda: 0), args=()),
            Loop(item_var="it", iter_var="items", body=[
                Query(target="q", query_name="qa", params=("it",)),
                # consumer-side effect: a later iteration's producer query
                # would cross it (external loop-carried anti edge) — refuse
                Assign(target=None, fn=_inc, args=("q",), effect="log"),
                Assign(target="acc", fn=_add, args=("acc", "q")),
            ]),
        ],
        inputs=("items",),
    )
    inputs = {"items": [1, 2, 3, 4]}
    r = synthesize_async(prog, inputs, ("acc",))
    assert r.best_sites == ()
    assert count_fissioned(r.best_program.body) == 0
    assert r.best_round_trips == r.sync_round_trips


def test_site_restriction_is_respected():
    prog, inputs = _proc_loop_program()
    kept = transform_program(prog, overlap=False, sites=())
    assert count_fissioned(kept.body) == 0
    res = check_program(prog, inputs, ("total",), sites=())
    assert res.equivalent
    assert res.async_round_trips == res.sync_round_trips


# ---------------------------------------------------------------------------
# build_ddg property: edges are exactly the read/write-set intersections
# ---------------------------------------------------------------------------

_EXT = "__db__"

_INTRA = {"flow": DepKind.FLOW, "anti": DepKind.ANTI, "out": DepKind.OUTPUT}
_INTRA_X = {"flow": DepKind.EXT_FLOW, "anti": DepKind.EXT_ANTI,
            "out": DepKind.EXT_OUTPUT}
_LOOP = {"flow": DepKind.LOOP_FLOW, "anti": DepKind.LOOP_ANTI,
         "out": DepKind.LOOP_OUTPUT}
_LOOP_X = {"flow": DepKind.EXT_LOOP_FLOW, "anti": DepKind.EXT_LOOP_ANTI,
           "out": DepKind.EXT_LOOP_OUTPUT}


def _expected_edges(body) -> set:
    """The spec, recomputed independently: an edge per variable in the
    read/write-set intersection of each ordered statement pair, external
    effects routed through the single ``__db__`` resource."""
    def rw(s):
        r, w = set(s.reads()), set(s.writes())
        if s.external_reads():
            r.add(_EXT)
        if s.external_writes():
            w.add(_EXT)
        return r, w

    rws = [rw(s) for s in body]
    want = set()
    n = len(body)
    for a in range(n):
        ra, wa = rws[a]
        for b in range(a + 1, n):
            rb, wb = rws[b]
            for v in wa & rb:
                want.add((a, b, (_INTRA_X if v == _EXT else _INTRA)["flow"], v))
            for v in ra & wb:
                want.add((a, b, (_INTRA_X if v == _EXT else _INTRA)["anti"], v))
            for v in wa & wb:
                want.add((a, b, (_INTRA_X if v == _EXT else _INTRA)["out"], v))
    for a in range(n):
        ra, wa = rws[a]
        for b in range(n):
            rb, wb = rws[b]
            for v in wa & rb:
                want.add((a, b, (_LOOP_X if v == _EXT else _LOOP)["flow"], v))
            for v in ra & wb:
                want.add((a, b, (_LOOP_X if v == _EXT else _LOOP)["anti"], v))
            for v in wa & wb:
                want.add((a, b, (_LOOP_X if v == _EXT else _LOOP)["out"], v))
    return want


def _ddg_matches_spec(seed: int) -> None:
    rng = random.Random(seed)
    gp = gen_program(rng)
    # check every flat statement sequence in the program: the top level and
    # each loop body (where loop-carried edges matter)
    bodies = [gp.program.body]
    stack = list(gp.program.body)
    while stack:
        s = stack.pop()
        if isinstance(s, Loop):
            bodies.append(s.body)
            stack.extend(s.body)
        elif isinstance(s, If):
            stack.extend(s.then_body)
            stack.extend(s.else_body)
    for body in bodies:
        got = {(e.src, e.dst, e.kind, e.var)
               for e in build_ddg(body, loop_body=True).edges}
        want = _expected_edges(body)
        assert got == want, (
            f"missing={sorted(want - got, key=repr)[:5]} "
            f"spurious={sorted(got - want, key=repr)[:5]}")


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_property_ddg_edges_exactly_match_rw_intersections(seed):
        """No missing and no spurious FLOW/ANTI/OUTPUT edges, plain or
        loop-carried or external, on any generated program."""
        _ddg_matches_spec(seed)
else:
    def test_property_ddg_edges_exactly_match_rw_intersections():
        """Seeded-random fallback for the hypothesis property (same skip
        pattern as test_lane_policy.py would use — but the plain-random
        core lets us run a real bounded variant instead of skipping)."""
        for seed in range(EQUIV_SEED, EQUIV_SEED + 40):
            _ddg_matches_spec(seed)


# ---------------------------------------------------------------------------
# hypothesis layer over the whole checker (skips when not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    from hir_strategies import hir_programs

    @settings(max_examples=25, deadline=None)
    @given(gp=hir_programs())
    def test_property_transform_is_observationally_equivalent(gp):
        res = check_program(gp.program, gp.inputs, gp.observe)
        assert res.equivalent, res.mismatches
        assert not res.violations()
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_property_transform_is_observationally_equivalent():
        """Placeholder so the dropped property test surfaces as a SKIP
        instead of silently disappearing from collection."""
