"""Prefix-granular KV sharing: COW pool invariants, PrefixIndex matching,
alias-at-admit bit-identity, partial eviction of shared readers, the
cross-template decode megabatch, and chaos recovery with sharing on."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.lane_policy import PrefixIndex
from repro.core.strategies import OneOrAll
from repro.models.registry import get_arch
from repro.serving.engine import HostSpillPool, InferenceEngine
from repro.serving.paged_kv import PagedInferenceEngine, PagedKVPool
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def setup():
    arch = get_arch("llama3-8b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


def _run_sched(eng, reqs, **kw):
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), **kw)
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    sched.run_until_drained(max_ticks=2000)
    return sched


def _shared_prompts(rng, n_readers=2, prefix_tokens=16, tail_tokens=4):
    """One owner + n_readers prompts sharing a page-aligned prefix."""
    shared = rng.integers(1, 200, size=prefix_tokens).astype(np.int32)
    return [np.concatenate([shared,
                            rng.integers(1, 200, size=tail_tokens)
                            .astype(np.int32)])
            for _ in range(1 + n_readers)]


# ------------------------------------------------------------ PrefixIndex

def test_prefix_index_longest_proper_match():
    idx = PrefixIndex(page_size=4)
    idx.insert("a", range(100, 112))  # 12 tokens: prefixes of 4 and 8
    # identical 12-token prompt: the 8-token prefix wins (k*ps < len
    # keeps the match strictly proper — a 12-token match would leave no
    # novel tail to prefill)
    assert idx.lookup(range(100, 112)) == ("a", 2)
    # diverges inside page 2: only the first page matches
    assert idx.lookup([100, 101, 102, 103, 99, 99, 99, 99, 1]) == ("a", 1)
    assert idx.lookup([1, 2, 3, 4, 5]) is None
    assert idx.lookup([100, 101, 102]) is None  # shorter than one page
    assert idx.hits == 2 and idx.misses == 2
    assert idx.lookup(range(100, 112), exclude={"a"}) is None


def test_prefix_index_remove_and_reregister():
    idx = PrefixIndex(page_size=4)
    idx.insert("a", range(8))
    idx.insert("b", range(8))
    assert idx.lookup(range(9))[0] == "a"  # insertion order breaks ties
    idx.remove("a")
    assert idx.lookup(range(9))[0] == "b"
    idx.remove("b")
    assert idx.lookup(range(9)) is None and len(idx) == 0
    idx.insert("a", range(4, 12))  # re-register under new tokens
    assert idx.lookup(range(4, 10)) == ("a", 1)


# -------------------------------------------------------- pool COW units

def test_pool_share_prefix_and_cow_fork():
    pool = PagedKVPool(8, page_size=4)
    src = pool.alloc_table("src", n=3)
    shared = pool.share("src", "dst", n_pages=2)
    assert shared == src[:2] and pool.n_free_pages == 5
    assert pool.page_ref(src[0]) == 2 and pool.page_ref(src[2]) == 1
    assert pool.shared_prefix_pages("src") == 2
    assert pool.shared_prefix_pages("dst") == 2
    # private page: fork declines
    assert pool.fork_page("src", 2) is None
    # shared page: the writer gets a fresh page, the reader keeps the old
    old, new = pool.fork_page("dst", 1)
    assert old == src[1] and new not in src
    assert pool.pages("dst")[1] == new and pool.pages("src")[1] == old
    assert pool.page_ref(old) == 1 and pool.page_ref(new) == 1
    pool.free_table("src")
    pool.free_table("dst")
    assert pool.n_free_pages == 8
    pool.alloc_table("s2", n=1)
    with pytest.raises(ValueError, match="has"):
        pool.share("s2", "d2", n_pages=5)  # longer than the source table


def test_pool_adopt_transfers_holds():
    pool = PagedKVPool(4, page_size=4)
    pages = pool.alloc_table("a", n=2)
    pool.incref_pages(pages)     # a spill entry's hold
    pool.free_table("a")
    assert pool.n_free_pages == 2  # the hold keeps them alive
    pool.adopt_table("b", pages)   # transfer: no extra incref
    assert pool.pages("b") == tuple(pages)
    pool.free_table("b")
    assert pool.n_free_pages == 4
    with pytest.raises(RuntimeError, match="free"):
        pool.adopt_table("c", pages)  # pages no longer referenced


def test_pool_all_shared_eviction_raises_typed():
    """An all-shared pool raises the same typed error as all-pinned
    instead of corrupting a live alias group (whole-table LRU eviction
    must be refcount-aware)."""
    pool = PagedKVPool(4, page_size=4)
    pool.alloc_table("a", n=2)
    pool.alloc_table("b", n=2)
    pool.free_table("b")
    pool.share("a", "alias")  # every resident page now refcounted > 1
    with pytest.raises(RuntimeError, match="pinned"):
        pool.alloc_table("c", n=3)
    # both tables intact: no alias group was corrupted
    assert pool.pages("a") == pool.pages("alias")
    pool.free_table("alias")
    pool.alloc_table("c", n=3)  # unshared again: LRU eviction of "a" works
    assert not pool.has_table("a") and pool.evicted == 1


def test_pool_double_free_raises():
    pool = PagedKVPool(4, page_size=4)
    pages = pool.alloc_table("a", n=2)
    pool.free_table("a")
    with pytest.raises(RuntimeError, match="double free"):
        pool.decref_pages(pages)
    with pytest.raises(RuntimeError, match="cannot reference"):
        pool.incref_pages(pages)


def _run_cow_invariants(seed: int, n_ops: int = 60) -> None:
    """Seeded random alias/fork/write/free workload on the pool against a
    shadow model: every table always reads its own values, forks never
    perturb siblings, refcounts return to zero after all owners retire."""
    rng = np.random.default_rng(seed)
    pool = PagedKVPool(16, page_size=4)
    phys: dict[int, int] = {}   # physical page -> symbolic contents
    shadow: dict[str, list] = {}  # table -> expected contents per slot
    stamp = 0

    def check():
        for key, vals in shadow.items():
            got = [phys[p] for p in pool.pages(key)]
            assert got == vals, (key, got, vals)

    for i in range(n_ops):
        op = rng.integers(0, 4)
        if op == 0 and pool.n_free_pages > 0:  # alloc
            n = int(rng.integers(1, min(4, pool.n_free_pages) + 1))
            key = f"t{i}"
            try:
                pages = pool.alloc_table(key, n=n)
            except RuntimeError:
                continue  # nothing evictable (all shared): acceptable
            for p in pages:
                stamp += 1
                phys[p] = stamp
            shadow[key] = [phys[p] for p in pages]
        elif op == 1 and shadow:  # share a prefix
            src = str(rng.choice(sorted(shadow)))
            k = int(rng.integers(1, len(shadow[src]) + 1))
            dst = f"s{i}"
            pool.share(src, dst, n_pages=k)
            shadow[dst] = list(shadow[src][:k])
        elif op == 2 and shadow:  # write one slot (COW when aliased)
            key = str(rng.choice(sorted(shadow)))
            slot = int(rng.integers(0, len(shadow[key])))
            page = pool.pages(key)[slot]
            if pool.page_ref(page) > 1:
                if pool.n_free_pages < 1:
                    continue  # no room to fork: the engine makes room
                old, new = pool.fork_page(key, slot)
                phys[new] = phys[old]  # the device-copy step
            stamp += 1
            phys[pool.pages(key)[slot]] = stamp
            shadow[key][slot] = stamp
        elif op == 3 and shadow:  # retire a reader
            key = str(rng.choice(sorted(shadow)))
            pool.free_table(key)
            del shadow[key]
        check()
    for key in sorted(shadow):
        pool.free_table(key)
    assert pool.n_free_pages == 16
    assert all(pool.page_ref(p) == 0 for p in range(16))
    with pytest.raises(RuntimeError, match="double free"):
        pool.decref_pages([0])


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 1337])
def test_cow_invariants_seeded(seed):
    _run_cow_invariants(seed)


if HAVE_HYPOTHESIS:  # pragma: no cover - optional dependency
    @given(seed=st.integers(min_value=0, max_value=2**16))
    @settings(max_examples=25, deadline=None)
    def test_cow_invariants_hypothesis(seed):
        _run_cow_invariants(seed)


# ----------------------------------------------- engine: prefix-hit admit

def test_prefix_hit_bit_identical_and_zero_cost(setup):
    """Admitting prompts with a shared page-aligned prefix aliases the
    prefix pages (zero KV bytes moved for them), prefills only the novel
    tail, and produces bit-identical outputs to the unshared engine —
    including intra-batch sharing (the owner arrives in the same batch)."""
    arch, params = setup
    rng = np.random.default_rng(41)
    prompts = _shared_prompts(rng, n_readers=2)

    def run(prefix_share):
        eng = PagedInferenceEngine(arch, params, n_lanes=4,
                                   max_prompt_len=32, max_len=32,
                                   page_size=8, prefix_share=prefix_share)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        eng.admit(reqs, None)
        for _ in range(4):
            out = eng.decode_tick()
            for r in reqs:
                r.generated.append(out[r.lane])
        return eng, [r.generated for r in reqs]

    e0, g0 = run(False)
    e1, g1 = run(True)
    assert g1 == g0
    assert e1.prefix_hits == 2  # both readers aliased the in-batch owner
    assert e1.prefill_flops_saved > 0
    assert e1.kv_bytes_moved < e0.kv_bytes_moved  # aliased pages are free
    ratio = e1.prefill_flops_total / (
        e1.prefill_flops_total - e1.prefill_flops_saved)
    assert ratio > 1.5


def test_prefix_share_requires_paged_compute(setup):
    arch, params = setup
    win = dataclasses.replace(arch, cfg=dataclasses.replace(arch.cfg,
                                                            attn_window=8))
    p = win.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="prefix_share"):
        PagedInferenceEngine(win, p, n_lanes=2, max_prompt_len=16,
                             max_len=32, page_size=8, prefix_share=True)


def test_cow_guard_forks_before_shared_page_write(setup):
    """A decode write into an aliased page forks a private copy first:
    the sibling's page bytes stay bit-identical and the writer's tokens
    are unchanged vs an unshared run."""
    arch, params = setup
    rng = np.random.default_rng(43)
    prompt = rng.integers(1, 200, size=12).astype(np.int32)

    def run(share):
        eng = PagedInferenceEngine(arch, params, n_lanes=2,
                                   max_prompt_len=16, max_len=32,
                                   page_size=8)
        r = Request(rid=0, prompt=prompt, max_new_tokens=4)
        eng.admit([r], None)
        ghost_page = None
        before = None
        if share:
            # Alias BOTH pages (incl. the one decode writes next) to a
            # ghost reader, as a raw-pool consumer might.
            pages = eng.pool.share(r.lane, "ghost", n_pages=2)
            ghost_page = pages[1]
            before = [np.asarray(a[:, ghost_page])
                      for a in jax.tree_util.tree_leaves(eng.cache)]
        for _ in range(4):
            r.generated.append(eng.decode_tick()[r.lane])
        if share:
            # the writer forked: the ghost's page is untouched
            assert eng.pool.pages(r.lane)[1] != ghost_page
            assert eng.pool.page_ref(ghost_page) == 1
            after = [np.asarray(a[:, ghost_page])
                     for a in jax.tree_util.tree_leaves(eng.cache)]
            for b, a in zip(before, after):
                np.testing.assert_array_equal(b, a)
            eng.pool.free_table("ghost")
        return r.generated

    assert run(share=True) == run(share=False)


# ------------------------------------------- partial eviction (satellite)

def test_shared_prefix_survives_straggler_spill(setup):
    """Regression: spilling one reader of a shared prefix moves only its
    private tail to host — the refcounted prefix pages stay resident for
    the sibling readers, and the restore re-adopts them with outputs
    bit-identical to an uninterrupted unshared run."""
    arch, params = setup
    rng = np.random.default_rng(47)
    prompts = _shared_prompts(rng, n_readers=2)

    def baseline():
        eng = PagedInferenceEngine(arch, params, n_lanes=4,
                                   max_prompt_len=32, max_len=32,
                                   page_size=8)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        eng.admit(reqs, None)
        for _ in range(6):
            out = eng.decode_tick()
            for r in reqs:
                r.generated.append(out[r.lane])
        return [r.generated for r in reqs]

    eng = PagedInferenceEngine(arch, params, n_lanes=4, max_prompt_len=32,
                               max_len=32, page_size=8, prefix_share=True,
                               kv_spill=HostSpillPool(8))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    eng.admit(reqs, None)
    for _ in range(2):
        out = eng.decode_tick()
        for r in reqs:
            r.generated.append(out[r.lane])
    victim = reqs[1]  # a reader, not the owner
    owner_prefix = eng.pool.pages(reqs[0].lane)[:2]
    free_before = eng.pool.n_free_pages
    bytes_before = eng.kv_bytes_moved
    assert eng.spill(victim.lane, victim.rid, None)
    # Only the victim's PRIVATE tail pages returned to the free list; the
    # 2 shared prefix pages stay resident under the spill entry's hold.
    assert all(eng.pool.page_ref(p) >= 2 for p in owner_prefix)
    spilled_bytes = eng.kv_bytes_moved - bytes_before
    prefix_rows_bytes = sum(  # what copying the 16 shared rows would cost
        a.dtype.itemsize * a.shape[0] * 16 * int(np.prod(a.shape[3:]))
        for a in jax.tree_util.tree_leaves(eng.cache))
    assert 0 < spilled_bytes < prefix_rows_bytes  # only the private tail
    assert eng.pool.n_free_pages > free_before
    # siblings keep decoding over the still-shared prefix
    for _ in range(1):
        out = eng.decode_tick()
        for r in (reqs[0], reqs[2]):
            r.generated.append(out[r.lane])
    lane = eng.try_restore(victim.rid, None)
    assert lane is not None
    victim.lane = lane
    for i in range(4):
        out = eng.decode_tick()
        victim.generated.append(out[lane])
        for r in (reqs[0], reqs[2]):
            if len(r.generated) < 7:  # admit added the first prefill token
                r.generated.append(out[r.lane])
    assert [r.generated for r in reqs] == baseline()


def test_spill_entry_drop_releases_prefix_holds(setup):
    """A spill entry that silently drops out of the host pool (LRU
    pressure) releases the refcounts it held on resident prefix pages —
    no page leak, the owner becomes the sole reader again."""
    arch, params = setup
    rng = np.random.default_rng(53)
    prompts = _shared_prompts(rng, n_readers=1)

    eng = PagedInferenceEngine(arch, params, n_lanes=4, max_prompt_len=32,
                               max_len=32, page_size=8, prefix_share=True,
                               kv_spill=HostSpillPool(max_entries=1))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    eng.admit(reqs, None)
    owner_prefix = eng.pool.pages(reqs[0].lane)[:2]
    assert eng.spill(reqs[1].lane, reqs[1].rid, None)
    assert all(eng.pool.page_ref(p) == 2 for p in owner_prefix)
    # An unrelated spill evicts the reader's entry (max_entries=1): the
    # on_drop hook must return the prefix holds.
    other = Request(rid=9, prompt=rng.integers(1, 200, size=5)
                    .astype(np.int32), max_new_tokens=2)
    eng.admit([other], None)
    assert eng.spill(other.lane, other.rid, None)
    assert reqs[1].rid not in eng.partition.spill
    assert all(eng.pool.page_ref(p) == 1 for p in owner_prefix)


def test_scheduler_prefix_hits_stat(setup):
    """End-to-end scheduler run with sharing on: outputs match the dense
    engine and stats.prefix_hits mirrors the engine counter."""
    arch, params = setup
    rng = np.random.default_rng(59)
    prompts = _shared_prompts(rng, n_readers=3)

    dense = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=32,
                            max_len=32)
    d_reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=5)
              for i, p in enumerate(prompts)]
    _run_sched(dense, d_reqs)

    eng = PagedInferenceEngine(arch, params, n_lanes=4, max_prompt_len=32,
                               max_len=32, page_size=8, prefix_share=True)
    p_reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=5)
              for i, p in enumerate(prompts)]
    sched = _run_sched(eng, p_reqs)
    assert sched.stats.prefix_hits >= 1
    assert sched.stats.prefix_hits == eng.prefix_hits
    for dr, pr in zip(d_reqs, p_reqs):
        assert dr.generated == pr.generated, (dr.rid,)


# --------------------------------------------------- megabatch + sampling

def test_megabatch_one_dispatch_across_templates(setup):
    """ONE decode dispatch per tick covers every active lane regardless
    of template/partition — the cross-template megabatch gate."""
    arch, params = setup
    rng = np.random.default_rng(61)
    eng = PagedInferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                               max_len=32, page_size=8,
                               kv_shares={"chat": 2, "embed": 1})
    ra = Request(rid=0, prompt=rng.integers(1, 200, size=6)
                 .astype(np.int32), max_new_tokens=4, template="chat")
    rb = Request(rid=1, prompt=rng.integers(1, 200, size=9)
                 .astype(np.int32), max_new_tokens=4, template="embed")
    eng.admit([ra], "chat")
    eng.admit([rb], "embed")
    for _ in range(4):
        before = eng.dispatches
        out = eng.decode_tick()
        assert eng.dispatches - before == 1  # one program, both templates
        assert ra.lane in out and rb.lane in out
        ra.generated.append(out[ra.lane])
        rb.generated.append(out[rb.lane])
    assert len(ra.generated) == 5 and len(rb.generated) == 5  # 1 + 4 ticks


def test_per_lane_sampling_in_one_megabatch(setup):
    """Per-lane sampling params ride through the single dispatch: a
    temperature-0 lane stays bit-identical to the all-greedy run while a
    sampled lane draws reproducibly — including across a spill/restore
    (the key is counter-based on the request's own position)."""
    arch, params = setup
    rng = np.random.default_rng(67)
    p0 = rng.integers(1, 200, size=6).astype(np.int32)
    p1 = rng.integers(1, 200, size=9).astype(np.int32)

    def run(sampled, interrupt=False):
        eng = PagedInferenceEngine(arch, params, n_lanes=2,
                                   max_prompt_len=16, max_len=32,
                                   page_size=8, kv_spill=HostSpillPool(4))
        r0 = Request(rid=0, prompt=p0, max_new_tokens=6)
        r1 = Request(rid=1, prompt=p1, max_new_tokens=6,
                     temperature=5.0 if sampled else 0.0, sample_seed=7)
        eng.admit([r0, r1], None)
        for i in range(6):
            if interrupt and i == 3:  # evict + restore the sampled lane
                assert eng.spill(r1.lane, r1.rid, None)
                r1.lane = eng.try_restore(r1.rid, None)
                assert r1.lane is not None
            out = eng.decode_tick()
            r0.generated.append(out[r0.lane])
            r1.generated.append(out[r1.lane])
        return r0.generated, r1.generated

    greedy0, greedy1 = run(sampled=False)
    s0_a, s1_a = run(sampled=True)
    s0_b, s1_b = run(sampled=True)
    assert s0_a == greedy0          # temp-0 lane untouched by the sampler
    assert s1_a == s1_b             # seeded sampling is deterministic
    assert s1_a != greedy1          # temp 5.0 actually samples
    _, s1_c = run(sampled=True, interrupt=True)
    assert s1_c == s1_a             # draws survive spill/restore


# ------------------------------------------------------- chaos (satellite)

def test_chaos_crash_on_shared_reader_bit_identical(setup):
    """Part 9 recovery with prefix sharing on: seeded lane crashes (which
    hit shared-prefix readers) quarantine, salvage the private tail,
    restore and resume — every request's tokens stay bit-identical to the
    fault-free unshared run, siblings unperturbed."""
    from repro.core.faults import ChaosEngine, ChaosPlan, chaos_seed
    from repro.core.resilience import Resilience

    arch, params = setup
    rng = np.random.default_rng(71)
    prompts = _shared_prompts(rng, n_readers=4, tail_tokens=3)

    def run(chaos, prefix_share):
        reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=5)
                for i, p in enumerate(prompts)]
        eng = PagedInferenceEngine(arch, params, n_lanes=3,
                                   max_prompt_len=32, max_len=32,
                                   page_size=8, prefix_share=prefix_share,
                                   kv_spill=HostSpillPool(max_entries=16))
        if chaos:
            eng = ChaosEngine(eng, ChaosPlan(seed=chaos_seed(0),
                                             decode_fault_rate=0.25))
        sched = ContinuousBatchingScheduler(
            eng, strategy=OneOrAll(),
            resilience=Resilience(quarantine_ticks=1) if chaos else None)
        for r in reqs:
            sched.submit(r)
        sched.producer_done()
        done = sched.run_until_drained(max_ticks=2000)
        assert len(done) == len(reqs)
        return {r.rid: list(r.generated) for r in reqs}, eng, sched

    baseline, _, _ = run(chaos=False, prefix_share=False)
    chaotic, eng, sched = run(chaos=True, prefix_share=True)
    assert eng.injected_decode_faults > 0, "chaos never bit: rate too low"
    assert sched.stats.quarantined > 0
    assert sched.stats.prefix_hits >= 1  # sharing was actually exercised
    assert chaotic == baseline
