import os

# Smoke tests and benches must see ONE device — never set
# xla_force_host_platform_device_count here (the dry-run sets it itself,
# in its own process).  Multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
