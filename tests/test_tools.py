"""Tooling tests: the bench-trend markdown renderer over artifact
histories (tools/bench_trend.py)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from tools.bench_trend import collect, main, render  # noqa: E402


def _write_run(tmp_path: Path, name: str, ratios: dict) -> Path:
    d = tmp_path / name
    d.mkdir()
    doc = {
        "batch_size_ratio": ratios.get("batch", 2.0),
        "throughput_ratio": ratios.get("tp", 3.0),
        "skewed_tenant": {"throughput_ratio": 2.0},
        "shared_projection": {"round_trip_gain": 3.0},
        "contention": {"submit_throughput_ratio": 5.0},
        "overlap": {"tokens_per_s_ratio": ratios.get("overlap", 1.5)},
        "overlap_depth": {"tokens_per_s_ratio": ratios.get("depth", 1.5)},
        "spill": {"hit_ratio": ratios.get("hit", 1.0)},
    }
    f = d / "bench_lanes.json"
    f.write_text(json.dumps(doc))
    return f


def test_bench_trend_renders_history_with_deltas(tmp_path):
    f1 = _write_run(tmp_path, "run-a", {"tp": 3.0, "depth": 1.2})
    f2 = _write_run(tmp_path, "run-b", {"tp": 4.5, "depth": 1.8})
    table = render(collect([str(f1), str(f2)], [], keep_order=True))
    lines = table.splitlines()
    assert lines[0].startswith("| run |")
    assert "overlap_depth.tokens_per_s_ratio" in lines[0]
    assert "spill.hit_ratio" in lines[0]
    assert lines[2].startswith("| run-a |")
    assert lines[3].startswith("| run-b |")
    assert "(+50.0%)" in lines[3]  # throughput 3.0 -> 4.5 on the last row
    # every row has one cell per metric (+ the label column)
    n_cols = lines[0].count("|")
    assert all(ln.count("|") == n_cols for ln in lines[1:])


def test_bench_trend_missing_metric_renders_dash(tmp_path):
    f1 = _write_run(tmp_path, "old-run", {})
    doc = json.loads(f1.read_text())
    del doc["overlap_depth"]  # a run predating the metric
    f1.write_text(json.dumps(doc))
    table = render(collect([str(f1)], [], keep_order=True))
    assert "—" in table


def test_bench_trend_cli_dir_search_and_out(tmp_path, capsys):
    _write_run(tmp_path, "r1", {})
    _write_run(tmp_path, "r2", {})
    out = tmp_path / "trend.md"
    assert main(["--dir", str(tmp_path), "--out", str(out)]) == 0
    text = out.read_text()
    assert text.count("\n") >= 4  # header + separator + 2 runs
    assert main([]) == 1  # no inputs → error exit
