"""LanePolicy engine: per-lane strategy isolation (hot/cold promotion,
no cross-lane state), tenant/lane quotas, weighted fairness, cross-template
projection sharing, result-cache TTL + invalidation hooks, AdaptiveCost
observe edge cases, and the scheduler's per-lane feedback / stuck-lane
diagnostics."""
from __future__ import annotations

import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade gracefully: property tests skip, rest run
    HAVE_HYPOTHESIS = False

from repro.core.lane_policy import LanePolicy
from repro.core.runtime import AsyncQueryRuntime
from repro.core.services import TableService
from repro.core.strategies import (
    AdaptiveCost,
    BatchingStrategy,
    LowerThreshold,
    PureAsync,
    PureBatch,
)

TABLES = {"t": {k: k * 10 for k in range(100)}}
USER_ROWS = {k: {"name": f"u{k}", "email": f"u{k}@x", "age": k % 80}
             for k in range(50)}


class Recording(BatchingStrategy):
    """decide()=take-all, records every observe call."""

    def __init__(self):
        self.observed: list[tuple[int, float]] = []
        self.decode_observed: list[float] = []
        self.aborted: list[float] = []

    def decide(self, n_pending, producer_done):
        return n_pending

    def observe(self, batch_size, duration):
        self.observed.append((batch_size, duration))

    def observe_decode(self, duration):
        self.decode_observed.append(duration)

    def observe_abort(self, duration, depth=1):
        self.aborted.append(duration)


# ---------------------------------------------------------------------------
# per-lane strategies: hot/cold promotion + isolation
# ---------------------------------------------------------------------------


def test_hot_cold_promotion_and_per_lane_instances():
    p = LanePolicy(hot_threshold=3)
    assert isinstance(p.strategy_for("a"), PureAsync)
    assert not p.is_hot("a")
    for _ in range(3):
        p.note_submit("a")
    assert p.is_hot("a")
    hot_a = p.strategy_for("a")
    assert isinstance(hot_a, AdaptiveCost)
    assert p.strategy_for("a") is hot_a  # promotion is sticky, instance stable
    # lane b is untouched: still cold, and a DIFFERENT instance
    assert isinstance(p.strategy_for("b"), PureAsync)
    assert p.strategy_for("b") is not p.strategy_for("a")
    # two hot lanes get two independent models
    for _ in range(3):
        p.note_submit("b")
    assert p.strategy_for("b") is not hot_a


def test_override_pins_lane_regardless_of_temperature():
    pinned = LowerThreshold(bt=3)
    p = LanePolicy(hot_threshold=0, overrides={"reports": pinned})
    for _ in range(10):
        p.note_submit("reports")
    assert p.strategy_for("reports") is pinned
    assert isinstance(p.strategy_for("other"), AdaptiveCost)  # threshold 0: hot


def test_observe_routes_to_the_lane_model_only():
    p = LanePolicy(hot_threshold=0)  # every lane hot from the start
    p.observe("a", 8, 1.0)
    p.observe("a", 1, 0.5)
    sa, sb = p.strategy_for("a"), p.strategy_for("b")
    assert (sa._n_single, sa._n_batch) == (1, 1)
    assert (sb._n_single, sb._n_batch) == (0, 0)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(
        obs=st.lists(
            st.tuples(
                st.sampled_from(["lane_a", "lane_b", "lane_c"]),
                st.integers(min_value=1, max_value=64),
                st.floats(min_value=0.0, max_value=10.0,
                          allow_nan=False, allow_infinity=False),
            ),
            max_size=60,
        )
    )
    def test_property_lane_models_never_share_state(obs):
        """Any interleaving of observations across lanes leaves each lane's
        model with exactly the evidence IT was shown — nothing leaks."""
        p = LanePolicy(hot_threshold=0)
        per_lane: dict = {}
        for lane, size, dur in obs:
            p.observe(lane, size, dur)
            kind = "single" if size <= 1 else "batch"
            per_lane.setdefault(lane, {"single": 0, "batch": 0})[kind] += 1
        for lane, want in per_lane.items():
            s = p.strategy_for(lane)
            assert s._n_single == want["single"]
            assert s._n_batch == want["batch"]
        # untouched lanes are pristine
        for lane in {"lane_a", "lane_b", "lane_c"} - set(per_lane):
            s = p.strategy_for(lane)
            assert s._n_single == 0 and s._n_batch == 0
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_property_lane_models_never_share_state():
        """Placeholder so the dropped property test surfaces as a SKIP
        instead of silently disappearing from collection."""


# ---------------------------------------------------------------------------
# weighted fairness
# ---------------------------------------------------------------------------


def test_weighted_fair_order_respects_weights():
    p = LanePolicy(lane_weights={"a": 2.0, "b": 1.0})
    picks = []
    for _ in range(30):
        lane = p.lane_order(["a", "b"])[0]
        picks.append(lane)
        p.charge(lane, 1)
    assert picks.count("a") == 20 and picks.count("b") == 10


def test_new_lane_joins_at_current_minimum_vtime():
    p = LanePolicy()
    for _ in range(10):
        p.charge("old", 1)
    # new joins AT old's vtime (10), not at 0 — it may not monopolize the
    # picker to "catch up"; the tie breaks by join order (old first).
    assert p.lane_order(["old", "new"]) == ["old", "new"]
    p.charge("old", 1)
    assert p.lane_order(["old", "new"])[0] == "new"


def test_lane_min_matches_lane_order_head():
    """lane_min is the O(n) single-selection twin of lane_order[0] — the
    ready-queue pop uses it so a weighted pick never sorts."""
    p = LanePolicy(lane_weights={"a": 2.0, "b": 1.0})
    for _ in range(20):
        cand = ["a", "b", "c"]
        assert p.lane_min(cand) == p.lane_order(cand)[0]
        p.charge(p.lane_min(cand), 1)
    with pytest.raises(ValueError):
        p.lane_min([])


def test_charge_scales_by_batch_size():
    p = LanePolicy()
    p.lane_order(["a", "b"])  # both join at vtime 0
    p.charge("a", 10)  # one big batch
    p.charge("b", 1)
    assert p.lane_order(["a", "b"]) == ["b", "a"]


def test_vtime_floor_spans_momentarily_drained_lanes():
    """A lane first seen while the busy lanes' queues happen to be empty
    must join at the GLOBAL vtime floor, not at 0 — otherwise it would
    monopolize the picker until it 'caught up' with the established lane."""
    p = LanePolicy()
    p.lane_order(["heavy"])
    for _ in range(100):
        p.charge("heavy", 1)           # heavy at vtime 100...
    assert p.lane_order(["light"]) == ["light"]  # ...and momentarily drained
    p.charge("light", 1)
    # heavy refills: alternation, not 100 picks of light first
    assert p.lane_order(["heavy", "light"])[0] == "heavy"
    p.charge("heavy", 1)   # 101 == light's 101: join order favors heavy
    assert p.lane_order(["heavy", "light"])[0] == "heavy"
    p.charge("heavy", 1)
    assert p.lane_order(["heavy", "light"])[0] == "light"


def test_invalid_lane_weight_rejected_at_construction():
    with pytest.raises(ValueError):
        LanePolicy(lane_weights={"t": 0.0})
    with pytest.raises(ValueError):
        LanePolicy(lane_weights={"t": -1.0})
    with pytest.raises(ValueError):
        LanePolicy(max_lanes=0)


def test_lane_state_bounded_by_max_lanes():
    p = LanePolicy(hot_threshold=1, max_lanes=4,
                   overrides={"pinned": PureAsync()})
    for i in range(50):
        lane = f"lane{i}"
        p.note_submit(lane)
        p.strategy_for(lane)
        p.charge(lane, 1)
        p.note_submit("pinned")
    assert len(p._submits) <= 4 + 1      # transient +1 before eviction settles
    assert len(p._strategies) <= 4
    assert "pinned" in p._submits        # overrides are never evicted
    assert "lane49" in p._submits        # most recent lane survives
    assert "lane0" not in p._submits     # coldest lanes were dropped


# ---------------------------------------------------------------------------
# quotas
# ---------------------------------------------------------------------------


class _GatedService(TableService):
    """execute() blocks until released; lets a test pin a call in flight."""

    def __init__(self, tables=None):
        super().__init__(tables or TABLES)
        self.started = threading.Event()
        self.release = threading.Event()

    def execute(self, query_name, params):
        self.started.set()
        assert self.release.wait(timeout=5.0)
        return super().execute(query_name, params)


def test_tenant_quota_blocks_only_that_tenant():
    svc = _GatedService()
    policy = LanePolicy(tenant_quotas={"whale": 2})
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=policy)
    rt.submit("t.lookup", (1,), tenant="whale")
    assert svc.started.wait(timeout=5.0)
    rt.submit("t.lookup", (2,), tenant="whale")  # outstanding=2 = quota
    entered, passed = threading.Event(), threading.Event()

    def third():
        entered.set()
        rt.submit("t.lookup", (3,), tenant="whale")
        passed.set()

    t = threading.Thread(target=third, daemon=True)
    t.start()
    assert entered.wait(timeout=5.0)
    assert not passed.wait(timeout=0.3)          # whale is at its bound...
    h_other = rt.submit("t.lookup", (4,), tenant="minnow")  # ...others aren't
    svc.release.set()
    assert passed.wait(timeout=5.0)
    rt.drain()
    assert rt.fetch(h_other) == 40
    rt.shutdown()
    assert rt.stats.quota_waits >= 1


def test_lane_quota_bounds_one_lane_not_others():
    svc = _GatedService(tables={"a": {1: 1}, "b": {1: 2}})
    policy = LanePolicy(lane_quota=1)
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=policy, dedup=False)
    rt.submit("a.lookup", (1,))
    assert svc.started.wait(timeout=5.0)  # a.lookup outstanding=1 = quota
    entered, passed = threading.Event(), threading.Event()

    def second_a():
        entered.set()
        rt.submit("a.lookup", (1,))
        passed.set()

    t = threading.Thread(target=second_a, daemon=True)
    t.start()
    assert entered.wait(timeout=5.0)
    assert not passed.wait(timeout=0.3)   # lane a is full...
    rt.submit("b.lookup", (1,))           # ...lane b admits immediately
    svc.release.set()
    assert passed.wait(timeout=5.0)
    rt.drain()
    rt.shutdown()


def test_default_tenant_quota_applies_to_unlisted_tenants():
    p = LanePolicy(tenant_quotas={"vip": 100}, default_tenant_quota=5)
    assert p.tenant_quota("vip") == 100
    assert p.tenant_quota("anyone") == 5
    assert p.tenant_quota(None) is None  # anonymous submissions unbounded


# ---------------------------------------------------------------------------
# cross-template projection sharing
# ---------------------------------------------------------------------------


def _shared_policy(batch: bool = True):
    # batch=True: PureBatch lanes (drain() before fetch).  batch=False: the
    # cold PureAsync default executes immediately (fetch without drain).
    if batch:
        policy = LanePolicy(hot_threshold=0, hot_factory=PureBatch)
    else:
        policy = LanePolicy(hot_threshold=10**9)
    policy.share("users.lookup", {
        "users.sel_name": lambda r: r["name"],
        "users.sel_email": lambda r: r["email"],
    })
    return policy


def test_projection_variants_share_one_lane_and_one_call():
    svc = TableService({"users": USER_ROWS})
    rt = AsyncQueryRuntime(svc, n_threads=2, policy=_shared_policy())
    h_name = rt.submit("users.sel_name", (7,))
    h_email = rt.submit("users.sel_email", (7,))
    h_full = rt.submit("users.lookup", (7,))
    rt.drain()
    assert rt.fetch(h_name) == "u7"
    assert rt.fetch(h_email) == "u7@x"
    assert rt.fetch(h_full) == USER_ROWS[7]
    rt.shutdown()
    # ONE execution served all three: variants coalesced onto the canonical
    assert svc.stats.single_queries + svc.stats.batched_items == 1
    assert rt.stats.deduped == 2
    assert rt.stats.shared == 2
    assert list(rt.stats.lane_traces) == ["users.lookup"]


def test_projection_share_rejects_conflicts():
    p = LanePolicy()
    p.share("users.lookup", {"users.sel_name": lambda r: r["name"]})
    with pytest.raises(ValueError):
        p.share("other.lookup", {"users.sel_name": lambda r: r})
    with pytest.raises(ValueError):
        p.share("users.lookup", {"users.lookup": lambda r: r})


def test_projection_applies_on_cache_hit():
    svc = TableService({"users": USER_ROWS})
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=_shared_policy(batch=False),
                           result_cache_size=8)
    assert rt.fetch(rt.submit("users.lookup", (3,))) == USER_ROWS[3]
    # cache now holds the canonical row; variant must hit AND project
    assert rt.fetch(rt.submit("users.sel_name", (3,))) == "u3"
    rt.shutdown()
    assert rt.stats.cache_hits == 1
    assert svc.stats.single_queries + svc.stats.batched_items == 1


def test_projection_error_surfaces_via_fetch():
    svc = TableService({"users": USER_ROWS})
    policy = LanePolicy()
    policy.share("users.lookup", {"users.bad": lambda r: r["nope"]})
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=policy)
    h = rt.submit("users.bad", (1,))
    with pytest.raises(KeyError):
        rt.fetch(h)
    rt.shutdown()


# ---------------------------------------------------------------------------
# auto-detected projection sharing (describe metadata)
# ---------------------------------------------------------------------------


def test_auto_share_from_metadata_coalesces_variants():
    """With describe() metadata, projection-compatible templates share one
    lane and one service call WITHOUT any explicit share() registration."""
    policy = LanePolicy(hot_threshold=0, hot_factory=PureBatch)
    policy.describe("users.lookup", base="users")  # full row: the superset
    policy.describe("users.sel_name", base="users", columns=("name",))
    policy.describe("users.sel_email", base="users", columns=("email",))
    svc = TableService({"users": USER_ROWS})
    rt = AsyncQueryRuntime(svc, n_threads=2, policy=policy)
    h_name = rt.submit("users.sel_name", (7,))
    h_email = rt.submit("users.sel_email", (7,))
    h_full = rt.submit("users.lookup", (7,))
    rt.drain()
    assert rt.fetch(h_name) == "u7"
    assert rt.fetch(h_email) == "u7@x"
    assert rt.fetch(h_full) == USER_ROWS[7]
    rt.shutdown()
    assert svc.stats.single_queries + svc.stats.batched_items == 1
    assert rt.stats.deduped == 2
    assert rt.stats.shared == 2
    assert list(rt.stats.lane_traces) == ["users.lookup"]


def test_auto_share_picks_widest_superset_and_multi_column_projector():
    p = LanePolicy()
    p.describe("u.a", base="u", columns=("a",))
    p.describe("u.ab", base="u", columns=("a", "b"))
    p.describe("u.abc", base="u", columns=("a", "b", "c"))
    row = {"a": 1, "b": 2, "c": 3}
    canon, proj = p.resolve("u.a")
    assert canon == "u.abc"  # widest covering superset, shared lane converges
    assert proj(row) == 1    # single column: bare value
    canon2, proj2 = p.resolve("u.ab")
    assert canon2 == "u.abc"
    assert proj2(row) == {"a": 1, "b": 2}  # multi column: mapping
    # the widest template itself stays unshared (it IS the canonical)
    assert p.resolve("u.abc") == ("u.abc", None)


def test_auto_share_requires_same_base_and_a_superset():
    p = LanePolicy()
    p.describe("u.a", base="u", columns=("a",))
    p.describe("v.lookup", base="v")  # different base: not compatible
    assert p.resolve("u.a") == ("u.a", None)
    assert p.resolve("v.lookup") == ("v.lookup", None)
    assert p.resolve("never.described") == ("never.described", None)


def test_explicit_share_wins_over_auto_detection():
    p = LanePolicy()
    p.describe("users.lookup", base="users")
    p.describe("users.sel_name", base="users", columns=("name",))
    assert p.resolve("users.sel_name")[0] == "users.lookup"  # auto-derived
    # an explicit registration silently replaces the auto route...
    p.share("users.wide", {"users.sel_name": lambda r: r["name"].upper()})
    canon, proj = p.resolve("users.sel_name")
    assert canon == "users.wide"
    assert proj({"name": "u1"}) == "U1"
    # ...and conflicting EXPLICIT registrations still raise
    with pytest.raises(ValueError):
        p.share("users.other", {"users.sel_name": lambda r: r})


def test_describe_after_auto_resolution_rederives_routes():
    p = LanePolicy()
    p.describe("u.a", base="u", columns=("a",))
    p.describe("u.ab", base="u", columns=("a", "b"))
    assert p.resolve("u.a")[0] == "u.ab"
    p.describe("u.lookup", base="u")  # a fuller superset appears
    assert p.resolve("u.a")[0] == "u.lookup"
    assert p.resolve("u.ab")[0] == "u.lookup"


# ---------------------------------------------------------------------------
# result-cache TTL + invalidation hooks
# ---------------------------------------------------------------------------


def test_cache_ttl_expires_entries():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=1, result_cache_size=4,
                           result_cache_ttl=0.03)
    assert rt.fetch(rt.submit("t.lookup", (1,))) == 10
    assert rt.fetch(rt.submit("t.lookup", (1,))) == 10  # fresh: cache hit
    assert rt.stats.cache_hits == 1
    time.sleep(0.06)
    assert rt.fetch(rt.submit("t.lookup", (1,))) == 10  # expired: re-executed
    rt.shutdown()
    assert rt.stats.cache_expired == 1
    assert svc.stats.single_queries == 2


def test_invalidate_one_entry_template_and_all():
    svc = TableService({"a": {1: 1, 2: 2}, "b": {1: 3}})
    rt = AsyncQueryRuntime(svc, n_threads=1, result_cache_size=8)
    for q, k in (("a.lookup", 1), ("a.lookup", 2), ("b.lookup", 1)):
        rt.fetch(rt.submit(q, (k,)))
    assert rt.invalidate("a.lookup", (1,)) == 1
    assert rt.invalidate("a.lookup") == 1          # the remaining a entry
    assert rt.invalidate() == 1                    # drops b's entry
    assert rt.invalidate("a.lookup", (9,)) == 0    # absent key: no-op
    rt.fetch(rt.submit("b.lookup", (1,)))          # re-executed after clear
    rt.shutdown()
    assert svc.stats.single_queries == 4
    assert rt.stats.cache_hits == 0


def test_invalidate_resolves_shared_variants():
    svc = TableService({"users": USER_ROWS})
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=_shared_policy(batch=False),
                           result_cache_size=8)
    rt.fetch(rt.submit("users.sel_name", (2,)))
    # invalidating the VARIANT must drop the canonical cache entry
    assert rt.invalidate("users.sel_name", (2,)) == 1
    rt.fetch(rt.submit("users.lookup", (2,)))
    rt.shutdown()
    assert svc.stats.single_queries == 2  # no cache reuse after invalidation


# ---------------------------------------------------------------------------
# AdaptiveCost.observe edge cases
# ---------------------------------------------------------------------------


def test_observe_sees_entry_count_not_handle_count_for_deduped_batches():
    """10 coalesced submissions are ONE service call: the strategy must see
    batch_size 1 (what the service executed), not 10 (what fanned out)."""
    rec = Recording()
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=1,
                           policy=LanePolicy(hot_threshold=0,
                                             hot_factory=lambda: rec))
    handles = [rt.submit("t.lookup", (5,)) for _ in range(10)]
    rt.drain()
    assert [rt.fetch(h) for h in handles] == [50] * 10
    rt.shutdown()
    assert rt.stats.deduped == 9
    assert [size for size, _ in rec.observed] == [1]


def test_adaptive_zero_duration_observations_are_safe():
    s = AdaptiveCost(min_samples=2)
    for _ in range(3):
        s.observe(1, 0.0)           # zero-duration clock reads
    for n in (4, 8, 16):
        s.observe(n, 0.0)
    # s == 0 <= c: batching "never pays"; decide degrades to async, no crash
    assert s.threshold in (None, float("inf"))
    assert s.decide(100, False) in (1, 100)
    f, c, single = s.estimates() or (0.0, 0.0, 0.0)
    assert f >= 0.0 and c >= 0.0 and single == 0.0


def test_adaptive_reset_midstream_returns_to_exploration():
    s = AdaptiveCost(alpha=0.3)
    for _ in range(5):
        s.observe(1, 1.0)
    for n in (4, 8, 16, 32):
        s.observe(n, 3.0 + 0.1 * n)
    assert s.threshold is not None
    s.reset()
    assert s.threshold is None
    assert s._n_single == 0 and s._n_batch == 0 and s._w == 0.0
    assert s._s is None and s.decode_latency is None
    # exploration alternates again after reset
    takes = {s.decide(10, False) for _ in range(4)}
    assert takes == {1, 10}
    # and the model can re-converge on fresh evidence
    for _ in range(5):
        s.observe(1, 1.0)
    for n in (4, 8, 16, 32):
        s.observe(n, 3.0 + 0.1 * n)
    assert s.threshold == pytest.approx(3.333, abs=0.4)


def test_adaptive_decode_latency_ewma():
    s = AdaptiveCost(alpha=0.5)
    assert s.decode_latency is None
    s.observe_decode(1.0)
    assert s.decode_latency == pytest.approx(1.0)
    s.observe_decode(0.0)
    assert s.decode_latency == pytest.approx(0.5)
    # decode feedback must not disturb the submit-side cost model
    assert s._n_single == 0 and s._n_batch == 0


def test_decode_occupancy_flips_batching_decision():
    """A decode-heavy lane batches sooner: one decode tick serves the whole
    batch (continuous batching), so the decode EWMA ``d`` is amortized by
    the batch like the fixed cost F, while each individual submission pays
    its own — the threshold drops from F/(s−c) to (F+d)/(s+d−c)."""
    s = AdaptiveCost(alpha=0.3)
    for _ in range(8):
        s.observe(1, 1.0)
    for n in (4, 8, 16, 32, 6, 12):
        s.observe(n, 3.0 + 0.1 * n)
    # no decode evidence: the paper-style threshold, a backlog of 3 waits
    assert s.threshold == pytest.approx(3.333, abs=0.3)
    assert s.decide(3, False) == 1
    for _ in range(6):
        s.observe_decode(1.0)
    # d≈1: threshold (3+1)/(1+1−0.1) ≈ 2.1 — the same backlog now batches
    assert s.threshold == pytest.approx(2.1, abs=0.3)
    assert s.decide(3, False) == 3
    # decode evidence must never make a losing batch look like a win when
    # singles are already cheaper than the per-item batch cost
    cheap = AdaptiveCost(alpha=0.5)
    for _ in range(5):
        cheap.observe(1, 0.1)
    for n in (4, 8, 16, 24, 12):
        cheap.observe(n, 1.0 + 0.5 * n)
    assert cheap.threshold == float("inf")
    cheap.observe_decode(0.2)  # s+d=0.3 still <= c=0.5: batching never pays
    assert cheap.threshold == float("inf")
    assert cheap.decide(100, False) == 1


def test_abort_penalty_raises_threshold_then_decays():
    """A wasted speculative prefill (observe_abort) enters the threshold
    like extra fixed cost — (F+d+ab)/(s+d−c) — so a chronically-missing
    lane demands a deeper backlog; landed batches decay the penalty."""
    s = AdaptiveCost(alpha=0.5, min_samples=3)
    for _ in range(3):
        s.observe(1, 1.0)                    # s = 1.0
    for n in (2, 4, 8):
        s.observe(n, 0.5 + 0.1 * n)          # exact line: F=0.5, c=0.1
    assert s.abort_penalty == 0.0
    base = s.threshold
    assert base == pytest.approx(0.5 / 0.9, abs=0.05)
    s.observe_abort(0.9)
    assert s.aborts == 1
    assert s.abort_penalty == pytest.approx(0.9)
    assert s.threshold == pytest.approx((0.5 + 0.9) / 0.9, abs=0.05)
    assert s.threshold > base
    # a batch that lands again decays the penalty back toward zero
    p0 = s.abort_penalty
    s.observe(4, 0.9)
    assert 0.0 < s.abort_penalty < p0
    # singles never decay it (no batch landed)
    p1 = s.abort_penalty
    s.observe(1, 1.0)
    assert s.abort_penalty == pytest.approx(p1)
    s.reset()
    assert s.abort_penalty == 0.0 and s.aborts == 0


def test_policy_routes_observe_abort_to_lane_strategy():
    rec_a, rec_b = Recording(), Recording()
    policy = LanePolicy(overrides={"a": rec_a, "b": rec_b})
    policy.observe_abort("a", 0.25)
    assert rec_a.aborted == [0.25]
    assert rec_b.aborted == []


def test_abort_penalty_attributes_per_bet_depth():
    """A depth-d abort charges d times the wasted dispatch: deep-pipeline
    misses raise the learned threshold proportionally faster, and the
    observed depth EWMA is exposed for spec_depth tuning."""
    shallow = AdaptiveCost(alpha=0.5)
    deep = AdaptiveCost(alpha=0.5)
    assert shallow.abort_depth is None
    shallow.observe_abort(0.4)             # depth defaults to 1
    deep.observe_abort(0.4, depth=4)
    assert shallow.abort_penalty == pytest.approx(0.4)
    assert deep.abort_penalty == pytest.approx(1.6)  # 0.4 * depth 4
    assert shallow.abort_depth == pytest.approx(1.0)
    assert deep.abort_depth == pytest.approx(4.0)
    deep.observe_abort(0.4, depth=2)
    assert deep.abort_depth == pytest.approx(3.0)  # EWMA(4, 2), alpha .5
    deep.reset()
    assert deep.abort_depth is None and deep.abort_penalty == 0.0


def test_spill_budget_knob_and_per_lane_overrides():
    """The serving-side host-KV spill budget: per-lane overrides beat the
    policy-wide default, shaped for HostSpillPool(budget_for=...)."""
    policy = LanePolicy(spill_budget=4, spill_budgets={"chat": 8, "bulk": 0})
    assert policy.spill_budget_for("chat") == 8
    assert policy.spill_budget_for("bulk") == 0     # fenced out of the pool
    assert policy.spill_budget_for("embed") == 4    # policy-wide default
    assert policy.spill_budget_for(None) == 4
    assert LanePolicy().spill_budget_for("x") is None  # unbounded default
    with pytest.raises(ValueError):
        LanePolicy(spill_budget=-1)
    with pytest.raises(ValueError):
        LanePolicy(spill_budgets={"a": -2})

    from repro.serving.engine import HostSpillPool

    pool = HostSpillPool(max_entries=8, budget_for=policy.spill_budget_for)
    pool.put(1, "bulk", {"kv": 1})       # budget 0: dropped on arrival
    assert 1 not in pool
    pool.put(2, "embed", {"kv": 2})
    assert 2 in pool


def test_resolve_submit_folds_note_into_one_call():
    """resolve_submit = resolve + note_submit on the canonical lane: shared
    variants warm the canonical's temperature, not their own."""
    policy = LanePolicy(hot_threshold=2)
    policy.share("users.lookup", {"users.sel_name": lambda r: r["name"]})
    lane, proj = policy.resolve_submit("users.sel_name")
    assert lane == "users.lookup" and proj is not None
    lane, proj = policy.resolve_submit("plain")
    assert lane == "plain" and proj is None
    snap = policy.snapshot()["lanes"]
    assert snap["users.lookup"]["submits"] == 1   # noted on the canonical
    assert "users.sel_name" not in snap
    assert snap["plain"]["submits"] == 1
    policy.resolve_submit("users.sel_name")
    assert policy.is_hot("users.lookup")          # 2 submits >= hot_threshold


# ---------------------------------------------------------------------------
# scheduler integration: per-lane feedback + stuck-lane diagnostics
# ---------------------------------------------------------------------------


class StubEngine:
    """Minimal engine contract for scheduler tests: no model, no JAX."""

    def __init__(self, n_lanes=2, emit=True):
        self.free_lanes = list(range(n_lanes))
        self.active: dict = {}
        self.emit = emit

    @property
    def n_free(self):
        return len(self.free_lanes)

    def admit(self, requests, template=None):
        for r in requests:
            r.lane = self.free_lanes.pop(0)
            r.generated.append(0)  # prefill emits token 0
            self.active[r.lane] = r
        return (len(requests), 8)

    def decode_tick(self):
        if not self.emit:
            return {}
        return {lane: 1 for lane in self.active}

    def retire(self, lane):
        self.active.pop(lane, None)
        self.free_lanes.append(lane)


def _mk_requests(n, template, max_new=2):
    import numpy as np

    from repro.serving.request import Request

    return [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=max_new, template=template)
            for i in range(n)]


def test_scheduler_routes_feedback_to_each_lanes_strategy():
    from repro.serving.scheduler import ContinuousBatchingScheduler

    rec_chat, rec_embed = Recording(), Recording()
    policy = LanePolicy(overrides={"chat": rec_chat, "embed": rec_embed})
    sched = ContinuousBatchingScheduler(StubEngine(n_lanes=2), policy=policy)
    for r in _mk_requests(4, "chat") + _mk_requests(4, "embed"):
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained(max_ticks=50)
    assert len(done) == 8
    # every admission was homogeneous and each lane saw only its own admits:
    # the global warm-shape set skips the very first admit of shape (2, 8),
    # which was chat's, so chat logs one steady-state admit and embed two.
    assert [s for s, _ in rec_chat.observed] == [2]
    assert [s for s, _ in rec_embed.observed] == [2, 2]
    # decode-tick durations flowed to the lanes that were running
    assert rec_chat.decode_observed and rec_embed.decode_observed


def test_scheduler_admission_follows_weighted_fairness():
    from repro.serving.scheduler import ContinuousBatchingScheduler

    policy = LanePolicy(cold_factory=PureAsync, hot_threshold=10**9,
                        lane_weights={"heavy": 3.0, "light": 1.0})
    sched = ContinuousBatchingScheduler(StubEngine(n_lanes=1), policy=policy)
    for r in _mk_requests(12, "heavy") + _mk_requests(12, "light"):
        sched.submit(r)
    sched.producer_done()
    for _ in range(16):  # partial drain: observe the admission mix under load
        sched.tick()
    heavy = sum(n for _, n in sched.stats.lane_admissions.get("heavy", []))
    light = sum(n for _, n in sched.stats.lane_admissions.get("light", []))
    assert heavy == 3 * light  # 3:1 service ratio from the vtime weights


def test_run_until_drained_names_stuck_lanes():
    from repro.serving.scheduler import ContinuousBatchingScheduler

    sched = ContinuousBatchingScheduler(StubEngine(n_lanes=1, emit=False))
    for r in _mk_requests(2, "chat"):
        sched.submit(r)
    sched.producer_done()
    with pytest.raises(RuntimeError) as exc:
        sched.run_until_drained(max_ticks=5)
    msg = str(exc.value)
    assert "max_ticks=5" in msg
    assert "chat" in msg  # both the queued template and the running lane


def test_run_until_drained_without_work_still_returns():
    from repro.serving.scheduler import ContinuousBatchingScheduler

    sched = ContinuousBatchingScheduler(StubEngine(n_lanes=1))
    # producer never signals done, but nothing is pending either: ticking out
    # the budget is idle waiting, not a stuck lane — no error.
    assert sched.run_until_drained(max_ticks=3) == []
