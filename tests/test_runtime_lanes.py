"""Sharded multi-lane runtime: per-template batching vs single-queue
head-of-line blocking, in-flight request deduplication, the completed-result
LRU cache, and the AdaptiveCost strategy's learned threshold."""
from __future__ import annotations

import threading

import pytest

from repro.core.runtime import AsyncQueryRuntime
from repro.core.services import TableService
from repro.core.strategies import AdaptiveCost, PureBatch, from_name

N_TEMPLATES = 4
TABLES = {f"t{i}": {k: k * (i + 1) for k in range(1000)} for i in range(N_TEMPLATES)}


def _interleaved(rt, n_per_template: int):
    """Submit A,B,C,D,A,B,... — the single queue's worst case."""
    handles = []
    for k in range(n_per_template):
        for i in range(N_TEMPLATES):
            handles.append((rt.submit(f"t{i}.lookup", (k,)), k * (i + 1)))
    return handles


# ---------------------------------------------------------------------------
# lanes vs single queue
# ---------------------------------------------------------------------------


def test_sharded_lanes_batch_per_template():
    """PureBatch + sharded: the whole backlog drains as ONE set-oriented
    execution per template, despite strict interleaving."""
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=4, strategy=PureBatch(), sharded=True)
    handles = _interleaved(rt, 50)
    rt.drain()
    for h, want in handles:
        assert rt.fetch(h) == want
    rt.shutdown()
    assert svc.stats.batches == N_TEMPLATES
    assert svc.stats.single_queries == 0
    assert svc.stats.batched_items == 50 * N_TEMPLATES
    # one homogeneous lane per template, each recording one batch of 50
    assert sorted(rt.stats.lane_traces) == sorted(f"t{i}.lookup"
                                                  for i in range(N_TEMPLATES))
    for trace in rt.stats.lane_traces.values():
        assert [sz for _, sz in trace] == [50]


def test_single_queue_head_of_line_blocks():
    """The paper's single queue on the same workload: every batch splits at
    the first template boundary, degenerating to size 1."""
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=4, strategy=PureBatch(), sharded=False)
    handles = _interleaved(rt, 25)
    rt.drain()
    for h, want in handles:
        assert rt.fetch(h) == want
    rt.shutdown()
    assert svc.stats.batches == 0
    assert svc.stats.single_queries == 25 * N_TEMPLATES
    assert list(rt.stats.lane_traces) == ["__single__"]


def test_sharded_mean_batch_size_dominates_single_queue():
    """The bench_lanes acceptance bar, asserted deterministically: sharded
    mean batch size >= 2x the single queue's on mixed-template traffic."""
    stats = {}
    for sharded in (True, False):
        svc = TableService(TABLES)
        rt = AsyncQueryRuntime(svc, n_threads=4, strategy=PureBatch(),
                               sharded=sharded)
        handles = _interleaved(rt, 40)
        rt.drain()
        for h, want in handles:
            assert rt.fetch(h) == want
        rt.shutdown()
        stats[sharded] = rt.stats.mean_batch_size
    assert stats[True] >= 2 * stats[False]
    assert stats[False] == 1.0


# ---------------------------------------------------------------------------
# request deduplication + result cache
# ---------------------------------------------------------------------------


def test_queued_duplicates_coalesce_to_one_call():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=4, strategy=PureBatch())
    handles = [rt.submit("t0.lookup", (7,)) for _ in range(10)]
    rt.drain()
    assert [rt.fetch(h) for h in handles] == [7] * 10
    rt.shutdown()
    # exactly ONE service execution for the 10 identical submissions
    assert svc.stats.single_queries + svc.stats.batched_items == 1
    assert rt.stats.deduped == 9
    assert rt.stats.completed == rt.stats.submitted == 10


class _GatedService(TableService):
    """execute() blocks until released; lets the test pin a call in flight."""

    def __init__(self):
        super().__init__(TABLES)
        self.started = threading.Event()
        self.release = threading.Event()

    def execute(self, query_name, params):
        self.started.set()
        assert self.release.wait(timeout=5.0)
        return super().execute(query_name, params)


def test_inflight_duplicates_coalesce_to_one_call():
    """Submissions arriving WHILE the identical request is executing attach
    to the in-flight call and share its result (SharedDB-style)."""
    svc = _GatedService()
    rt = AsyncQueryRuntime(svc, n_threads=2)
    h0 = rt.submit("t0.lookup", (3,))
    assert svc.started.wait(timeout=5.0)  # first call now in flight
    dupes = [rt.submit("t0.lookup", (3,)) for _ in range(5)]
    svc.release.set()
    assert rt.fetch(h0) == 3
    assert [rt.fetch(h) for h in dupes] == [3] * 5
    rt.drain()
    rt.shutdown()
    assert svc.stats.single_queries == 1
    assert rt.stats.deduped == 5


def test_bounded_queue_counts_deduped_outstanding():
    """max_pending bounds OUTSTANDING requests, so coalesced duplicates
    (which enqueue nothing) still trigger producer back-off."""
    svc = _GatedService()
    rt = AsyncQueryRuntime(svc, n_threads=1, max_pending=2)
    rt.submit("t0.lookup", (3,))
    assert svc.started.wait(timeout=5.0)   # outstanding=1, in flight
    rt.submit("t0.lookup", (3,))           # coalesces; outstanding=2 = bound
    entered = threading.Event()
    passed = threading.Event()

    def third():
        entered.set()
        rt.submit("t0.lookup", (3,))
        passed.set()

    t = threading.Thread(target=third, daemon=True)
    t.start()
    assert entered.wait(timeout=5.0)
    assert not passed.wait(timeout=0.3)    # blocked at the bound
    svc.release.set()                      # first call completes → unblocks
    assert passed.wait(timeout=5.0)
    rt.drain()
    rt.shutdown()


def test_empty_lanes_are_garbage_collected():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=2, strategy=PureBatch())
    handles = _interleaved(rt, 5)
    rt.drain()
    for h, want in handles:
        assert rt.fetch(h) == want
    rt.shutdown()
    assert rt._lanes == {}  # drained lanes dropped from the scan set
    # ...but their traces survive for analysis
    assert set(rt.stats.lane_traces) == {f"t{i}.lookup" for i in range(N_TEMPLATES)}


def test_dedup_disabled_executes_each():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=1, strategy=PureBatch(), dedup=False)
    handles = [rt.submit("t0.lookup", (7,)) for _ in range(6)]
    rt.drain()
    assert [rt.fetch(h) for h in handles] == [7] * 6
    rt.shutdown()
    assert rt.stats.deduped == 0
    assert svc.stats.batched_items == 6  # one batch, but all 6 executed


def test_result_cache_lru():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=1, result_cache_size=2)
    assert rt.fetch(rt.submit("t0.lookup", (1,))) == 1
    assert rt.fetch(rt.submit("t0.lookup", (1,))) == 1  # cache hit
    assert rt.fetch(rt.submit("t0.lookup", (2,))) == 2
    assert rt.fetch(rt.submit("t0.lookup", (3,))) == 3  # evicts (1,)
    assert rt.fetch(rt.submit("t0.lookup", (1,))) == 1  # miss again
    rt.shutdown()
    assert rt.stats.cache_hits == 1
    assert svc.stats.single_queries == 4


# ---------------------------------------------------------------------------
# adaptive cost strategy
# ---------------------------------------------------------------------------


def test_adaptive_converges_on_synthetic_cost_model():
    """Feed the textbook model s=1, T_batch(n)=3+0.1n: the learned threshold
    must converge to F/(s-c) = 3/(0.9) ~ 3.33 and gate decide() there."""
    s = AdaptiveCost(alpha=0.3)
    assert s.threshold is None  # still exploring
    for _ in range(8):
        s.observe(1, 1.0)
    for n in (4, 8, 16, 32, 6, 12):
        s.observe(n, 3.0 + 0.1 * n)
    assert s.threshold == pytest.approx(3.333, abs=0.3)
    f, c, single = s.estimates()
    assert f == pytest.approx(3.0, abs=0.3)
    assert c == pytest.approx(0.1, abs=0.05)
    assert single == pytest.approx(1.0, abs=0.05)
    assert s.decide(3, False) == 1   # below threshold: individual
    assert s.decide(5, False) == 5   # above: take all
    assert s.decide(0, False) == 0


def test_adaptive_degrades_to_async_when_batching_never_pays():
    s = AdaptiveCost(alpha=0.5)
    for _ in range(5):
        s.observe(1, 0.1)            # singles are cheap
    for n in (4, 8, 16, 24, 12):
        s.observe(n, 1.0 + 0.5 * n)  # per-item batch cost >> single cost
    assert s.threshold == float("inf")
    assert s.decide(100, False) == 1


def test_adaptive_explores_before_estimating():
    s = AdaptiveCost(min_samples=2)
    assert s.decide(1, False) == 1
    # with >1 pending it alternates take-all / take-one to feed both sides
    takes = {s.decide(10, False) for _ in range(4)}
    assert takes == {1, 10}
    s.reset()
    assert s.threshold is None


def test_adaptive_end_to_end_in_runtime():
    """AdaptiveCost inside the runtime: completes a mixed workload correctly
    and ends up with a usable cost model from real observations."""
    svc = TableService(TABLES, latency=0.001,
                       batch_latency=lambda n: 0.004 + 0.0001 * n)
    rt = AsyncQueryRuntime(svc, n_threads=2, strategy=AdaptiveCost(alpha=0.3))
    handles = _interleaved(rt, 30)
    rt.drain()
    for h, want in handles:
        assert rt.fetch(h) == want
    rt.shutdown()
    assert rt.stats.completed == 30 * N_TEMPLATES
    # exploration guarantees both execution kinds were observed
    assert rt.stats.single_executions >= 1
    assert rt.stats.batch_executions >= 1


def test_from_name_adaptive():
    assert isinstance(from_name("adaptive"), AdaptiveCost)


def test_adaptive_ignores_failed_calls():
    """Fast-failing service calls must not feed the cost model (they would
    drag the learned latencies toward zero)."""
    strat = AdaptiveCost()
    svc = TableService(TABLES, queries={"boom": lambda tables, p: 1 / 0})
    rt = AsyncQueryRuntime(svc, n_threads=1, strategy=strat)
    h = rt.submit("boom", ())
    with pytest.raises(ZeroDivisionError):
        rt.fetch(h)
    rt.shutdown()
    assert strat._n_single == 0 and strat._n_batch == 0


# ---------------------------------------------------------------------------
# stats plumbing
# ---------------------------------------------------------------------------


def test_snapshot_includes_lane_traces_and_mean():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=2, strategy=PureBatch())
    handles = _interleaved(rt, 10)
    rt.drain()
    for h, _ in handles:
        rt.fetch(h)
    rt.shutdown()
    snap = rt.stats.snapshot()
    assert snap["mean_batch_size"] == rt.stats.mean_batch_size > 1
    assert set(snap["lane_traces"]) == {f"t{i}.lookup" for i in range(N_TEMPLATES)}
