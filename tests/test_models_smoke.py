"""Per-architecture smoke tests (brief requirement): every assigned arch in
a REDUCED same-family config runs one forward + one train step on CPU with
shape checks and no NaNs; plus prefill/decode consistency per family."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import ARCH_IDS, get_arch
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainStepConfig, make_train_step

B, S = 2, 16


def _reduced(name):
    arch = get_arch(name)
    return dataclasses.replace(arch, cfg=arch.cfg.reduced())


def _batch(cfg, key):
    if cfg.is_encoder_decoder:
        return {
            "src_embeds": jax.random.normal(key, (B, S, cfg.d_model)),
            "tgt_tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    if cfg.frontend != "none":
        b = {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model)),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
        if cfg.rope == "mrope":
            b["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (3, B, S)
            ).copy()
        return b
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_finiteness(name):
    arch = _reduced(name)
    cfg = arch.cfg
    key = jax.random.PRNGKey(0)
    params = arch.init(key)
    logits, aux = arch.forward(params, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_one_train_step(name):
    arch = _reduced(name)
    cfg = arch.cfg
    key = jax.random.PRNGKey(1)
    params = arch.init(key)
    init_state, train_step = make_train_step(
        arch, AdamWConfig(lr=1e-3), TrainStepConfig(donate=False)
    )
    state = init_state(params)
    batch = _batch(cfg, key)
    new_params, new_state, metrics = train_step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["opt"]["step"]) == 1
    # params actually changed
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize("name", ["chatglm3-6b", "llama3-8b", "qwen1.5-4b",
                                  "olmo-1b", "mamba2-1.3b", "hymba-1.5b"])
def test_prefill_decode_matches_forward(name):
    arch = _reduced(name)
    cfg = arch.cfg
    k = jax.random.PRNGKey(0)
    params = arch.init(k)
    toks = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    full, _ = arch.forward(params, {"tokens": toks, "labels": toks})
    last, cache = arch.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 8)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    lg, _ = arch.decode_step(params, toks[:, S], cache,
                             jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["deepseek-moe-16b", "kimi-k2-1t-a32b"])
def test_moe_prefill_decode_dropless(name):
    """With a dropless capacity factor MoE decode matches forward exactly;
    with the training capacity factor they may differ (documented)."""
    arch = _reduced(name)
    cfg = dataclasses.replace(arch.cfg, capacity_factor=8.0)
    arch = dataclasses.replace(arch, cfg=cfg)
    k = jax.random.PRNGKey(0)
    params = arch.init(k)
    toks = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    full, _ = arch.forward(params, {"tokens": toks, "labels": toks})
    last, cache = arch.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 8)
    lg, _ = arch.decode_step(params, toks[:, S], cache,
                             jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_encdec_prefill_decode():
    arch = _reduced("seamless-m4t-medium")
    cfg = arch.cfg
    k = jax.random.PRNGKey(0)
    params = arch.init(k)
    src = jax.random.normal(k, (B, S, cfg.d_model))
    tgt = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    full, _ = arch.forward(params, {"src_embeds": src, "tgt_tokens": tgt})
    last, cache = arch.prefill(
        params, {"src_embeds": src, "tgt_tokens": tgt[:, :S]}, max_len=S + 4)
    lg, _ = arch.decode_step(params, tgt[:, S], cache,
                             jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_input_specs_cover_all_cells():
    """Every (arch × shape) cell has well-formed ShapeDtypeStruct specs."""
    n_cells = 0
    for name in ARCH_IDS:
        arch = get_arch(name)
        for shape in arch.shapes():
            specs = arch.input_specs(shape)
            for leaf in jax.tree_util.tree_leaves(specs):
                assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
            n_cells += 1
    # 10 archs × 3 shapes + 2 long-context archs × 1 = 32 runnable cells
    assert n_cells == 32


def test_param_counts_match_published_scale():
    """Analytic param counts are in the right ballpark for each model name."""
    expect = {
        "chatglm3-6b": (5e9, 8e9),
        "olmo-1b": (0.9e9, 1.5e9),
        "llama3-8b": (7e9, 9e9),
        "qwen1.5-4b": (3e9, 5e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "hymba-1.5b": (1.1e9, 2.2e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "seamless-m4t-medium": (0.7e9, 1.8e9),
        "deepseek-moe-16b": (14e9, 18e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).cfg.param_count()
        assert lo <= n <= hi, f"{name}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params():
    cfg = get_arch("kimi-k2-1t-a32b").cfg
    a = cfg.active_param_count()
    assert 2.5e10 <= a <= 4.5e10, f"kimi active {a:.3e} (should be ≈32B)"
