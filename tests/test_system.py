"""End-to-end behaviour of the paper's system: a program written against a
blocking query API is mechanically transformed and served by the async
runtime with adaptive batching — against a *JAX model* as the backing
service (the ML instantiation), with observable semantics preserved."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hir import (
    Assign,
    Interpreter,
    Loop,
    Program,
    Query,
    transform_program,
)
from repro.core.runtime import AsyncQueryRuntime
from repro.core.services import ModelService, SimulatedDBService
from repro.core.strategies import GrowingUpperThreshold, LowerThreshold


def test_model_service_end_to_end():
    """The 'database' is a jitted scoring model; the transformed program
    batches N per-item forwards into few vmapped dispatches."""
    W = jax.random.normal(jax.random.PRNGKey(0), (16, 16))

    def score(x):
        return jnp.tanh(x @ W).sum()

    svc = ModelService(score)
    items = [jax.random.normal(jax.random.PRNGKey(i), (16,)) for i in range(40)]

    prog = Program(
        inputs=("items", "total"),
        body=[
            Loop(item_var="x", iter_var="items", body=[
                Query(target="s", query_name="score", params=("x",)),
                Assign(target="total", fn=lambda t, s: t + float(s), args=("total", "s")),
            ]),
        ],
    )
    base = Interpreter(ModelService(score)).run(prog, {"items": items, "total": 0.0})

    t = transform_program(prog, overlap=True)
    rt = AsyncQueryRuntime(svc, n_threads=2, strategy=LowerThreshold(bt=3))
    out = Interpreter(rt).run(t, {"items": items, "total": 0.0})
    rt.drain()
    rt.shutdown()
    np.testing.assert_allclose(out["total"], base["total"], rtol=1e-5)
    # batching actually kicked in: far fewer device dispatches than items
    assert svc.stats.batches >= 1
    assert svc.stats.single_queries + svc.stats.batched_items == 40


def test_async_faster_than_sync_on_latency_bound_service():
    """The paper's headline effect: with round-trip-dominated queries the
    transformed program is significantly faster end-to-end."""
    def mk():
        return SimulatedDBService(rtt=4e-3, single_proc=1e-3, batch_proc=5e-5,
                                  batch_fixed=5e-4, concurrency=8)

    prog = Program(
        inputs=("keys", "acc"),
        body=[
            Loop(item_var="k", iter_var="keys", body=[
                Query(target="r", query_name="q", params=("k",)),
                Assign(target="acc", fn=lambda a, r: a + 1, args=("acc", "r")),
            ]),
        ],
    )
    inputs = {"keys": list(range(60)), "acc": 0}

    t0 = time.perf_counter()
    base = Interpreter(mk()).run(prog, dict(inputs))
    t_sync = time.perf_counter() - t0

    tp = transform_program(prog, overlap=True)
    rt = AsyncQueryRuntime(mk(), n_threads=10,
                           strategy=GrowingUpperThreshold(initial_upper=8, bt=3))
    t0 = time.perf_counter()
    out = Interpreter(rt).run(tp, dict(inputs))
    rt.drain()
    t_async = time.perf_counter() - t0
    rt.shutdown()

    assert out["acc"] == base["acc"] == 60
    assert t_async < t_sync / 2, (t_sync, t_async)


def test_model_service_lane_keyed_padding_buckets():
    """pad_batches=True: each lane (query template) converges on ONE
    power-of-two batch shape, so jit recompiles stop after the lane's
    largest batch — the device analogue of a prepared statement."""
    W = jax.random.normal(jax.random.PRNGKey(1), (8, 8))

    def score(x):
        return jnp.tanh(x @ W).sum()

    svc = ModelService(score, pad_batches=True)
    items = [jax.random.normal(jax.random.PRNGKey(i), (8,)) for i in range(16)]

    out3 = svc.execute_batch("score", [(x,) for x in items[:3]])
    assert len(out3) == 3
    assert svc.lane_buckets["score"] == 4          # 3 -> bucket 4
    out2 = svc.execute_batch("score", [(x,) for x in items[3:5]])
    assert len(out2) == 2                          # padded to 4, sliced to 2
    assert svc.lane_buckets["score"] == 4
    svc.execute_batch("score", [(x,) for x in items[:6]])
    assert svc.lane_buckets["score"] == 8          # grows monotonically
    # a different lane gets its own bucket
    svc.execute_batch("embed", [(x,) for x in items[:2]])
    assert svc.lane_buckets["embed"] == 2
    assert svc.stats.padded_items == 1 + 2 + 2 + 0
    # padded results equal unpadded execution
    ref = ModelService(score).execute_batch("score", [(x,) for x in items[3:5]])
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), rtol=1e-6)
