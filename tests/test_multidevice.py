"""Multi-device proofs, each in a subprocess with 8 forced host devices:

 * a REDUCED llama-family model actually RUNS a sharded train step on a
   (data=4, model=2) mesh (not just compiles) and matches the single-device
   loss;
 * the production-mesh dry-run machinery lowers + compiles on a small mesh
   inside the test suite (the full 512-device sweep is the dryrun script).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).parents[1]


def run_sub(script: str, timeout=420) -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-3000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


SHARDED_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.models.registry import get_arch
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainStepConfig, make_train_step
from repro.distributed.sharding import param_shardings, mesh_context, logical_to_spec
from jax.sharding import NamedSharding, PartitionSpec as P

arch = get_arch("llama3-8b")
arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
key = jax.random.PRNGKey(0)
toks = jax.random.randint(key, (8, 16), 0, arch.cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}

# single-device reference
init_state, step = make_train_step(arch, AdamWConfig(lr=1e-3), TrainStepConfig(donate=False))
params = arch.init(key)
state = init_state(params)
_, _, m_ref = step(params, state, batch)

# sharded execution on a 4x2 mesh
mesh = jax.make_mesh((4, 2), ("data", "model"))
p_sh = param_shardings(mesh, jax.eval_shape(lambda: arch.init(key)))
params_s = jax.device_put(params, p_sh)
state_s = init_state(params_s)
b_sh = NamedSharding(mesh, P("data", None))
batch_s = {k: jax.device_put(v, b_sh) for k, v in batch.items()}
with mesh_context(mesh):
    init2, step2 = make_train_step(arch, AdamWConfig(lr=1e-3), TrainStepConfig(donate=False), mesh=mesh)
    step2 = jax.jit(step2)
    new_p, new_s, m = step2(params_s, state_s, batch_s)
    jax.block_until_ready(new_p)

wq = new_p["layers"]["attn"]["wq"]
print(json.dumps({
    "loss_ref": float(m_ref["loss"]), "loss_sharded": float(m["loss"]),
    "n_devices": jax.device_count(),
    "wq_nshards": len(wq.addressable_shards),
}))
"""


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches():
    res = run_sub(SHARDED_TRAIN)
    assert res["n_devices"] == 8
    assert res["wq_nshards"] == 8
    assert abs(res["loss_ref"] - res["loss_sharded"]) < 1e-3


SMALL_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax, jax.numpy as jnp
from repro.models.registry import get_arch
from repro.models.config import ShapeSpec
from repro.distributed.sharding import param_shardings, mesh_context
from repro.launch.dryrun import parse_collective_bytes, _input_shardings
from repro.launch.hlo_cost import cost_analysis_dict

arch = get_arch("deepseek-moe-16b")
arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeSpec("mini_train", 32, 8, "train")
specs = arch.input_specs(shape)
params_sds = jax.eval_shape(lambda: arch.init(jax.random.PRNGKey(0)))
p_sh = param_shardings(mesh, params_sds)
in_sh = _input_shardings(mesh, specs)

def fwd(params, batch):
    logits, aux = arch.forward(params, batch)
    return logits.mean() + aux

with mesh_context(mesh):
    lowered = jax.jit(fwd, in_shardings=(p_sh, in_sh)).lower(params_sds, specs)
    compiled = lowered.compile()
coll = parse_collective_bytes(compiled.as_text())
cost = cost_analysis_dict(compiled)  # list vs dict varies by JAX version
print(json.dumps({
    "collective_count": coll["total_count"],
    "collective_bytes": coll["total_bytes"],
    "flops": float(cost.get("flops", 0)),
}))
"""


@pytest.mark.slow
def test_small_mesh_moe_compiles_with_collectives():
    res = run_sub(SMALL_DRYRUN)
    # a TP+EP-sharded MoE forward must contain real collectives
    assert res["collective_count"] >= 1
    assert res["collective_bytes"] > 0
    assert res["flops"] > 0
