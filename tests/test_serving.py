"""Continuous batching scheduler: correctness vs sequential generation,
strategy behaviour, straggler re-queue, data pipeline determinism."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import (
    GrowingUpperThreshold,
    LowerThreshold,
    OneOrAll,
    PureAsync,
)
from repro.data.pipeline import PrefetchLoader, SyntheticLMStream
from repro.models.registry import get_arch
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler


@pytest.fixture(scope="module")
def setup():
    arch = get_arch("llama3-8b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


def _requests(n, rng, max_new=6):
    return [
        Request(rid=i, prompt=rng.integers(1, 200, size=rng.integers(3, 14)).astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def _sequential_reference(arch, params, req, max_len=48):
    toks = jnp.asarray(req.prompt)[None]
    last, cache = arch.prefill(params, {"tokens": toks}, max_len=max_len)
    out = [int(jnp.argmax(last, -1)[0])]
    lengths = jnp.asarray([len(req.prompt)], jnp.int32)
    cur = jnp.asarray(out, jnp.int32)
    for _ in range(req.max_new_tokens - 1):
        lg, cache = arch.decode_step(params, cur, cache, lengths)
        nxt = int(jnp.argmax(lg, -1)[0])
        out.append(nxt)
        cur = jnp.asarray([nxt], jnp.int32)
        lengths = lengths + 1
    return out


@pytest.mark.parametrize("strategy", [
    PureAsync(), OneOrAll(), LowerThreshold(bt=3),
    GrowingUpperThreshold(initial_upper=2, bt=None),
])
def test_scheduler_matches_sequential(setup, strategy):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=strategy)
    rng = np.random.default_rng(42)
    reqs = _requests(9, rng)
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 9
    for r in reqs[:3]:  # spot-check 3 against the sequential oracle
        ref = _sequential_reference(arch, params, r)
        assert r.generated[: len(ref)] == ref, (r.rid, r.generated, ref)


def test_admission_trace_recorded(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(1)
    for r in _requests(8, rng):
        sched.submit(r)
    sched.producer_done()
    sched.run_until_drained()
    assert sum(n for _, n in sched.stats.admission_trace) == 8
    # OneOrAll with an empty engine admits everything at once
    assert sched.stats.admission_trace[0][1] == 8


def test_straggler_requeue(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=64)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), lane_timeout=3)
    rng = np.random.default_rng(2)
    reqs = _requests(2, rng, max_new=10)  # 10 tokens > timeout 3 → requeue
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    # run a bounded number of ticks; requests keep being requeued
    for _ in range(30):
        sched.tick()
    assert sched.stats.requeued >= 1


def test_lanes_respected(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(3)
    for r in _requests(7, rng):
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 7
    assert max(n for _, n in sched.stats.admission_trace) <= 2


def test_mixed_template_lane_admissions(setup):
    """Requests with different templates are admitted from per-template
    lanes: every admission batch is homogeneous and each lane's trace is
    recorded separately."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(5)
    reqs = []
    for i in range(8):
        tmpl = "chat" if i % 2 == 0 else "summarize"
        size = 4 if tmpl == "chat" else 13
        reqs.append(Request(rid=i, prompt=rng.integers(1, 200, size=size).astype(np.int32),
                            max_new_tokens=4, template=tmpl))
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 8
    assert set(sched.stats.lane_admissions) == {"chat", "summarize"}
    # each lane admitted its 4 requests; totals agree with the global trace
    for tmpl, trace in sched.stats.lane_admissions.items():
        assert sum(n for _, n in trace) == 4
    assert sum(n for _, n in sched.stats.admission_trace) == 8
    assert sched.queues == {}  # drained lanes are garbage-collected


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_stream_deterministic():
    s1 = SyntheticLMStream(1000, 32, 4, seed=9)
    s2 = SyntheticLMStream(1000, 32, 4, seed=9)
    np.testing.assert_array_equal(s1.batch_at(17)["tokens"], s2.batch_at(17)["tokens"])
    assert not np.array_equal(s1.batch_at(17)["tokens"], s1.batch_at(18)["tokens"])


def test_prefetch_loader_order_and_bound():
    stream = SyntheticLMStream(100, 8, 2, seed=1)
    loader = PrefetchLoader(stream, n_prefetch=3, max_steps=10)
    batches = list(loader)
    assert len(batches) == 10
    np.testing.assert_array_equal(batches[4]["tokens"], stream.batch_at(4)["tokens"])


def test_engine_pins_one_prefill_shape_per_template(setup):
    """template= admission pins the padding bucket: after a template's
    largest batch, every later admit dispatches the SAME compiled shape."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    rng = np.random.default_rng(7)
    reqs = _requests(6, rng, max_new=2)
    shape_a = eng.admit(reqs[:3], template="chat")     # bucket (4, plen)
    assert shape_a[0] == 4
    for r in reqs[:3]:
        eng.retire(r.lane)
    shape_b = eng.admit(reqs[3:4], template="chat")    # 1 request, pinned shape
    assert shape_b == shape_a
    assert eng.template_shapes["chat"] == shape_a
    for r in reqs[3:4]:
        eng.retire(r.lane)
    # an unrelated template sizes its own bucket from scratch
    shape_c = eng.admit(reqs[4:5], template="embed")
    assert shape_c[0] == 1
