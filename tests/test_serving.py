"""Continuous batching scheduler: correctness vs sequential generation,
strategy behaviour, straggler re-queue, data pipeline determinism."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import (
    GrowingUpperThreshold,
    LowerThreshold,
    OneOrAll,
    PureAsync,
)
from repro.core.strategies import BatchingStrategy
from repro.data.pipeline import PrefetchLoader, SyntheticLMStream
from repro.models.registry import get_arch
from repro.serving.engine import InferenceEngine, KVPartition, proportional_shares
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler


@pytest.fixture(scope="module")
def setup():
    arch = get_arch("llama3-8b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


def _requests(n, rng, max_new=6):
    return [
        Request(rid=i, prompt=rng.integers(1, 200, size=rng.integers(3, 14)).astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def _sequential_reference(arch, params, req, max_len=48):
    toks = jnp.asarray(req.prompt)[None]
    last, cache = arch.prefill(params, {"tokens": toks}, max_len=max_len)
    out = [int(jnp.argmax(last, -1)[0])]
    lengths = jnp.asarray([len(req.prompt)], jnp.int32)
    cur = jnp.asarray(out, jnp.int32)
    for _ in range(req.max_new_tokens - 1):
        lg, cache = arch.decode_step(params, cur, cache, lengths)
        nxt = int(jnp.argmax(lg, -1)[0])
        out.append(nxt)
        cur = jnp.asarray([nxt], jnp.int32)
        lengths = lengths + 1
    return out


@pytest.mark.parametrize("strategy", [
    PureAsync(), OneOrAll(), LowerThreshold(bt=3),
    GrowingUpperThreshold(initial_upper=2, bt=None),
])
def test_scheduler_matches_sequential(setup, strategy):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=strategy)
    rng = np.random.default_rng(42)
    reqs = _requests(9, rng)
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 9
    for r in reqs[:3]:  # spot-check 3 against the sequential oracle
        ref = _sequential_reference(arch, params, r)
        assert r.generated[: len(ref)] == ref, (r.rid, r.generated, ref)


def test_admission_trace_recorded(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(1)
    for r in _requests(8, rng):
        sched.submit(r)
    sched.producer_done()
    sched.run_until_drained()
    assert sum(n for _, n in sched.stats.admission_trace) == 8
    # OneOrAll with an empty engine admits everything at once
    assert sched.stats.admission_trace[0][1] == 8


def test_straggler_requeue(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=64)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), lane_timeout=3)
    rng = np.random.default_rng(2)
    reqs = _requests(2, rng, max_new=10)  # 10 tokens > timeout 3 → requeue
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    # run a bounded number of ticks; requests keep being requeued
    for _ in range(30):
        sched.tick()
    assert sched.stats.requeued >= 1


def test_lanes_respected(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(3)
    for r in _requests(7, rng):
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 7
    assert max(n for _, n in sched.stats.admission_trace) <= 2


def test_mixed_template_lane_admissions(setup):
    """Requests with different templates are admitted from per-template
    lanes: every admission batch is homogeneous and each lane's trace is
    recorded separately."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(5)
    reqs = []
    for i in range(8):
        tmpl = "chat" if i % 2 == 0 else "summarize"
        size = 4 if tmpl == "chat" else 13
        reqs.append(Request(rid=i, prompt=rng.integers(1, 200, size=size).astype(np.int32),
                            max_new_tokens=4, template=tmpl))
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 8
    assert set(sched.stats.lane_admissions) == {"chat", "summarize"}
    # each lane admitted its 4 requests; totals agree with the global trace
    for tmpl, trace in sched.stats.lane_admissions.items():
        assert sum(n for _, n in trace) == 4
    assert sum(n for _, n in sched.stats.admission_trace) == 8
    assert sched.queues == {}  # drained lanes are garbage-collected


# ---------------------------------------------------------------------------
# KV partitioning (per-template lane reservations)
# ---------------------------------------------------------------------------


def test_kv_partition_reservations_and_release():
    part = KVPartition(6, {"a": 2, "b": 2})
    assert part.n_free == 6
    assert part.n_free_for("a") == 4          # own 2 + shared 2
    assert part.n_free_for("c") == 2          # unreserved: shared only
    assert part.n_free_for(None) == 2
    # a's burst drains its reservation first, then the shared pool…
    taken = [part.alloc("a") for _ in range(4)]
    assert part.n_free_for("a") == 0
    # …but b's reservation is untouched by the burst
    assert part.n_free_for("b") == 2
    assert part.n_free_for("c") == 0
    b_lanes = [part.alloc("b"), part.alloc("b")]
    # releases go home: a's reserved lanes back to a, shared back to shared
    for lane in taken:
        part.release(lane)
    assert part.n_free_for("a") == 4 and part.n_free_for("c") == 2
    for lane in b_lanes:
        part.release(lane)
    assert part.n_free == 6


def test_kv_partition_validates_shares():
    with pytest.raises(ValueError):
        KVPartition(4, {"a": 3, "b": 2})  # over-reserved
    with pytest.raises(ValueError):
        KVPartition(4, {"a": -1})


def test_proportional_shares_follow_weights():
    shares = proportional_shares({"chat": 3.0, "embed": 1.0}, n_lanes=8,
                                 reserve=0.5)
    assert shares == {"chat": 3, "embed": 1}  # 4 reserved, 4 shared
    assert proportional_shares({}, 8) == {}
    # tiny budgets round by largest remainder, deterministically
    shares = proportional_shares({"a": 1.0, "b": 1.0, "c": 1.0}, n_lanes=4,
                                 reserve=0.5)
    assert sum(shares.values()) == 2 and all(v >= 0 for v in shares.values())
    with pytest.raises(ValueError):
        proportional_shares({"a": 0.0}, 8)


def test_engine_kv_burst_cannot_take_reserved_lanes(setup):
    """A single-template admission burst may drain its own reservation and
    the shared pool, but other templates' reserved lanes stay free — the
    contention guarantee the partition exists for."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=6, max_prompt_len=16,
                          max_len=48, kv_shares={"a": 2, "b": 2})
    rng = np.random.default_rng(11)
    burst = _requests(4, rng, max_new=2)
    assert eng.n_free_for("a") == 4
    eng.admit(burst, template="a")            # burst takes ALL of a's lanes
    assert eng.n_free_for("a") == 0
    assert eng.n_free_for("b") == 2           # b's reservation never evicted
    with pytest.raises(AssertionError):
        eng.admit(_requests(1, rng, max_new=2), template="a")
    b_reqs = _requests(2, rng, max_new=2)
    eng.admit(b_reqs, template="b")           # b admits despite the burst
    for r in burst:
        eng.retire(r.lane)
    assert eng.n_free_for("a") == 4           # lanes went home on release
    for r in b_reqs:
        eng.retire(r.lane)
    assert eng.n_free == 6


# ---------------------------------------------------------------------------
# speculative prefill / decode overlap
# ---------------------------------------------------------------------------


def test_overlap_matches_sequential(setup):
    """overlap=True pipelines prefill under decode but must not change a
    single generated token (same greedy decode, same KV)."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), overlap=True)
    rng = np.random.default_rng(42)
    reqs = _requests(9, rng)
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 9
    for r in reqs[:3]:
        ref = _sequential_reference(arch, params, r)
        assert r.generated[: len(ref)] == ref, (r.rid, r.generated, ref)
    st = sched.stats
    # the pipeline actually ran, its ledger balances, and nothing is staged
    assert st.spec_dispatched >= 1
    assert st.spec_dispatched == st.spec_committed + st.spec_aborted
    assert not sched._staged
    # every request lands exactly once (aborted speculations re-land later)
    assert sum(n for _, n in st.admission_trace) == 9
    assert sum(1 for r in done if r.metrics.speculative) == st.spec_committed


def test_overlap_with_policy_and_kv_shares(setup):
    """The full tentpole wiring: LanePolicy weights → proportional KV
    shares → overlapped scheduler; mixed templates all complete and each
    lane's admissions stay homogeneous."""
    arch, params = setup
    from repro.core.lane_policy import LanePolicy

    weights = {"chat": 2.0, "summarize": 1.0}
    shares = proportional_shares(weights, n_lanes=8, reserve=0.5)
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16,
                          max_len=48, kv_shares=shares)
    policy = LanePolicy(hot_threshold=10**9, lane_weights=weights)
    sched = ContinuousBatchingScheduler(eng, policy=policy, overlap=True)
    rng = np.random.default_rng(6)
    reqs = []
    for i in range(10):
        tmpl = "chat" if i % 2 == 0 else "summarize"
        size = 4 if tmpl == "chat" else 13
        reqs.append(Request(rid=i,
                            prompt=rng.integers(1, 200, size=size).astype(np.int32),
                            max_new_tokens=4, template=tmpl))
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 10
    assert set(sched.stats.lane_admissions) == {"chat", "summarize"}


class _AbortRecorder(BatchingStrategy):
    """OneOrAll that records observe_abort feedback."""

    def __init__(self):
        self.aborts: list = []

    def decide(self, n_pending, producer_done):
        return n_pending

    def observe_abort(self, duration, depth=1):
        self.aborts.append((duration, depth))


def test_spec_abort_requeues_and_feeds_observe_abort(setup):
    """A speculation whose freed lane lands in another template's
    reservation misses: the staged requests go back to the queue head and
    the wasted prefill feeds observe_abort."""
    arch, params = setup
    # Every lane reserved to "x": template "y" has NO admissible lane, so a
    # speculative dispatch for y (betting on x's imminent retirement) must
    # abort at commit — deterministically.  The pool-aware sizing hint
    # (lane_benefits) would refuse that bet outright, so disable it: this
    # exercises the documented fallback for engines without the hint,
    # whose speculations CAN miss.
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                          max_len=48, kv_shares={"x": 2})
    eng.kv.benefits = None  # instance attr shadows the method → optimistic
    strat = _AbortRecorder()
    sched = ContinuousBatchingScheduler(eng, strategy=strat, overlap=True)
    rng = np.random.default_rng(3)
    rx = Request(rid=0, prompt=rng.integers(1, 200, 6).astype(np.int32),
                 max_new_tokens=2, template="x")
    ry = Request(rid=1, prompt=rng.integers(1, 200, 6).astype(np.int32),
                 max_new_tokens=2, template="y")
    sched.submit(rx)
    sched.submit(ry)
    sched.producer_done()
    sched.tick()   # admits x; speculates y on x's imminent retirement
    assert sched.stats.spec_dispatched == 1
    sched.tick()   # x's lane went home to x's pool: y's commit finds 0 lanes
    assert sched.stats.spec_aborted == 1
    assert sched.stats.spec_committed == 0
    assert len(sched.queues["y"]) == 1        # back at the head of its lane
    assert ry.generated == []                 # nothing committed
    assert ry.metrics.speculative is False    # the attempt did not land
    assert len(strat.aborts) == 1
    assert strat.aborts[0][0] > 0.0 and strat.aborts[0][1] >= 1
    assert rx.done                            # x finished untouched


class _SplitStubEngine:
    """No-JAX engine with the full split dispatch surface (a KVPartition
    exposed as ``kv``, dispatch/commit) for scheduler-logic tests."""

    def __init__(self, n_lanes=2, kv_shares=None):
        self.partition = KVPartition(n_lanes, kv_shares)
        self.active: dict = {}

    @property
    def kv(self):
        return self.partition  # the KVView the scheduler binds

    @property
    def n_free(self):
        return self.partition.n_free

    def n_free_for(self, template):
        return self.partition.n_free_for(template)

    def prefill_dispatch(self, requests, template=None):
        return dataclasses.make_dataclass("S", ["template", "requests"])(
            template, list(requests))

    def commit_prefill(self, staged, n=None):
        reqs = staged.requests if n is None else staged.requests[:n]
        for r in reqs:
            r.lane = self.partition.alloc(staged.template)
            r.generated.append(0)
            self.active[r.lane] = r
        return (len(staged.requests), 8)

    def admit(self, requests, template=None):
        return self.commit_prefill(self.prefill_dispatch(requests, template))

    def decode_tick(self):
        return {lane: 1 for lane in self.active}

    def retire(self, lane):
        self.active.pop(lane, None)
        self.partition.release(lane)


def test_weighted_spec_scan_passes_a_declining_lane():
    """Under weighted-fair picking, a head lane whose strategy declines
    must not blind the speculator: the scan filters declined lanes out of
    the candidate set and speculates the next dispatchable one."""
    from repro.core.lane_policy import LanePolicy

    class _Wait(BatchingStrategy):
        def decide(self, n_pending, producer_done):
            return 0  # always "wait" — e.g. AdaptiveCost below threshold

    class _TakeAll(BatchingStrategy):
        def decide(self, n_pending, producer_done):
            return n_pending

    eng = _SplitStubEngine(n_lanes=1)
    policy = LanePolicy(lane_weights={"a": 1.0, "b": 1.0},
                        overrides={"a": _Wait(), "b": _TakeAll(),
                                   "c": _TakeAll()})
    sched = ContinuousBatchingScheduler(eng, policy=policy, overlap=True)
    rng = np.random.default_rng(0)
    # occupy the only lane; rid=0 retires at the NEXT tick's decode
    # (token 0 at admit + one token per decode tick → 3 tokens = 2 ticks)
    sched.submit(Request(rid=0, prompt=rng.integers(1, 9, 4).astype(np.int32),
                         max_new_tokens=3, template="c"))
    sched.tick()
    assert eng.n_free == 0 and len(sched.running) == 1
    # "a" wins the weighted-fair pick (earlier join at the vtime floor)…
    sched.submit(Request(rid=1, prompt=rng.integers(1, 9, 4).astype(np.int32),
                         max_new_tokens=2, template="a"))
    sched.submit(Request(rid=2, prompt=rng.integers(1, 9, 4).astype(np.int32),
                         max_new_tokens=2, template="b"))
    done = sched.tick()
    assert [r.rid for r in done] == [0]  # rid=0 retired during this tick
    # …but "a" declines, so the speculation must land on "b", not nothing
    assert sched._staged and sched._staged[0].template == "b"
    assert sched.stats.spec_dispatched == 1
    # and the declined lane kept its queue position (no rotation)
    assert sched._ready.peek(select=policy.lane_min) == "a"
    done = sched.tick()  # commits b's spec prefill; decode finishes it
    assert [r.rid for r in done] == [2]
    assert sched.stats.spec_committed == 1 and sched.stats.spec_aborted == 0
    assert len(sched.queues["a"]) == 1  # "a" still parked, untouched


# ---------------------------------------------------------------------------
# depth-k speculation pipeline
# ---------------------------------------------------------------------------


class _TakeAllRec(BatchingStrategy):
    """Take-all strategy that records observe_abort feedback."""

    def __init__(self):
        self.aborts: list = []

    def decide(self, n_pending, producer_done):
        return n_pending

    def observe_abort(self, duration, depth=1):
        self.aborts.append((duration, depth))


def test_spec_depth_validation():
    eng = _SplitStubEngine(n_lanes=2)
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(eng, spec_depth=0)
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(eng, spec_depth=2)  # needs overlap
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(eng, chunk_tokens=4)  # needs overlap
    with pytest.raises(ValueError):
        # stub engine has no prefill_resume: chunking must be refused
        ContinuousBatchingScheduler(eng, overlap=True, chunk_tokens=4)
    s = ContinuousBatchingScheduler(eng, overlap=True, spec_depth=4)
    assert s.spec_depth == 4


def test_depth_k_pipeline_stages_multiple_bets():
    """With spec_depth=3 and several ready lanes, one tick stages multiple
    bets, each sized against capacity net of older bets' promises."""
    from repro.serving.scheduler import _SpecTask  # noqa: F401 (API check)

    eng = _SplitStubEngine(n_lanes=4)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(),
                                        overlap=True, spec_depth=3)
    rng = np.random.default_rng(0)
    # occupy all 4 lanes with requests retiring one tick later (token 0 at
    # admit + decode per tick: remaining hits 1 during the NEXT tick)
    runners = [Request(rid=i, prompt=rng.integers(1, 9, 4).astype(np.int32),
                       max_new_tokens=3, template=f"run{i}")
               for i in range(4)]
    for r in runners:
        sched.submit(r)
    sched.tick()  # all 4 admitted (OneOrAll per lane, 1 each)
    assert eng.n_free == 0
    # 4 lanes retire next tick (remaining == 1) → speculative capacity 4,
    # split across bets: older bets' promises shrink younger bets.
    for i, tmpl in enumerate(("a", "a", "b", "b", "c")):
        sched.submit(Request(rid=10 + i,
                             prompt=rng.integers(1, 9, 4).astype(np.int32),
                             max_new_tokens=1, template=tmpl))
    sched.tick()
    staged = list(sched._staged)
    # a promises 2 of the 4 speculative lanes, b the other 2; c sees
    # 4 − 2 − 2 = 0 remaining capacity and is DECLINED — the pipeline
    # fills to available capacity, not blindly to spec_depth.
    assert [t.template for t in staged] == ["a", "b"]
    assert [len(t.batch) for t in staged] == [2, 2]
    assert len(sched.queues["c"]) == 1  # declined, still queued
    assert sched.stats.spec_dispatched == 4
    sched.tick()  # both bets commit oldest-first at this boundary
    assert sched.stats.spec_committed == 4
    assert sched.stats.spec_aborted == 0


def test_depth_k_abort_cascade_oldest_first():
    """The cascade discipline: the oldest bet settles first (partial
    commit + shortfall abort); after the miss, a younger bet covered by
    its own reservation survives staged, an uncovered one aborts NOW and
    feeds observe_abort with its pipeline depth."""
    from repro.core.lane_policy import LanePolicy
    from repro.serving.scheduler import _SpecTask

    eng = _SplitStubEngine(n_lanes=3, kv_shares={"b": 1})  # 2 shared + b's 1
    rec_a, rec_b, rec_c = _TakeAllRec(), _TakeAllRec(), _TakeAllRec()
    policy = LanePolicy(overrides={"a": rec_a, "b": rec_b, "c": rec_c})
    sched = ContinuousBatchingScheduler(eng, policy=policy, overlap=True,
                                        spec_depth=3)
    rng = np.random.default_rng(1)

    def mk(rid, tmpl):
        return Request(rid=rid, prompt=rng.integers(1, 9, 4).astype(np.int32),
                       max_new_tokens=8, template=tmpl)

    # Occupy ONE shared lane with a long runner so only 1 shared lane +
    # b's reserved lane are free at the boundary.
    runner = mk(0, "long")
    eng.admit([runner], template="long")
    sched.running[runner.lane] = runner
    sched._lane_age[runner.lane] = 0
    # Stage three bets by hand (deterministic pipeline state):
    #   oldest: "a" wants 2 shared lanes — only 1 free → partial miss
    #   middle: "b" wants 1 — its own reservation covers it → survives
    #   youngest: "c" wants 1 shared — uncovered after the miss → aborts
    a1, a2, b1, c1 = mk(1, "a"), mk(2, "a"), mk(3, "b"), mk(4, "c")
    for t in (_SpecTask(eng, "a", [a1, a2]), _SpecTask(eng, "b", [b1]),
              _SpecTask(eng, "c", [c1])):
        t.join()
        sched._staged.append(t)
    # Boundaries 1 and 2: the oldest bet's shortfall is within its
    # spec_depth horizon — it WAITS (no split, no abort), younger bets
    # queue behind it.
    sched.tick()
    sched.tick()
    st = sched.stats
    assert st.spec_committed == 0 and st.spec_aborted == 0
    assert [t.template for t in sched._staged] == ["a", "b", "c"]
    # Boundary 3: the horizon expired → the miss settles the cascade.
    sched.tick()
    # oldest: committed 1, aborted 1 (back at a's queue head)
    assert st.spec_committed == 1 and a1.lane is not None
    assert list(sched.queues["a"]) == [a2]
    # youngest: uncovered → aborted at the SAME boundary, its pipeline
    # depth (3 boundaries staged) attributed to the abort penalty
    assert list(sched.queues["c"]) == [c1]
    assert st.spec_aborted == 2
    assert len(rec_c.aborts) == 1 and rec_c.aborts[0][1] == 3
    # partial commits carry no penalty; the surviving bet none either
    assert rec_a.aborts == [] and rec_b.aborts == []
    # middle bet survived the cascade and is still staged, oldest-first
    assert [t.template for t in sched._staged] == ["b"]
    sched.tick()  # b's reservation still holds its lane → commits now
    assert b1.lane is not None and sched.stats.spec_committed == 2


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def test_engine_chunked_prefill_matches_one_shot(setup):
    """Resume-equivalence at the engine level: dispatch(chunk=) + resume
    loop + commit generates EXACTLY the tokens one-shot admit does."""
    arch, params = setup
    rng = np.random.default_rng(21)
    prompt = rng.integers(1, 200, size=13).astype(np.int32)

    eng1 = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=48)
    r1 = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng1.admit([r1], template="t")
    for _ in range(5):
        for lane, tok in eng1.decode_tick().items():
            if lane == r1.lane:
                r1.generated.append(tok)

    eng2 = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=48)
    r2 = Request(rid=1, prompt=prompt, max_new_tokens=6)
    staged = eng2.prefill_dispatch([r2], template="t", chunk=4)
    assert not staged.complete and staged.first is None
    resumes = 0
    while not eng2.prefill_resume(staged):
        resumes += 1
    assert resumes + 1 == 3  # ceil((13-4)/4) = 3 chunks after the first
    eng2.commit_prefill(staged)
    for _ in range(5):
        for lane, tok in eng2.decode_tick().items():
            if lane == r2.lane:
                r2.generated.append(tok)
    assert r2.generated == r1.generated

    # a prompt that fits one chunk falls through to the one-shot path
    short = Request(rid=2, prompt=prompt[:3], max_new_tokens=2)
    st = eng2.prefill_dispatch([short], template="t", chunk=4)
    assert st.complete and st.first is not None


def test_scheduler_chunked_prefill_overlaps_and_matches(setup):
    """A huge prompt under chunk_tokens rides the speculation thread one
    chunk per tick and still produces the one-shot tokens; decode of
    other lanes keeps running while the chunks fold in."""
    arch, params = setup
    rng = np.random.default_rng(22)
    big_prompt = rng.integers(1, 200, size=14).astype(np.int32)

    # reference: one-shot admit of the same prompt
    ref_eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                              max_len=48)
    ref = Request(rid=0, prompt=big_prompt, max_new_tokens=5)
    ref_sched = ContinuousBatchingScheduler(ref_eng, strategy=OneOrAll())
    ref_sched.submit(ref)
    ref_sched.producer_done()
    ref_sched.run_until_drained()

    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16,
                          max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(),
                                        overlap=True, chunk_tokens=4)
    big = Request(rid=1, prompt=big_prompt, max_new_tokens=5,
                  template="big")
    small = [Request(rid=10 + i,
                     prompt=rng.integers(1, 200, 4).astype(np.int32),
                     max_new_tokens=4, template="small") for i in range(3)]
    sched.submit(small[0])
    sched.tick()          # occupy a lane so decode has work under the chunks
    sched.submit(big)
    for r in small[1:]:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 4
    assert big.generated == ref.generated  # chunked ≡ one-shot
    assert sched.stats.spec_chunks >= 2   # the chunk pipeline actually ran
    assert big.metrics.speculative        # landed via the overlap path


# ---------------------------------------------------------------------------
# host KV spill
# ---------------------------------------------------------------------------


def test_host_spill_pool_lru_and_budget():
    from repro.serving.engine import HostSpillPool

    pool = HostSpillPool(max_entries=2)
    pool.put(1, "a", {"x": 1})
    pool.put(2, "a", {"x": 2})
    pool.put(3, "b", {"x": 3})  # over max_entries: LRU (key 1) dropped
    assert 1 not in pool and 2 in pool and 3 in pool
    assert pool.take(2) == {"x": 2}
    assert pool.take(2) is None  # taken once
    assert pool.snapshot()["spilled"] == 3
    assert pool.snapshot()["dropped"] == 1
    assert pool.snapshot()["restored"] == 1

    # per-template budget: one template's churn cannot evict another's
    budgets = {"a": 1}
    pool2 = HostSpillPool(max_entries=8,
                          budget_for=lambda t: budgets.get(t))
    pool2.put(1, "a", {"x": 1})
    pool2.put(2, "b", {"x": 2})
    pool2.put(3, "a", {"x": 3})  # a over budget: drops a's LRU (key 1)
    assert 1 not in pool2 and 2 in pool2 and 3 in pool2
    # budget 0 fences a template out entirely — put REPORTS the refusal
    # (and accepts() lets callers skip the KV copy up front)
    budgets["c"] = 0
    assert pool2.accepts("a") and not pool2.accepts("c")
    assert pool2.put(4, "c", {"x": 4}) is False
    assert 4 not in pool2
    assert pool2.put(5, "a", {"x": 5}) is True

    with pytest.raises(ValueError):
        HostSpillPool(max_entries=0)


def test_spill_restore_round_trip_preserves_decode_output(setup):
    """A straggler-evicted request whose KV was spilled resumes decoding
    on re-admission with its tokens intact — final output identical to an
    uninterrupted run, with zero extra prefills."""
    from repro.serving.engine import HostSpillPool

    arch, params = setup
    rng = np.random.default_rng(31)
    prompt = rng.integers(1, 200, size=9).astype(np.int32)

    ref_eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                              max_len=48)
    ref = Request(rid=0, prompt=prompt, max_new_tokens=8)
    ref_sched = ContinuousBatchingScheduler(ref_eng, strategy=OneOrAll())
    ref_sched.submit(ref)
    ref_sched.producer_done()
    ref_sched.run_until_drained()

    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                          max_len=48, kv_spill=HostSpillPool(max_entries=4))
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(),
                                        lane_timeout=2)
    r = Request(rid=1, prompt=prompt, max_new_tokens=8)
    sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert [x.rid for x in done] == [1]
    st = sched.stats
    assert st.kv_spilled >= 1          # the straggler actually evicted
    assert st.kv_restored == st.kv_spilled  # every eviction restored
    assert r.generated == ref.generated     # decode output preserved
    assert eng.prefill_calls == 1      # restored, never re-prefilled
    assert eng.kv_spill.snapshot()["restored"] == st.kv_restored


def test_spill_miss_restarts_cleanly(setup):
    """If the spill entry is evicted before re-admission (pool budget),
    the request re-prefills from scratch — stale partial generation is
    discarded, output still correct."""
    from repro.serving.engine import HostSpillPool

    arch, params = setup
    rng = np.random.default_rng(32)
    prompt = rng.integers(1, 200, size=9).astype(np.int32)

    ref_eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                              max_len=48)
    ref = Request(rid=0, prompt=prompt, max_new_tokens=6)
    ref_sched = ContinuousBatchingScheduler(ref_eng, strategy=OneOrAll())
    ref_sched.submit(ref)
    ref_sched.producer_done()
    ref_sched.run_until_drained()

    # budget_for returns 0: every spill is dropped on arrival (the
    # degenerate pool) — restores always miss.
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                          max_len=48,
                          kv_spill=HostSpillPool(max_entries=4,
                                                 budget_for=lambda t: 0))
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(),
                                        lane_timeout=3)
    r = Request(rid=1, prompt=prompt, max_new_tokens=6)
    sched.submit(r)
    sched.producer_done()
    for _ in range(10):  # tick until the straggler is evicted once
        sched.tick()
        if sched.stats.requeued:
            break
    # the fenced pool refused the entry: spill() reported the truth, so
    # kv_spilled stays 0 and the partial generation was discarded at once
    assert sched.stats.requeued == 1 and sched.stats.kv_spilled == 0
    assert r.generated == []
    sched.lane_timeout = None  # let the restart run to completion
    done = sched.run_until_drained()
    assert [x.rid for x in done] == [1]
    assert sched.stats.kv_restored == 0  # nothing staged: nothing restored
    assert r.generated == ref.generated  # restarted cleanly, same output
    assert eng.prefill_calls >= 2        # the restart re-prefilled


def test_spilled_oversized_prompt_is_restored_not_starved(setup):
    """Regression: a spilled request whose prompt exceeds chunk_tokens
    used to starve forever — the admission oversized-prompt gate skipped
    the lane before the restore path ran, while the spec path declined it
    because has_spill() was true.  The restore path must win (it pays no
    prefill, so prompt width is irrelevant) and the request completes
    with its decode output preserved."""
    from repro.serving.engine import HostSpillPool

    arch, params = setup
    rng = np.random.default_rng(41)
    prompt = rng.integers(1, 200, size=15).astype(np.int32)

    ref_eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                              max_len=64)
    ref = Request(rid=0, prompt=prompt, max_new_tokens=12)
    ref_sched = ContinuousBatchingScheduler(ref_eng, strategy=OneOrAll())
    ref_sched.submit(ref)
    ref_sched.producer_done()
    ref_sched.run_until_drained()

    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                          max_len=64, kv_spill=HostSpillPool(max_entries=4))
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(),
                                        overlap=True, chunk_tokens=8,
                                        lane_timeout=3)
    r = Request(rid=1, prompt=prompt, max_new_tokens=12, template="doc")
    sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained(max_ticks=500)  # pre-fix: RuntimeError
    assert [x.rid for x in done] == [1]
    assert sched.stats.kv_spilled >= 1
    assert sched.stats.kv_restored == sched.stats.kv_spilled
    assert r.generated == ref.generated
    assert eng.prefill_calls == 1  # chunked prefill once, then restores only


def test_abort_cascade_keeps_same_template_fifo_order():
    """Regression: when an older and a younger same-template bet both
    abort at one boundary, the younger batch must requeue BEHIND the
    older one (requeues flush youngest-first), preserving arrival order
    at the queue head."""
    from repro.serving.scheduler import _SpecTask

    eng = _SplitStubEngine(n_lanes=1)
    sched = ContinuousBatchingScheduler(eng, strategy=_TakeAllRec(),
                                        overlap=True, spec_depth=2)
    rng = np.random.default_rng(2)

    def mk(rid):
        return Request(rid=rid, prompt=rng.integers(1, 9, 4).astype(np.int32),
                       max_new_tokens=8, template="t")

    # the only lane is held by a long runner: both bets must miss
    runner = mk(0)
    eng.admit([runner], template="hold")
    sched.running[runner.lane] = runner
    sched._lane_age[runner.lane] = 0
    r1, r2, r3 = mk(1), mk(2), mk(3)
    for t in (_SpecTask(eng, "t", [r1, r2]), _SpecTask(eng, "t", [r3])):
        t.join()
        sched._staged.append(t)
    sched.tick()   # boundary 1: within the depth-2 horizon → both wait
    assert sched.stats.spec_aborted == 0
    sched.tick()   # boundary 2: horizon expired → cascade settles
    assert sched.stats.spec_aborted == 3
    # arrival order survives: the older bet's requests lead the queue
    assert [x.rid for x in sched.queues["t"]] == [1, 2, 3]


def test_example_overlap_kv_demo_smoke(setup):
    """The examples/serve_continuous_batching.py overlap demo runs end to
    end on the reduced model: every request finishes and the demo's stats
    ledger balances."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))
    try:
        from serve_continuous_batching import depth_spill_demo, overlap_kv_demo
    finally:
        sys.path.pop(0)
    arch, params = setup
    done, st = overlap_kv_demo(arch, params, n_requests=8, verbose=False)
    assert len(done) == 8
    assert all(r.done for r in done)
    assert st.spec_dispatched == st.spec_committed + st.spec_aborted

    done, st = depth_spill_demo(arch, params, n_requests=6, verbose=False)
    assert len(done) == 6
    assert all(r.done for r in done)
    assert st.spec_dispatched == st.spec_committed + st.spec_aborted
    assert st.spec_chunks >= 1  # the oversized prompt actually chunked
    assert st.kv_restored == st.kv_spilled  # every spill restored


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_stream_deterministic():
    s1 = SyntheticLMStream(1000, 32, 4, seed=9)
    s2 = SyntheticLMStream(1000, 32, 4, seed=9)
    np.testing.assert_array_equal(s1.batch_at(17)["tokens"], s2.batch_at(17)["tokens"])
    assert not np.array_equal(s1.batch_at(17)["tokens"], s1.batch_at(18)["tokens"])


def test_prefetch_loader_order_and_bound():
    stream = SyntheticLMStream(100, 8, 2, seed=1)
    loader = PrefetchLoader(stream, n_prefetch=3, max_steps=10)
    batches = list(loader)
    assert len(batches) == 10
    np.testing.assert_array_equal(batches[4]["tokens"], stream.batch_at(4)["tokens"])


def test_engine_pins_one_prefill_shape_per_template(setup):
    """template= admission pins the padding bucket: after a template's
    largest batch, every later admit dispatches the SAME compiled shape."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    rng = np.random.default_rng(7)
    reqs = _requests(6, rng, max_new=2)
    shape_a = eng.admit(reqs[:3], template="chat")     # bucket (4, plen)
    assert shape_a[0] == 4
    for r in reqs[:3]:
        eng.retire(r.lane)
    shape_b = eng.admit(reqs[3:4], template="chat")    # 1 request, pinned shape
    assert shape_b == shape_a
    assert eng.template_shapes["chat"] == shape_a
    for r in reqs[3:4]:
        eng.retire(r.lane)
    # an unrelated template sizes its own bucket from scratch
    shape_c = eng.admit(reqs[4:5], template="embed")
    assert shape_c[0] == 1


# ---------------------------------------------------------------------------
# seeded chaos: serving output must be bit-identical under injected faults
# (REPRO_CHAOS_SEED selects the schedule; the CI chaos job runs two seeds)
# ---------------------------------------------------------------------------


def test_chaos_decode_faults_preserve_outputs_bit_identical(setup):
    """Acceptance: with seeded decode-tick crashes injected into the real
    engine, every request completes with EXACTLY the tokens the
    fault-free run produces — crashed lanes are quarantined, their KV
    salvaged through the spill pool (or re-prefilled), and the requests
    resume with no token lost, duplicated, or changed."""
    from repro.core.faults import ChaosEngine, ChaosPlan, chaos_seed
    from repro.core.resilience import Resilience
    from repro.serving.engine import HostSpillPool

    arch, params = setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 200, size=rng.integers(3, 12)).astype(np.int32)
               for _ in range(6)]

    def run(chaos: bool):
        reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=5)
                for i, p in enumerate(prompts)]
        eng = InferenceEngine(arch, params, n_lanes=3, max_prompt_len=16,
                              max_len=48, kv_spill=HostSpillPool(max_entries=16))
        if chaos:
            eng = ChaosEngine(eng, ChaosPlan(seed=chaos_seed(0),
                                             decode_fault_rate=0.25))
        sched = ContinuousBatchingScheduler(
            eng, strategy=OneOrAll(),
            resilience=Resilience(quarantine_ticks=1) if chaos else None)
        for r in reqs:
            sched.submit(r)
        sched.producer_done()
        done = sched.run_until_drained(max_ticks=2000)
        assert len(done) == len(reqs)
        return {r.rid: list(r.generated) for r in reqs}, eng, sched

    baseline, _, _ = run(chaos=False)
    chaotic, eng, sched = run(chaos=True)
    assert eng.injected_decode_faults > 0, "chaos never bit: rate too low"
    assert sched.stats.quarantined > 0
    assert chaotic == baseline  # bit-identical to the fault-free run
