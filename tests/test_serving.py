"""Continuous batching scheduler: correctness vs sequential generation,
strategy behaviour, straggler re-queue, data pipeline determinism."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies import (
    GrowingUpperThreshold,
    LowerThreshold,
    OneOrAll,
    PureAsync,
)
from repro.core.strategies import BatchingStrategy
from repro.data.pipeline import PrefetchLoader, SyntheticLMStream
from repro.models.registry import get_arch
from repro.serving.engine import InferenceEngine, KVPartition, proportional_shares
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler


@pytest.fixture(scope="module")
def setup():
    arch = get_arch("llama3-8b")
    arch = dataclasses.replace(arch, cfg=arch.cfg.reduced())
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


def _requests(n, rng, max_new=6):
    return [
        Request(rid=i, prompt=rng.integers(1, 200, size=rng.integers(3, 14)).astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def _sequential_reference(arch, params, req, max_len=48):
    toks = jnp.asarray(req.prompt)[None]
    last, cache = arch.prefill(params, {"tokens": toks}, max_len=max_len)
    out = [int(jnp.argmax(last, -1)[0])]
    lengths = jnp.asarray([len(req.prompt)], jnp.int32)
    cur = jnp.asarray(out, jnp.int32)
    for _ in range(req.max_new_tokens - 1):
        lg, cache = arch.decode_step(params, cur, cache, lengths)
        nxt = int(jnp.argmax(lg, -1)[0])
        out.append(nxt)
        cur = jnp.asarray([nxt], jnp.int32)
        lengths = lengths + 1
    return out


@pytest.mark.parametrize("strategy", [
    PureAsync(), OneOrAll(), LowerThreshold(bt=3),
    GrowingUpperThreshold(initial_upper=2, bt=None),
])
def test_scheduler_matches_sequential(setup, strategy):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=strategy)
    rng = np.random.default_rng(42)
    reqs = _requests(9, rng)
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 9
    for r in reqs[:3]:  # spot-check 3 against the sequential oracle
        ref = _sequential_reference(arch, params, r)
        assert r.generated[: len(ref)] == ref, (r.rid, r.generated, ref)


def test_admission_trace_recorded(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(1)
    for r in _requests(8, rng):
        sched.submit(r)
    sched.producer_done()
    sched.run_until_drained()
    assert sum(n for _, n in sched.stats.admission_trace) == 8
    # OneOrAll with an empty engine admits everything at once
    assert sched.stats.admission_trace[0][1] == 8


def test_straggler_requeue(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=64)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), lane_timeout=3)
    rng = np.random.default_rng(2)
    reqs = _requests(2, rng, max_new=10)  # 10 tokens > timeout 3 → requeue
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    # run a bounded number of ticks; requests keep being requeued
    for _ in range(30):
        sched.tick()
    assert sched.stats.requeued >= 1


def test_lanes_respected(setup):
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(3)
    for r in _requests(7, rng):
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 7
    assert max(n for _, n in sched.stats.admission_trace) <= 2


def test_mixed_template_lane_admissions(setup):
    """Requests with different templates are admitted from per-template
    lanes: every admission batch is homogeneous and each lane's trace is
    recorded separately."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll())
    rng = np.random.default_rng(5)
    reqs = []
    for i in range(8):
        tmpl = "chat" if i % 2 == 0 else "summarize"
        size = 4 if tmpl == "chat" else 13
        reqs.append(Request(rid=i, prompt=rng.integers(1, 200, size=size).astype(np.int32),
                            max_new_tokens=4, template=tmpl))
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 8
    assert set(sched.stats.lane_admissions) == {"chat", "summarize"}
    # each lane admitted its 4 requests; totals agree with the global trace
    for tmpl, trace in sched.stats.lane_admissions.items():
        assert sum(n for _, n in trace) == 4
    assert sum(n for _, n in sched.stats.admission_trace) == 8
    assert sched.queues == {}  # drained lanes are garbage-collected


# ---------------------------------------------------------------------------
# KV partitioning (per-template lane reservations)
# ---------------------------------------------------------------------------


def test_kv_partition_reservations_and_release():
    part = KVPartition(6, {"a": 2, "b": 2})
    assert part.n_free == 6
    assert part.n_free_for("a") == 4          # own 2 + shared 2
    assert part.n_free_for("c") == 2          # unreserved: shared only
    assert part.n_free_for(None) == 2
    # a's burst drains its reservation first, then the shared pool…
    taken = [part.alloc("a") for _ in range(4)]
    assert part.n_free_for("a") == 0
    # …but b's reservation is untouched by the burst
    assert part.n_free_for("b") == 2
    assert part.n_free_for("c") == 0
    b_lanes = [part.alloc("b"), part.alloc("b")]
    # releases go home: a's reserved lanes back to a, shared back to shared
    for lane in taken:
        part.release(lane)
    assert part.n_free_for("a") == 4 and part.n_free_for("c") == 2
    for lane in b_lanes:
        part.release(lane)
    assert part.n_free == 6


def test_kv_partition_validates_shares():
    with pytest.raises(ValueError):
        KVPartition(4, {"a": 3, "b": 2})  # over-reserved
    with pytest.raises(ValueError):
        KVPartition(4, {"a": -1})


def test_proportional_shares_follow_weights():
    shares = proportional_shares({"chat": 3.0, "embed": 1.0}, n_lanes=8,
                                 reserve=0.5)
    assert shares == {"chat": 3, "embed": 1}  # 4 reserved, 4 shared
    assert proportional_shares({}, 8) == {}
    # tiny budgets round by largest remainder, deterministically
    shares = proportional_shares({"a": 1.0, "b": 1.0, "c": 1.0}, n_lanes=4,
                                 reserve=0.5)
    assert sum(shares.values()) == 2 and all(v >= 0 for v in shares.values())
    with pytest.raises(ValueError):
        proportional_shares({"a": 0.0}, 8)


def test_engine_kv_burst_cannot_take_reserved_lanes(setup):
    """A single-template admission burst may drain its own reservation and
    the shared pool, but other templates' reserved lanes stay free — the
    contention guarantee the partition exists for."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=6, max_prompt_len=16,
                          max_len=48, kv_shares={"a": 2, "b": 2})
    rng = np.random.default_rng(11)
    burst = _requests(4, rng, max_new=2)
    assert eng.n_free_for("a") == 4
    eng.admit(burst, template="a")            # burst takes ALL of a's lanes
    assert eng.n_free_for("a") == 0
    assert eng.n_free_for("b") == 2           # b's reservation never evicted
    with pytest.raises(AssertionError):
        eng.admit(_requests(1, rng, max_new=2), template="a")
    b_reqs = _requests(2, rng, max_new=2)
    eng.admit(b_reqs, template="b")           # b admits despite the burst
    for r in burst:
        eng.retire(r.lane)
    assert eng.n_free_for("a") == 4           # lanes went home on release
    for r in b_reqs:
        eng.retire(r.lane)
    assert eng.n_free == 6


# ---------------------------------------------------------------------------
# speculative prefill / decode overlap
# ---------------------------------------------------------------------------


def test_overlap_matches_sequential(setup):
    """overlap=True pipelines prefill under decode but must not change a
    single generated token (same greedy decode, same KV)."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=4, max_prompt_len=16, max_len=48)
    sched = ContinuousBatchingScheduler(eng, strategy=OneOrAll(), overlap=True)
    rng = np.random.default_rng(42)
    reqs = _requests(9, rng)
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 9
    for r in reqs[:3]:
        ref = _sequential_reference(arch, params, r)
        assert r.generated[: len(ref)] == ref, (r.rid, r.generated, ref)
    st = sched.stats
    # the pipeline actually ran, its ledger balances, and nothing is staged
    assert st.spec_dispatched >= 1
    assert st.spec_dispatched == st.spec_committed + st.spec_aborted
    assert sched._staged is None
    # every request lands exactly once (aborted speculations re-land later)
    assert sum(n for _, n in st.admission_trace) == 9
    assert sum(1 for r in done if r.metrics.speculative) == st.spec_committed


def test_overlap_with_policy_and_kv_shares(setup):
    """The full tentpole wiring: LanePolicy weights → proportional KV
    shares → overlapped scheduler; mixed templates all complete and each
    lane's admissions stay homogeneous."""
    arch, params = setup
    from repro.core.lane_policy import LanePolicy

    weights = {"chat": 2.0, "summarize": 1.0}
    shares = proportional_shares(weights, n_lanes=8, reserve=0.5)
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16,
                          max_len=48, kv_shares=shares)
    policy = LanePolicy(hot_threshold=10**9, lane_weights=weights)
    sched = ContinuousBatchingScheduler(eng, policy=policy, overlap=True)
    rng = np.random.default_rng(6)
    reqs = []
    for i in range(10):
        tmpl = "chat" if i % 2 == 0 else "summarize"
        size = 4 if tmpl == "chat" else 13
        reqs.append(Request(rid=i,
                            prompt=rng.integers(1, 200, size=size).astype(np.int32),
                            max_new_tokens=4, template=tmpl))
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained()
    assert len(done) == 10
    assert set(sched.stats.lane_admissions) == {"chat", "summarize"}


class _AbortRecorder(BatchingStrategy):
    """OneOrAll that records observe_abort feedback."""

    def __init__(self):
        self.aborts: list = []

    def decide(self, n_pending, producer_done):
        return n_pending

    def observe_abort(self, duration):
        self.aborts.append(duration)


def test_spec_abort_requeues_and_feeds_observe_abort(setup):
    """A speculation whose freed lane lands in another template's
    reservation misses: the staged requests go back to the queue head and
    the wasted prefill feeds observe_abort."""
    arch, params = setup
    # Every lane reserved to "x": template "y" has NO admissible lane, so a
    # speculative dispatch for y (betting on x's imminent retirement) must
    # abort at commit — deterministically.  The pool-aware sizing hint
    # (lane_benefits) would refuse that bet outright, so disable it: this
    # exercises the documented fallback for engines without the hint,
    # whose speculations CAN miss.
    eng = InferenceEngine(arch, params, n_lanes=2, max_prompt_len=16,
                          max_len=48, kv_shares={"x": 2})
    eng.lane_benefits = None  # instance attr shadows the method → optimistic
    strat = _AbortRecorder()
    sched = ContinuousBatchingScheduler(eng, strategy=strat, overlap=True)
    rng = np.random.default_rng(3)
    rx = Request(rid=0, prompt=rng.integers(1, 200, 6).astype(np.int32),
                 max_new_tokens=2, template="x")
    ry = Request(rid=1, prompt=rng.integers(1, 200, 6).astype(np.int32),
                 max_new_tokens=2, template="y")
    sched.submit(rx)
    sched.submit(ry)
    sched.producer_done()
    sched.tick()   # admits x; speculates y on x's imminent retirement
    assert sched.stats.spec_dispatched == 1
    sched.tick()   # x's lane went home to x's pool: y's commit finds 0 lanes
    assert sched.stats.spec_aborted == 1
    assert sched.stats.spec_committed == 0
    assert len(sched.queues["y"]) == 1        # back at the head of its lane
    assert ry.generated == []                 # nothing committed
    assert ry.metrics.speculative is False    # the attempt did not land
    assert len(strat.aborts) == 1 and strat.aborts[0] > 0.0
    assert rx.done                            # x finished untouched


class _SplitStubEngine:
    """No-JAX engine with the full split dispatch surface (KVPartition
    pools, dispatch/commit, lane_benefits) for scheduler-logic tests."""

    def __init__(self, n_lanes=2, kv_shares=None):
        self.partition = KVPartition(n_lanes, kv_shares)
        self.active: dict = {}

    @property
    def n_free(self):
        return self.partition.n_free

    def n_free_for(self, template):
        return self.partition.n_free_for(template)

    def lane_benefits(self, lane, template):
        return self.partition.benefits(lane, template)

    def prefill_dispatch(self, requests, template=None):
        return dataclasses.make_dataclass("S", ["template", "requests"])(
            template, list(requests))

    def commit_prefill(self, staged, n=None):
        reqs = staged.requests if n is None else staged.requests[:n]
        for r in reqs:
            r.lane = self.partition.alloc(staged.template)
            r.generated.append(0)
            self.active[r.lane] = r
        return (len(staged.requests), 8)

    def admit(self, requests, template=None):
        return self.commit_prefill(self.prefill_dispatch(requests, template))

    def decode_tick(self):
        return {lane: 1 for lane in self.active}

    def retire(self, lane):
        self.active.pop(lane, None)
        self.partition.release(lane)


def test_weighted_spec_scan_passes_a_declining_lane():
    """Under weighted-fair picking, a head lane whose strategy declines
    must not blind the speculator: the scan filters declined lanes out of
    the candidate set and speculates the next dispatchable one."""
    from repro.core.lane_policy import LanePolicy

    class _Wait(BatchingStrategy):
        def decide(self, n_pending, producer_done):
            return 0  # always "wait" — e.g. AdaptiveCost below threshold

    class _TakeAll(BatchingStrategy):
        def decide(self, n_pending, producer_done):
            return n_pending

    eng = _SplitStubEngine(n_lanes=1)
    policy = LanePolicy(lane_weights={"a": 1.0, "b": 1.0},
                        overrides={"a": _Wait(), "b": _TakeAll(),
                                   "c": _TakeAll()})
    sched = ContinuousBatchingScheduler(eng, policy=policy, overlap=True)
    rng = np.random.default_rng(0)
    # occupy the only lane; rid=0 retires at the NEXT tick's decode
    # (token 0 at admit + one token per decode tick → 3 tokens = 2 ticks)
    sched.submit(Request(rid=0, prompt=rng.integers(1, 9, 4).astype(np.int32),
                         max_new_tokens=3, template="c"))
    sched.tick()
    assert eng.n_free == 0 and len(sched.running) == 1
    # "a" wins the weighted-fair pick (earlier join at the vtime floor)…
    sched.submit(Request(rid=1, prompt=rng.integers(1, 9, 4).astype(np.int32),
                         max_new_tokens=2, template="a"))
    sched.submit(Request(rid=2, prompt=rng.integers(1, 9, 4).astype(np.int32),
                         max_new_tokens=2, template="b"))
    done = sched.tick()
    assert [r.rid for r in done] == [0]  # rid=0 retired during this tick
    # …but "a" declines, so the speculation must land on "b", not nothing
    assert sched._staged is not None and sched._staged.template == "b"
    assert sched.stats.spec_dispatched == 1
    # and the declined lane kept its queue position (no rotation)
    assert sched._ready.peek(select=policy.lane_min) == "a"
    done = sched.tick()  # commits b's spec prefill; decode finishes it
    assert [r.rid for r in done] == [2]
    assert sched.stats.spec_committed == 1 and sched.stats.spec_aborted == 0
    assert len(sched.queues["a"]) == 1  # "a" still parked, untouched


def test_example_overlap_kv_demo_smoke(setup):
    """The examples/serve_continuous_batching.py overlap demo runs end to
    end on the reduced model: every request finishes and the demo's stats
    ledger balances."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))
    try:
        from serve_continuous_batching import overlap_kv_demo
    finally:
        sys.path.pop(0)
    arch, params = setup
    done, st = overlap_kv_demo(arch, params, n_requests=8, verbose=False)
    assert len(done) == 8
    assert all(r.done for r in done)
    assert st.spec_dispatched == st.spec_committed + st.spec_aborted


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_stream_deterministic():
    s1 = SyntheticLMStream(1000, 32, 4, seed=9)
    s2 = SyntheticLMStream(1000, 32, 4, seed=9)
    np.testing.assert_array_equal(s1.batch_at(17)["tokens"], s2.batch_at(17)["tokens"])
    assert not np.array_equal(s1.batch_at(17)["tokens"], s1.batch_at(18)["tokens"])


def test_prefetch_loader_order_and_bound():
    stream = SyntheticLMStream(100, 8, 2, seed=1)
    loader = PrefetchLoader(stream, n_prefetch=3, max_steps=10)
    batches = list(loader)
    assert len(batches) == 10
    np.testing.assert_array_equal(batches[4]["tokens"], stream.batch_at(4)["tokens"])


def test_engine_pins_one_prefill_shape_per_template(setup):
    """template= admission pins the padding bucket: after a template's
    largest batch, every later admit dispatches the SAME compiled shape."""
    arch, params = setup
    eng = InferenceEngine(arch, params, n_lanes=8, max_prompt_len=16, max_len=48)
    rng = np.random.default_rng(7)
    reqs = _requests(6, rng, max_new=2)
    shape_a = eng.admit(reqs[:3], template="chat")     # bucket (4, plen)
    assert shape_a[0] == 4
    for r in reqs[:3]:
        eng.retire(r.lane)
    shape_b = eng.admit(reqs[3:4], template="chat")    # 1 request, pinned shape
    assert shape_b == shape_a
    assert eng.template_shapes["chat"] == shape_a
    for r in reqs[3:4]:
        eng.retire(r.lane)
    # an unrelated template sizes its own bucket from scratch
    shape_c = eng.admit(reqs[4:5], template="embed")
    assert shape_c[0] == 1
