"""AsyncQueryRuntime + batching strategies: decision semantics, ordering,
adaptivity, straggler re-submission, bounded-queue back-off."""
from __future__ import annotations

import threading
import time

import pytest

from repro.core.runtime import AsyncQueryRuntime
from repro.core.services import SimulatedDBService, TableService
from repro.core.strategies import (
    GrowingUpperThreshold,
    LowerThreshold,
    OneOrAll,
    PureAsync,
    PureBatch,
    from_name,
)

TABLES = {"t": {i: i * 3 for i in range(10_000)}}


# ---------------------------------------------------------------------------
# strategy.decide semantics (paper §5.2.3)
# ---------------------------------------------------------------------------


def test_pure_async_decides_one():
    s = PureAsync()
    assert s.decide(0, False) == 0
    assert s.decide(1, False) == 1
    assert s.decide(100, False) == 1


def test_pure_batch_waits_for_producer():
    s = PureBatch()
    assert s.decide(50, False) == 0  # not until the whole loop has submitted
    assert s.decide(50, True) == 50


def test_one_or_all():
    s = OneOrAll()
    assert s.decide(1, False) == 1
    assert s.decide(7, False) == 7


def test_lower_threshold():
    s = LowerThreshold(bt=3)
    assert s.decide(2, False) == 1   # at/below bt → individual
    assert s.decide(3, False) == 1
    assert s.decide(4, False) == 4   # above bt → take all
    with pytest.raises(ValueError):
        LowerThreshold(bt=2)  # paper: bt >= 3 (3 round trips per batch)


def test_growing_upper_threshold_doubles():
    s = GrowingUpperThreshold(initial_upper=4, bt=None)
    assert s.decide(3, False) == 3       # below upper → all
    assert s.decide(10, False) == 4      # capped at upper, upper doubles
    assert s.upper == 8
    assert s.decide(10, False) == 8      # next cap
    assert s.upper == 16
    s.reset()
    assert s.upper == 4


def test_growing_upper_with_lower():
    s = GrowingUpperThreshold(initial_upper=8, bt=3)
    assert s.decide(2, False) == 1       # under bt → individual
    assert s.decide(6, False) == 6


def test_from_name():
    assert isinstance(from_name("async"), PureAsync)
    assert isinstance(from_name("growing_upper", initial_upper=2), GrowingUpperThreshold)
    with pytest.raises(KeyError):
        from_name("nope")


# ---------------------------------------------------------------------------
# runtime behaviour
# ---------------------------------------------------------------------------


def test_submit_fetch_order_and_values():
    svc = TableService(TABLES)
    with AsyncQueryRuntime(svc, n_threads=4, strategy=OneOrAll()) as rt:
        handles = [rt.submit("t.lookup", (i,)) for i in range(200)]
        results = [rt.fetch(h) for h in handles]
    assert results == [i * 3 for i in range(200)]


def test_batching_actually_batches():
    svc = TableService(TABLES, latency=0.002)
    rt = AsyncQueryRuntime(svc, n_threads=2, strategy=LowerThreshold(bt=3))
    handles = [rt.submit("t.lookup", (i,)) for i in range(100)]
    rt.drain()
    assert svc.stats.batches >= 1
    assert svc.stats.batched_items + svc.stats.single_queries == 100
    # batch trace recorded sizes
    assert any(sz > 1 for _, sz in rt.stats.batch_trace)
    results = [rt.fetch(h) for h in handles]
    assert results == [i * 3 for i in range(100)]
    rt.shutdown()


def test_pure_batch_single_set_oriented_execution():
    svc = TableService(TABLES)
    rt = AsyncQueryRuntime(svc, n_threads=4, strategy=PureBatch())
    handles = [rt.submit("t.lookup", (i,)) for i in range(50)]
    rt.producer_done()
    results = [rt.fetch(h) for h in handles]
    rt.shutdown()
    assert results == [i * 3 for i in range(50)]
    assert svc.stats.batches == 1 and svc.stats.batched_items == 50
    assert svc.stats.single_queries == 0


def test_bounded_queue_backoff():
    svc = TableService(TABLES, latency=0.005)
    rt = AsyncQueryRuntime(svc, n_threads=1, strategy=PureAsync(), max_pending=4)
    t0 = time.perf_counter()
    handles = [rt.submit("t.lookup", (i,)) for i in range(20)]
    dt = time.perf_counter() - t0
    # submissions must have blocked (20 reqs, 5ms each, queue of 4)
    assert dt > 0.02
    rt.drain()
    assert [rt.fetch(h) for h in handles] == [i * 3 for i in range(20)]
    rt.shutdown()


def test_error_propagates_through_fetch():
    svc = TableService({"t": {}}, queries={"boom": lambda tables, p: 1 / 0})
    rt = AsyncQueryRuntime(svc, n_threads=1)
    h = rt.submit("boom", ())
    with pytest.raises(ZeroDivisionError):
        rt.fetch(h)
    rt.shutdown()


class _FlakyService(TableService):
    """First execution of each key hangs (straggler); retries are instant."""

    def __init__(self):
        super().__init__(TABLES)
        self._seen = set()
        self._lock2 = threading.Lock()

    def execute(self, query_name, params):
        with self._lock2:
            first = params not in self._seen
            self._seen.add(params)
        if first:
            time.sleep(0.25)
        return super().execute(query_name, params)


def test_straggler_resubmission():
    svc = _FlakyService()
    rt = AsyncQueryRuntime(svc, n_threads=3, strategy=PureAsync(),
                           straggler_timeout=0.05)
    h = rt.submit("t.lookup", (7,))
    val = rt.fetch(h)
    assert val == 21
    assert rt.stats.resubmissions >= 1
    rt.shutdown()


def test_simulated_db_cost_model():
    svc = SimulatedDBService(rtt=0.004, single_proc=0.001, batch_proc=0.0001,
                             batch_fixed=0.001, concurrency=4)
    t0 = time.perf_counter()
    svc.execute("q", (1,))
    single = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.execute_batch("q", [(i,) for i in range(50)])
    batch = time.perf_counter() - t0
    # batch of 50 ≈ 3 RTTs + fixed + 50·batch_proc  «  50 single requests
    assert batch < 50 * single
    assert svc.stats.round_trips == 1 + 3
