"""Seeded random HIR program generator for the differential harness.

The core generator is plain-``random`` (fully deterministic from a seed, no
third-party dependency) so the equivalence harness runs as tier-1 tests in
any environment; :mod:`hypothesis` strategies are layered on top when the
library is installed (``hir_programs()`` below), giving shrinking for free
in dev environments.

Generated programs mix every surface the transformer handles:

* straight-line arithmetic over a small integer domain,
* ``If`` guards (data-dependent predicates, both branches),
* (nested) ``Loop`` s over list inputs,
* queries with data-dependent and loop-carried parameters,
* ``Proc``/``Call`` — including procedures containing queries and whole
  query loops, so inline-then-fission gets exercised end to end,
* occasional effectful assigns (ordered observable emissions) that force
  the transformer to *refuse* fission — negative coverage.

Construction maintains a defined-variable scope so every read is preceded
by a write on every path (guarded writes to fresh names are followed by an
unconditional default first), keeping both the synchronous oracle and the
transformed program crash-free.  Query-bearing loops iterate lists of
8–12 items of which at least six pass the parity guards the generator
emits, so a fissioned loop always executes >= 4 queries — what makes the
"strictly fewer round trips" assertion non-vacuous (a batch costs 3 round
trips; see services.SimulatedDBService).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Optional

from repro.core.hir import (
    Assign,
    Call,
    If,
    Loop,
    Proc,
    Program,
    Query,
    collect_names,
)

__all__ = ["GeneratedProgram", "gen_program", "QUERY_NAMES", "db_compute"]

QUERY_NAMES = ("qa", "qb", "qc")


# ---------------------------------------------------------------------------
# Deterministic value domain
# ---------------------------------------------------------------------------
#
# All program values are small ints (lists of ints as loop iterables); all
# functions are total over ints and named, so program repr()s stay readable
# in failure reports.  The modulus keeps values bounded under repeated
# multiplication without ever colliding to a constant.

_MOD = 10007


def db_compute(query_name: str, params: tuple) -> int:
    """The simulated database's deterministic compute function: a distinct
    total function of (query, params) so result mix-ups are visible."""
    base = sum((i + 3) * int(v) for i, v in enumerate(params))
    off = {name: j + 1 for j, name in enumerate(QUERY_NAMES)}
    return (base * 7 + off.get(query_name, 0)) % _MOD


def _add(a: int, b: int) -> int:
    return (a + b) % _MOD


def _sub(a: int, b: int) -> int:
    return (a - b) % _MOD


def _mul(a: int, b: int) -> int:
    return (a * b) % _MOD


def _mix(a: int, b: int) -> int:
    return (a * 31 + b * 17 + 5) % _MOD


def _inc(a: int) -> int:
    return (a + 1) % _MOD


def _is_even(a: int) -> bool:
    return int(a) % 2 == 0


def _is_small(a: int) -> bool:
    return int(a) % 16 < 11


def _zero() -> int:
    return 0


_BINOPS = (_add, _sub, _mul, _mix)
_PREDS = (_is_even, _is_small)


# ---------------------------------------------------------------------------
# Generator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GeneratedProgram:
    """One generated trial: the program, concrete inputs, and the variable
    names whose final environment values are the observable output."""

    program: Program
    inputs: dict[str, Any]
    observe: tuple[str, ...]
    seed_note: str = ""


class _Gen:
    """Stateful single-program builder (one instance per generated program)."""

    def __init__(self, rng: random.Random, allow_procs: bool = True,
                 allow_effects: bool = True):
        self.rng = rng
        self.allow_procs = allow_procs
        self.allow_effects = allow_effects
        self.n_vars = 0
        self.n_queries = 0
        self.procs: list[Proc] = []

    def fresh_int(self) -> str:
        self.n_vars += 1
        return f"v{self.n_vars - 1}"

    def fresh_query_target(self) -> str:
        # q_-prefixed on purpose: programs must survive sharing the
        # transformer's own fresh-name shapes (regression: _FreshNames).
        self.n_queries += 1
        return f"q_{self.n_queries - 1}"

    # -- leaf statements ----------------------------------------------------
    def assign(self, scope: list[str], target: Optional[str] = None,
               guard: Optional[str] = None) -> Assign:
        rng = self.rng
        if target is None:
            target = self.fresh_int()
        if rng.random() < 0.15 or not scope:
            return Assign(target=target, fn=_zero, args=(), guard=guard)
        if rng.random() < 0.25:
            return Assign(target=target, fn=_inc,
                          args=(rng.choice(scope),), guard=guard)
        fn = rng.choice(_BINOPS)
        return Assign(target=target, fn=fn,
                      args=(rng.choice(scope), rng.choice(scope)),
                      guard=guard)

    def pred_assign(self, scope: list[str],
                    parity_only: bool = False) -> Assign:
        # Query guards are parity-only: generated lists carry >= 6 even
        # elements, so a guarded query still executes >= 4 times and the
        # round-trip win over the 3-trip batch stays strict.
        target = self.fresh_int()
        fn = _is_even if parity_only else self.rng.choice(_PREDS)
        return Assign(target=target, fn=fn,
                      args=(self.rng.choice(scope),))

    def query(self, scope: list[str], guard: Optional[str] = None) -> Query:
        rng = self.rng
        n_params = rng.choice((1, 1, 2))
        params = tuple(rng.choice(scope) for _ in range(n_params))
        return Query(target=self.fresh_query_target(),
                     query_name=rng.choice(QUERY_NAMES),
                     params=params, guard=guard)

    def effect(self, scope: list[str]) -> Assign:
        return Assign(target=None, fn=_inc, args=(self.rng.choice(scope),),
                      effect="log")

    # -- procedures ---------------------------------------------------------
    def make_scalar_proc(self, idx: int) -> Proc:
        """Straight-line proc: arithmetic around a query, scalar result."""
        rng = self.rng
        body: list = [Assign(target="t0", fn=_mix, args=("a", "b"))]
        if rng.random() < 0.5:
            body.append(Assign(target="t1", fn=_inc, args=("t0",)))
        else:
            body.append(Assign(target="t1", fn=rng.choice(_BINOPS),
                               args=("t0", "a")))
        body.append(Query(target="pr", query_name=rng.choice(QUERY_NAMES),
                          params=("t1",)))
        body.append(Assign(target="out", fn=_add, args=("pr", "t0")))
        return Proc(name=f"p{idx}", formals=("a", "b"), body=body,
                    result="out")

    def make_loop_proc(self, idx: int) -> Proc:
        """Proc whose body is a whole query loop over a list formal —
        inlining it inside (or outside) a caller loop is the thesis's
        procedure-boundary fission case."""
        rng = self.rng
        body: list = [
            Assign(target="acc", fn=_zero, args=()),
            Loop(item_var="k", iter_var="ks", body=[
                Query(target="r", query_name=rng.choice(QUERY_NAMES),
                      params=("k",)),
                Assign(target="acc", fn=_add, args=("acc", "r")),
            ]),
        ]
        return Proc(name=f"p{idx}", formals=("ks",), body=body, result="acc")

    # -- compound statements ------------------------------------------------
    def loop_body(self, item: str, outer_scope: list[str],
                  depth: int, lists: list[str]) -> list:
        """A loop body: guard computation, query (usually), accumulator
        updates, occasionally a call / nested loop / effect."""
        rng = self.rng
        scope = list(outer_scope) + [item]
        body: list = []
        # optional pre-query arithmetic (may be loop-carried via outer vars)
        for _ in range(rng.randrange(0, 3)):
            a = self.assign(scope)
            body.append(a)
            scope.append(a.target)
        # optional loop-carried accumulator update placed BEFORE the query
        # half the time (often fissionable, and makes loop-carried query
        # parameters possible) and after it otherwise (a loop-carried flow
        # crossing whenever something before the query reads it — refusal
        # coverage)
        acc_stmt = None
        accs = [v for v in outer_scope if v.startswith("v")]
        if accs:
            acc = rng.choice(accs)
            src = rng.choice([v for v in scope
                              if not v.startswith("q_")] or [item])
            acc_stmt = Assign(target=acc, fn=rng.choice((_add, _mix)),
                              args=(acc, src))
            if rng.random() < 0.5:
                body.append(acc_stmt)
                acc_stmt = None
        style = rng.random()
        if style < 0.10 and self.allow_effects:
            # effect + query in one loop -> transformer must refuse
            body.append(self.effect(scope))
            body.append(self.query(scope))
        elif style < 0.22 and self.procs and self.allow_procs:
            proc = rng.choice(self.procs)
            if proc.formals == ("ks",):
                args: tuple = (rng.choice(lists),)
            else:
                args = (rng.choice(scope), rng.choice(scope))
            target = self.fresh_int()
            body.append(Call(target=target, proc=proc, args=args))
            scope.append(target)
        elif style < 0.34 and depth < 1 and lists:
            inner_item = self.fresh_int()
            inner = Loop(item_var=inner_item, iter_var=rng.choice(lists),
                         body=self.loop_body(inner_item, scope, depth + 1,
                                             lists))
            body.append(inner)
        elif style < 0.46:
            # If around the query: Rule B must flatten it into guards; both
            # branches write the target so it is always defined
            g = self.pred_assign([item], parity_only=True)
            body.append(g)
            scope.append(g.target)
            q = self.query(scope)
            body.append(If(pred=g.target, then_body=[q],
                           else_body=[Assign(target=q.target, fn=_inc,
                                             args=(item,))]))
            scope.append(q.target)
        else:
            guard = None
            if rng.random() < 0.4:
                g = self.pred_assign([item], parity_only=True)
                body.append(g)
                scope.append(g.target)
                guard = g.target
            q = self.query(scope, guard=guard)
            body.append(q)
            if guard is None:
                scope.append(q.target)
            else:
                # guarded query target may be unset this iteration: only
                # use it behind the same guard
                body.append(Assign(target=self.fresh_int(), fn=_inc,
                                   args=(q.target,), guard=guard))
            if rng.random() < 0.35 and guard is None:
                # second query in the same loop (stays blocking after
                # fission — consumer-side execute path)
                body.append(self.query(scope))
        if acc_stmt is not None:
            body.append(acc_stmt)
        return body

    def gen(self) -> GeneratedProgram:
        rng = self.rng
        # ---- inputs: ints + int lists (stacked so parity guards pass on
        # at least six elements -> fissioned loops execute >= 4 queries)
        inputs: dict[str, Any] = {}
        int_inputs = [f"x{i}" for i in range(rng.randrange(2, 4))]
        for name in int_inputs:
            inputs[name] = rng.randrange(0, 50)
        lists = [f"L{i}" for i in range(rng.randrange(1, 3))]
        for name in lists:
            n = rng.randrange(8, 13)
            vals = [rng.randrange(0, 30) * 2 for _ in range(max(6, n - 2))]
            vals += [rng.randrange(0, 30) for _ in range(n - len(vals))]
            rng.shuffle(vals)
            inputs[name] = vals

        if self.allow_procs and rng.random() < 0.7:
            self.procs.append(self.make_scalar_proc(len(self.procs)))
        if self.allow_procs and rng.random() < 0.35:
            self.procs.append(self.make_loop_proc(len(self.procs)))

        scope = list(int_inputs)
        body: list = []
        # a couple of accumulators usable as loop-carried state
        for _ in range(2):
            a = self.assign(scope)
            body.append(a)
            scope.append(a.target)

        n_top = rng.randrange(3, 7)
        n_loops = 0
        for _ in range(n_top):
            roll = rng.random()
            if roll < 0.45 and n_loops < 2:
                n_loops += 1
                item = self.fresh_int()
                body.append(Loop(item_var=item, iter_var=rng.choice(lists),
                                 body=self.loop_body(item, scope, 0, lists)))
            elif roll < 0.6:
                g = self.pred_assign(scope)
                body.append(g)
                then_a = self.assign(scope, target=self.fresh_int())
                else_a = Assign(target=then_a.target, fn=_inc,
                                args=(rng.choice(scope),))
                body.append(If(pred=g.target, then_body=[then_a],
                               else_body=[else_a]))
                scope.append(then_a.target)
            elif roll < 0.72 and self.procs:
                proc = rng.choice(self.procs)
                if proc.formals == ("ks",):
                    args: tuple = (rng.choice(lists),)
                else:
                    args = (rng.choice(scope), rng.choice(scope))
                target = self.fresh_int()
                body.append(Call(target=target, proc=proc, args=args))
                scope.append(target)
            elif roll < 0.82:
                q = self.query(scope)
                body.append(q)
                scope.append(q.target)
            elif roll < 0.9 and self.allow_effects:
                body.append(self.effect(scope))
            else:
                a = self.assign(scope)
                body.append(a)
                scope.append(a.target)
        if n_loops == 0:
            # every program gets at least one query loop — the whole point
            item = self.fresh_int()
            body.append(Loop(item_var=item, iter_var=rng.choice(lists),
                             body=self.loop_body(item, scope, 0, lists)))

        prog = Program(body=body, inputs=tuple(int_inputs + lists))
        observe = tuple(sorted(collect_names(prog.body) | set(prog.inputs)))
        return GeneratedProgram(program=prog, inputs=inputs, observe=observe)


def gen_program(rng: random.Random, *, allow_procs: bool = True,
                allow_effects: bool = True) -> GeneratedProgram:
    """Generate one random HIR program with concrete inputs (deterministic
    in the ``rng`` state)."""
    return _Gen(rng, allow_procs=allow_procs,
                allow_effects=allow_effects).gen()


# ---------------------------------------------------------------------------
# Optional hypothesis layer
# ---------------------------------------------------------------------------

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True

    @st.composite
    def hir_programs(draw) -> GeneratedProgram:
        """Hypothesis strategy wrapping :func:`gen_program`: hypothesis
        drives (and shrinks) the seed, the plain-random core does the
        structured generation."""
        seed = draw(st.integers(min_value=0, max_value=2**32 - 1))
        return gen_program(random.Random(seed))

except ImportError:  # degrade gracefully: plain-random core still works
    HAVE_HYPOTHESIS = False

    def hir_programs():  # type: ignore[misc]
        """Placeholder that fails loudly if used without hypothesis."""
        raise RuntimeError(
            "hypothesis is not installed; use gen_program(random.Random(s))")
