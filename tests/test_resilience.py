"""Failure-domain hardening: fault injection, retry/backoff/deadline,
batch fission-retry error isolation, circuit breaking, and crash-safe
lane recovery in the serving scheduler.

The governing invariant is the paper's exception-semantics equivalence,
extended to failures the paper never had to survive: every submitted
request either completes with the value the fault-free run produces, or
raises exactly ITS OWN exception at ITS OWN fetch point — never someone
else's error, never a hang, never a lost or double delivery.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import Counter

import pytest

from repro.core.concurrency import QuotaGate  # noqa: F401 — API surface
from repro.core.faults import (
    ChaosEngine,
    ChaosPlan,
    ChaosService,
    InjectedFault,
    InjectedParamError,
    chaos_seed,
)
from repro.core.lane_policy import LanePolicy
from repro.core.resilience import (
    CircuitBreaker,
    DeadlineExceeded,
    FailureDomain,
    LaneError,
    LaneFailedError,
    Resilience,
    RetryBudget,
    RetryPolicy,
    ServiceCardinalityError,
    hash_unit,
)
from repro.core.runtime import AsyncQueryRuntime
from repro.core.strategies import AdaptiveCost, OneOrAll, PureAsync
from repro.serving.engine import KVPartition
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # CI installs hypothesis (pip install -e .[dev])
    HAVE_HYPOTHESIS = False


TABLES = {"t": {i: i * 10 for i in range(512)}}


def _table_service():
    from repro.core.services import TableService
    return TableService(TABLES)


# --------------------------------------------------------------- primitives
def test_hash_unit_is_deterministic_and_uniform_ish():
    a = hash_unit(7, "poison", "t.lookup", (3,))
    b = hash_unit(7, "poison", "t.lookup", (3,))
    assert a == b and 0.0 <= a < 1.0
    draws = [hash_unit(7, "x", i) for i in range(400)]
    assert 0.3 < sum(d < 0.5 for d in draws) / 400 < 0.7


def test_retry_budget_spends_and_earns():
    b = RetryBudget(cap=2.0, earn=0.5)
    assert b.try_spend() and b.try_spend()
    assert not b.try_spend()  # bucket empty: retry storm stopped
    b.earn()
    b.earn()
    assert b.try_spend()
    assert b.tokens == pytest.approx(0.0)


def test_retry_policy_backoff_grows_capped_and_jitters_down():
    p = RetryPolicy(backoff_base=0.001, backoff_multiplier=2.0,
                    backoff_max=0.003, jitter=0.0)
    assert p.backoff_for(1) == pytest.approx(0.001)
    assert p.backoff_for(2) == pytest.approx(0.002)
    assert p.backoff_for(5) == pytest.approx(0.003)  # capped
    pj = RetryPolicy(backoff_base=0.001, jitter=0.5)
    d = pj.backoff_for(1, key="lane")
    assert 0.0005 <= d <= 0.001
    assert d == pj.backoff_for(1, key="lane")  # deterministic per key


def test_nonretryable_is_not_retried_by_policy():
    p = RetryPolicy()
    assert p.is_retryable(RuntimeError("x"))
    assert not p.is_retryable(DeadlineExceeded("q", 1.0, 2.0))
    assert not p.is_retryable(ServiceCardinalityError("q", 2, 3))
    assert not p.is_retryable(InjectedParamError("q", (1,)))


def test_circuit_breaker_state_machine():
    """closed → (threshold failures) → open/shed → half-open probe →
    closed; a failed probe re-opens.  The transitions list records the
    whole walk."""
    trips = []
    b = CircuitBreaker(threshold=2, cooldown=0.01, probes=1,
                       on_trip=lambda: trips.append(1))
    assert b.allow() == "closed"
    b.record_failure()
    assert b.state == "closed"
    b.record_failure()  # trips
    assert b.state == "open" and b.trips == 1 and trips == [1]
    assert b.allow() == "shed"
    time.sleep(0.012)
    assert b.allow() == "probe"  # half-open: one trial goes through
    assert b.allow() == "shed"   # concurrent traffic keeps shedding
    b.record_failure()           # failed probe: straight back to open
    assert b.state == "open" and b.trips == 2
    time.sleep(0.012)
    assert b.allow() == "probe"
    b.record_success()
    assert b.state == "closed" and b.allow() == "closed"
    assert b.transitions == ["open", "half_open", "open", "half_open",
                             "closed"]


def test_failure_domain_lazily_builds_per_key_state():
    fd = FailureDomain(Resilience(breaker_threshold=3))
    assert fd.breaker("a") is fd.breaker("a")
    assert fd.breaker("a") is not fd.breaker("b")
    assert fd.budget("a") is fd.budget("a")
    assert "a" in fd.snapshot()["breakers"]
    assert FailureDomain(Resilience(breaker_threshold=None)).breaker("a") is None


# ------------------------------------------------------------ chaos plumbing
def test_chaos_plan_is_pure_in_the_seed():
    p1 = ChaosPlan(seed=5, fail_rate=0.3)
    p2 = ChaosPlan(seed=5, fail_rate=0.3)
    ids = [("t.lookup", (i,)) for i in range(64)]
    assert [p1.poisoned(*i) for i in ids] == [p2.poisoned(*i) for i in ids]
    assert any(p1.poisoned(*i) for i in ids)
    assert not all(p1.poisoned(*i) for i in ids)


def test_chaos_transient_fails_then_succeeds():
    plan = ChaosPlan(seed=1, transient_rate=1.0, transient_repeats=2)
    svc = ChaosService(_table_service(), plan)
    with pytest.raises(InjectedFault):
        svc.execute("t.lookup", (3,))
    with pytest.raises(InjectedFault):
        svc.execute("t.lookup", (3,))
    assert svc.execute("t.lookup", (3,)) == 30  # third attempt lands


def test_chaos_seed_env(monkeypatch):
    monkeypatch.setenv("REPRO_CHAOS_SEED", "17")
    assert chaos_seed() == 17
    monkeypatch.delenv("REPRO_CHAOS_SEED")
    assert chaos_seed(3) == 3


# ----------------------------------------------------- runtime: retry path
def test_runtime_absorbs_transient_faults():
    plan = ChaosPlan(seed=2, transient_rate=1.0, transient_repeats=1)
    svc = ChaosService(_table_service(), plan)
    with AsyncQueryRuntime(svc, n_threads=2,
                           resilience=Resilience()) as rt:
        hs = [rt.submit("t.lookup", (i,)) for i in range(8)]
        vals = [rt.fetch(h) for h in hs]
    assert vals == [i * 10 for i in range(8)]
    assert int(rt.stats.retries) > 0
    assert int(rt.stats.failures) > 0


def test_runtime_without_resilience_is_legacy_fail_fast():
    class _Boom:
        def execute(self, q, p):
            raise RuntimeError("boom")

        def execute_batch(self, q, ps):
            raise RuntimeError("boom")

    with AsyncQueryRuntime(_Boom(), n_threads=1) as rt:
        h = rt.submit("q", (1,))
        with pytest.raises(RuntimeError, match="boom"):
            rt.fetch(h)
    assert int(rt.stats.retries) == 0


# -------------------------------------------- fission-retry error isolation
def test_fission_isolates_poisoned_params():
    """A batch poisoned by SOME params splits until each culprit fails
    alone: poisoned handles raise their OWN InjectedParamError, innocent
    co-batched handles still get values."""
    plan = ChaosPlan(seed=3, fail_rate=0.25)
    svc = ChaosService(_table_service(), plan)
    ids = list(range(48))
    poisoned = {i for i in ids if plan.poisoned("t.lookup", (i,))}
    assert poisoned and len(poisoned) < len(ids)  # a mixed batch exists
    with AsyncQueryRuntime(svc, n_threads=1, strategy=OneOrAll(),
                           dedup=False,
                           resilience=Resilience()) as rt:
        hs = {i: rt.submit("t.lookup", (i,)) for i in ids}
        for i, h in hs.items():
            if i in poisoned:
                with pytest.raises(InjectedParamError) as exc:
                    rt.fetch(h)
                assert exc.value.params == (i,)  # its OWN exception
            else:
                assert rt.fetch(h) == i * 10
    assert int(rt.stats.fissions) > 0
    assert int(rt.stats.completed) == len(ids)


def test_fission_disabled_poisons_whole_batch():
    plan = ChaosPlan(seed=3, fail_rate=0.25)
    svc = ChaosService(_table_service(), plan)
    ids = list(range(16))
    poisoned = {i for i in ids if plan.poisoned("t.lookup", (i,))}
    assert poisoned
    res = Resilience(fission=False, retry=RetryPolicy(max_attempts=1))
    with AsyncQueryRuntime(svc, n_threads=1, strategy=OneOrAll(),
                           dedup=False, resilience=res) as rt:
        hs = [rt.submit("t.lookup", (i,)) for i in ids]
        errs = 0
        for h in hs:
            try:
                rt.fetch(h)
            except Exception:
                errs += 1
    assert errs >= len(poisoned)  # innocents die with the batch
    assert int(rt.stats.fissions) == 0


# ------------------------------------- satellite: dedup'd failure delivery
class _RaisingBatchService:
    """execute_batch always raises; execute returns normally — isolates
    the batched fan-out failure path."""

    def __init__(self, exc):
        self.exc = exc

    def execute(self, query_name, params):
        return params[0]

    def execute_batch(self, query_name, params_list):
        raise self.exc


def test_dedup_failure_delivered_once_per_waiter_no_stranding():
    """Regression: an exception raised while fanning a dedup'd batch out
    must reach EVERY waiter exactly once — a mid-fanout raise that skips
    the stripe CV would strand concurrent fetchers forever."""
    svc = _RaisingBatchService(RuntimeError("db down"))
    with AsyncQueryRuntime(svc, n_threads=1, strategy=OneOrAll()) as rt:
        # Same params: handles dedup onto one entry; distinct params force
        # a real batch so execute_batch (the raiser) runs.
        hs = [rt.submit("t.lookup", (1,)) for _ in range(4)]
        hs += [rt.submit("t.lookup", (2,))]
        outcomes: list = [None] * len(hs)

        def fetch(i, h):
            try:
                outcomes[i] = ("ok", rt.fetch(h))
            except BaseException as e:  # noqa: BLE001
                outcomes[i] = ("err", e)

        ts = [threading.Thread(target=fetch, args=(i, h), daemon=True)
              for i, h in enumerate(hs)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=10.0)
            assert not t.is_alive(), "a fetcher was stranded"
    kinds = Counter(k for k, _ in outcomes)
    assert kinds == Counter({"err": len(hs)})
    assert all(str(e) == "db down" for _, e in outcomes)
    assert int(rt.stats.completed) == len(hs)  # exactly once per waiter


def test_wrong_cardinality_service_raises_typed_error_not_hang():
    class _Short:
        def execute(self, q, p):
            return p[0]

        def execute_batch(self, q, ps):
            return [0]  # wrong length: alignment would be a guess

    res = Resilience(fission=False, retry=RetryPolicy(max_attempts=2))
    with AsyncQueryRuntime(_Short(), n_threads=1, strategy=OneOrAll(),
                           dedup=False, resilience=res) as rt:
        hs = [rt.submit("t.lookup", (i,)) for i in range(3)]
        for h in hs:
            with pytest.raises(ServiceCardinalityError):
                rt.fetch(h)
    assert int(rt.stats.retries) == 0  # non-retryable: no blind retry


# ------------------------------------------------------------ deadlines
class _GluedService:
    """Blocks every call until released (deadline / shed testing)."""

    def __init__(self):
        self.release = threading.Event()

    def execute(self, query_name, params):
        self.release.wait(timeout=10.0)
        return params[0]

    def execute_batch(self, query_name, params_list):
        self.release.wait(timeout=10.0)
        return [p[0] for p in params_list]


def test_deadline_exceeded_is_typed_and_at_the_fetch_point():
    svc = _GluedService()
    rt = AsyncQueryRuntime(svc, n_threads=1,
                           resilience=Resilience(deadline=0.05))
    try:
        h = rt.submit("q", (1,))
        t0 = time.monotonic()
        with pytest.raises(DeadlineExceeded) as exc:
            rt.fetch(h)
        assert time.monotonic() - t0 < 5.0
        assert exc.value.query_name == "q"
        assert exc.value.waited >= 0.0
        # resolved exactly once: a second fetch re-raises, no double count
        with pytest.raises(DeadlineExceeded):
            rt.fetch(h)
        assert int(rt.stats.deadline_expired) == 1
        assert int(rt.stats.completed) == 1
    finally:
        svc.release.set()
        rt.shutdown()


def test_per_submit_deadline_overrides_config():
    svc = _GluedService()
    rt = AsyncQueryRuntime(svc, n_threads=1, resilience=Resilience())
    try:
        h = rt.submit("q", (1,), deadline=0.05)
        with pytest.raises(DeadlineExceeded):
            rt.fetch(h)
    finally:
        svc.release.set()
        rt.shutdown()


def test_deadline_releases_quota_slots():
    svc = _GluedService()
    policy = LanePolicy(tenant_quotas={"w": 1})
    rt = AsyncQueryRuntime(svc, n_threads=1, policy=policy,
                           resilience=Resilience(deadline=0.05))
    try:
        h1 = rt.submit("q", (1,), tenant="w")
        with pytest.raises(DeadlineExceeded):
            rt.fetch(h1)
        # the expired request's slot is back: a second submit must not block
        done = threading.Event()

        def second():
            rt.submit("q", (2,), tenant="w")
            done.set()

        threading.Thread(target=second, daemon=True).start()
        assert done.wait(timeout=5.0), "deadline leaked the tenant slot"
    finally:
        svc.release.set()
        rt.shutdown()


# ------------------------------------------------------- circuit breaking
class _FlakyThenHealthyService:
    """Fails every call until ``heal`` is set, then succeeds."""

    def __init__(self):
        self.healed = threading.Event()
        self.calls = 0

    def execute(self, query_name, params):
        self.calls += 1
        if not self.healed.is_set():
            raise RuntimeError("flaky")
        return params[0] * 10

    def execute_batch(self, query_name, params_list):
        return [self.execute(query_name, p) for p in params_list]


def test_breaker_trips_sheds_then_recovers():
    svc = _FlakyThenHealthyService()
    res = Resilience(
        retry=RetryPolicy(max_attempts=1, retry_budget=4.0),
        breaker_threshold=2, breaker_cooldown=0.02, fission=False)
    rt = AsyncQueryRuntime(svc, n_threads=1, resilience=res)
    try:
        lane_key = rt._lane_key("q")
        for i in range(4):  # trip the breaker (threshold 2)
            with pytest.raises(RuntimeError):
                rt.fetch(rt.submit("q", (i,)))
        br = rt._fd.breaker(lane_key)
        assert br.state == "open"
        assert int(rt.stats.breaker_trips) >= 1
        # while open, submissions shed to the direct path (still fail —
        # the service is still sick — but without batch/retry machinery)
        with pytest.raises(RuntimeError):
            rt.fetch(rt.submit("q", (9,)))
        assert int(rt.stats.shed_submissions) >= 1
        svc.healed.set()
        time.sleep(0.03)  # past the cooldown: next call is the probe
        deadline = time.monotonic() + 5.0
        while br.state != "closed" and time.monotonic() < deadline:
            assert rt.fetch(rt.submit("q", (5,))) == 50
        assert br.state == "closed"  # probe success closed it
        assert "half_open" in br.transitions and "closed" in br.transitions
        assert rt.fetch(rt.submit("q", (7,))) == 70
    finally:
        rt.shutdown()


def test_adaptive_cost_failure_penalty_raises_threshold():
    s = AdaptiveCost()
    s.reset()
    # T(1)=0.002 singles; T(n)=0.002+n*0.0005 batches — an exact fit, so
    # the learned threshold is stable under further identical evidence.
    for _ in range(6):
        s.observe(1, 0.002)
        s.observe(4, 0.004)
        s.observe(8, 0.006)
    base = s.threshold
    assert base is not None and base != float("inf")
    for _ in range(8):
        s.observe_failure(0.004)
    assert s.threshold > base  # failing lanes batch less eagerly
    assert s.failure_penalty > 0.0 and s.failures == 8
    for _ in range(64):
        s.observe(4, 0.004)  # successes decay the penalty back down
        s.observe(8, 0.006)
    assert s.threshold == pytest.approx(base, rel=0.05)


# ----------------------------------------- scheduler: crash-safe recovery
class _CrashStubEngine:
    """_SplitStubEngine plus scripted decode LaneErrors + admit faults."""

    def __init__(self, n_lanes=2, kv_shares=None,
                 crash_on_ticks=(), admit_failures=0):
        self.partition = KVPartition(n_lanes, kv_shares)
        self.active: dict = {}
        self.ticks = 0
        self.crash_on_ticks = set(crash_on_ticks)
        self.admit_failures = admit_failures

    @property
    def kv(self):
        return self.partition

    @property
    def n_free(self):
        return self.partition.n_free

    def n_free_for(self, template):
        return self.partition.n_free_for(template)

    def prefill_dispatch(self, requests, template=None):
        return dataclasses.make_dataclass("S", ["template", "requests"])(
            template, list(requests))

    def commit_prefill(self, staged, n=None):
        reqs = staged.requests if n is None else staged.requests[:n]
        for r in reqs:
            r.lane = self.partition.alloc(staged.template)
            r.generated.append(0)
            self.active[r.lane] = r
        return (len(staged.requests), 8)

    def admit(self, requests, template=None):
        if self.admit_failures > 0:
            self.admit_failures -= 1
            raise InjectedFault("admit fault")
        return self.commit_prefill(self.prefill_dispatch(requests, template))

    def decode_tick(self):
        self.ticks += 1
        if self.ticks in self.crash_on_ticks and self.active:
            lane = min(self.active)
            raise LaneError(lane, reason=f"scripted crash @ {self.ticks}")
        return {lane: 1 for lane in self.active}

    def retire(self, lane):
        self.active.pop(lane, None)
        self.partition.release(lane)


def _reqs(n, tmpl="default", max_new=3):
    import numpy as np
    return [Request(rid=i, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=max_new, template=tmpl) for i in range(n)]


def test_decode_crash_quarantines_lane_and_request_completes():
    eng = _CrashStubEngine(n_lanes=2, crash_on_ticks=(2,))
    sched = ContinuousBatchingScheduler(
        eng, strategy=PureAsync(),
        resilience=Resilience(quarantine_ticks=2))
    reqs = _reqs(2)
    for r in reqs:
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained(max_ticks=200)
    assert len(done) == 2
    assert all(len(r.generated) == r.max_new_tokens for r in reqs)
    assert sched.stats.quarantined == 1
    assert sched.stats.decode_retries >= 1
    assert sched.stats.requeued >= 1
    assert not eng.partition.quarantined  # released after the cooldown
    assert eng.partition.n_free == 2


def test_quarantine_holds_lane_out_until_cooldown():
    part = KVPartition(3, {"a": 1})
    lane = part.alloc("a")
    part.release(lane)
    part.quarantine(lane)
    assert lane in part.quarantined
    assert part.n_free == 2
    assert part.n_free_for("a") == 2  # its reserved lane is held out
    with pytest.raises(ValueError):
        part.quarantine(99)  # not free: refuse, don't corrupt pools
    part.unquarantine(lane)
    assert part.n_free == 3 and not part.quarantined
    part.unquarantine(lane)  # idempotent
    assert part.n_free == 3


def test_admit_faults_retry_then_land():
    eng = _CrashStubEngine(n_lanes=2, admit_failures=2)
    sched = ContinuousBatchingScheduler(
        eng, strategy=PureAsync(), resilience=Resilience())
    for r in _reqs(2):
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained(max_ticks=100)
    assert len(done) == 2
    assert sched.stats.prefill_retries >= 1


def test_all_failing_lane_raises_named_error():
    """Satellite: an all-failing lane surfaces as LaneFailedError naming
    the template and the last exception — not a generic stuck-lane
    timeout thousands of ticks later."""
    eng = _CrashStubEngine(n_lanes=2, admit_failures=10_000)
    sched = ContinuousBatchingScheduler(
        eng, strategy=PureAsync(),
        resilience=Resilience(lane_fail_threshold=4,
                              retry=RetryPolicy(max_attempts=1)))
    for r in _reqs(1, tmpl="broken"):
        sched.submit(r)
    sched.producer_done()
    with pytest.raises(LaneFailedError) as exc:
        sched.run_until_drained(max_ticks=10_000)
    assert exc.value.template == "broken"
    assert isinstance(exc.value.last_error, InjectedFault)
    assert exc.value.failures >= 4


def test_spec_crash_aborts_bet_cleanly():
    class _SpecCrashEngine(_CrashStubEngine):
        def __init__(self):
            super().__init__(n_lanes=1)
            self.spec_dispatches = 0

        def prefill_dispatch(self, requests, template=None):
            # crash the FIRST dispatch that runs on the speculation
            # thread; synchronous admission (same method, main thread)
            # stays healthy — isolates the spec-crash abort path.
            if threading.current_thread().name == "cbs-spec-prefill":
                self.spec_dispatches += 1
                if self.spec_dispatches == 1:
                    raise InjectedFault("spec thread crash")
            return super().prefill_dispatch(requests, template)

    eng = _SpecCrashEngine()
    sched = ContinuousBatchingScheduler(
        eng, strategy=PureAsync(), overlap=True,
        resilience=Resilience())
    for r in _reqs(2):
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained(max_ticks=200)
    assert len(done) == 2  # the crashed bet's request was re-queued + served
    assert sched.stats.spec_crashes == 1
    assert sched.stats.spec_aborted >= 1


def test_spec_crash_without_resilience_still_raises():
    class _SpecCrashEngine(_CrashStubEngine):
        def prefill_dispatch(self, requests, template=None):
            raise InjectedFault("spec thread crash")

    sched = ContinuousBatchingScheduler(
        _SpecCrashEngine(n_lanes=1), strategy=PureAsync(), overlap=True)
    for r in _reqs(2):
        sched.submit(r)
    sched.producer_done()
    with pytest.raises(InjectedFault):
        sched.run_until_drained(max_ticks=50)


def test_chaos_engine_injects_decode_faults_deterministically():
    plan = ChaosPlan(seed=4, decode_fault_rate=0.3)
    eng = ChaosEngine(_CrashStubEngine(n_lanes=2), plan)
    sched = ContinuousBatchingScheduler(
        eng, strategy=OneOrAll(),
        resilience=Resilience(quarantine_ticks=1))
    for r in _reqs(4, max_new=4):
        sched.submit(r)
    sched.producer_done()
    done = sched.run_until_drained(max_ticks=500)
    assert len(done) == 4
    assert eng.injected_decode_faults > 0
    assert sched.stats.quarantined == eng.injected_decode_faults


# --------------------------------------------------- chaos property sweep
def _chaos_sweep(seed: int, n_producers: int = 16, per_producer: int = 12):
    """Seeded failures + latency across concurrent producers: assert the
    delivery invariants the failure domain guarantees."""
    plan = ChaosPlan(seed=seed, fail_rate=0.12, transient_rate=0.2,
                     transient_repeats=1, latency_rate=0.1, latency=0.001)
    svc = ChaosService(_table_service(), plan)
    policy = LanePolicy(tenant_quotas={f"w{i}": 4 for i in range(n_producers)})
    rt = AsyncQueryRuntime(svc, n_threads=4, policy=policy,
                           resilience=Resilience())
    results: dict = {}
    lock = threading.Lock()

    def producer(w: int):
        local = []
        for j in range(per_producer):
            i = (w * per_producer + j) % 256
            h = rt.submit("t.lookup", (i,), tenant=f"w{w}")
            local.append((i, h))
        for i, h in local:
            try:
                out = ("ok", rt.fetch(h))
            except InjectedParamError as e:
                out = ("poisoned", e.params)
            except BaseException as e:  # noqa: BLE001
                out = ("other", e)
            with lock:
                results[(w, i)] = out

    threads = [threading.Thread(target=producer, args=(w,), daemon=True)
               for w in range(n_producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
        assert not t.is_alive(), "a producer hung under chaos"
    rt.drain()
    rt.shutdown()

    # no lost or duplicated deliveries
    assert len(results) == n_producers * per_producer
    assert int(rt.stats.completed) == int(rt.stats.submitted)
    for (w, i), (kind, val) in results.items():
        if plan.poisoned("t.lookup", (i,)):
            # a poisoned request raises exactly ITS OWN injected error
            assert kind == "poisoned" and val == (i,), (w, i, kind, val)
        else:
            assert kind == "ok" and val == i * 10, (w, i, kind, val)
    # every admission slot came back: quota gates read zero
    for gate in rt._tenant_gates.values():
        assert gate.count == 0
    for gate in rt._lane_gates.values():
        assert gate.count == 0
    return rt


@pytest.mark.parametrize("seed", [chaos_seed(0), chaos_seed(0) + 101])
def test_chaos_sweep_delivery_invariants(seed):
    rt = _chaos_sweep(seed)
    assert int(rt.stats.failures) > 0  # chaos actually bit


def test_chaos_breaker_observes_full_cycle():
    """Under a burst of failures the breaker trips, sheds, half-opens and
    closes — observed through the runtime's own failure domain."""
    svc = _FlakyThenHealthyService()
    res = Resilience(retry=RetryPolicy(max_attempts=1),
                     breaker_threshold=2, breaker_cooldown=0.01,
                     fission=False)
    rt = AsyncQueryRuntime(svc, n_threads=1, resilience=res)
    try:
        for i in range(3):
            with pytest.raises(RuntimeError):
                rt.fetch(rt.submit("q", (i,)))
        svc.healed.set()
        br = rt._fd.breaker(rt._lane_key("q"))
        deadline = time.monotonic() + 5.0
        while br.state != "closed" and time.monotonic() < deadline:
            time.sleep(0.012)
            try:
                rt.fetch(rt.submit("q", (1,)))
            except RuntimeError:
                pass
        seq = br.transitions
        assert "open" in seq and "half_open" in seq and "closed" in seq
        assert seq.index("open") < seq.index("half_open") < len(seq)
    finally:
        rt.shutdown()


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16))
    def test_chaos_property_any_seed(seed):
        """Property form of the sweep: ANY seed preserves the delivery
        invariants (hypothesis shrinks a failing schedule to a minimal
        seed)."""
        _chaos_sweep(seed, n_producers=4, per_producer=6)

else:

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_chaos_property_any_seed():
        pass


def test_dry_retry_budget_never_leaks_single_entry_transients():
    """The retry budget caps batch re-execution amplification, not
    exception semantics: with the bucket fully drained, a size-1
    submission's transient fault must still clear through its bounded
    in-place retries instead of leaking to the fetcher (the load-
    dependent chaos-sweep flake this pins down)."""
    plan = ChaosPlan(seed=11, transient_rate=1.0, transient_repeats=1)
    svc = ChaosService(_table_service(), plan)
    rt = AsyncQueryRuntime(svc, n_threads=1, resilience=Resilience())
    try:
        budget = rt._fd.budget(rt._lane_key("t.lookup"))
        while budget.try_spend():
            pass
        assert not budget.try_spend()
        assert rt.fetch(rt.submit("t.lookup", (9,))) == 90
        assert int(rt.stats.retries) >= 1
    finally:
        rt.shutdown()
