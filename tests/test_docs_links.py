"""Docs gate, tier-1 edition: the CI ``docs`` job runs
``tools/check_links.py``; this wraps the same checker so a broken
relative link (or a doc the tentpole promised going missing) fails
locally before CI ever sees it."""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

from check_links import broken_links, iter_markdown  # noqa: E402


def test_no_broken_relative_links():
    assert broken_links(ROOT) == []


def test_docs_layer_exists_and_is_scanned():
    scanned = {p.relative_to(ROOT).as_posix() for p in iter_markdown(ROOT)}
    for required in ("docs/ARCHITECTURE.md", "docs/TUNING.md", "ROADMAP.md",
                     "benchmarks/README.md"):
        assert required in scanned, f"{required} missing from the docs gate"


def test_checker_flags_a_broken_link(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "a.md").write_text(
        "[ok](a.md) [dead](missing.md) [ext](https://x) [anchor](#sec)")
    problems = broken_links(tmp_path)
    assert problems == ["docs/a.md: missing.md"]
