"""Paper transformation rules on the host IR: Rule A, Rule B, reordering,
nested loops, applicability — each checked by executing original vs
transformed programs against the same deterministic service, plus
hypothesis property tests over randomly generated programs."""
from __future__ import annotations


import pytest
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade gracefully: property tests skip, rest run
    HAVE_HYPOTHESIS = False

from repro.core.hir import (
    Assign,
    FissionError,
    If,
    Interpreter,
    Loop,
    Program,
    Query,
    analyze_applicability,
    apply_rule_a,
    apply_rule_b,
    build_ddg,
    transform_program,
)
from repro.core.runtime import AsyncQueryRuntime
from repro.core.services import TableService
from repro.core.strategies import (
    GrowingUpperThreshold,
    LowerThreshold,
    OneOrAll,
    PureAsync,
)

TABLES = {"part": {i: i * 10 + 1 for i in range(1000)}}


def add(a, b):
    return a + b


def run_both(prog, inputs, strategy=None, overlap=False, n_threads=4):
    base = Interpreter(TableService(TABLES)).run(prog, dict(inputs))
    t = transform_program(prog, overlap=overlap)
    rt = AsyncQueryRuntime(TableService(TABLES), n_threads=n_threads,
                           strategy=strategy or OneOrAll())
    interp = Interpreter(rt)
    out = interp.run(t, dict(inputs))
    rt.drain()
    rt.shutdown()
    return base, out


# ---------------------------------------------------------------------------
# paper examples
# ---------------------------------------------------------------------------


def example2_program():
    """Paper Example 2: query + dependent statement in a loop."""
    return Program(
        inputs=("categories", "sum"),
        body=[
            Loop(item_var="category", iter_var="categories", body=[
                Query(target="partCount", query_name="part.lookup",
                      params=("category",)),
                Assign(target="sum", fn=add, args=("sum", "partCount")),
            ]),
        ],
    )


def test_example2_rule_a():
    base, out = run_both(example2_program(), {"categories": list(range(50)), "sum": 0})
    assert base["sum"] == out["sum"]


def test_example2_overlap():
    base, out = run_both(example2_program(), {"categories": list(range(50)), "sum": 0},
                         overlap=True)
    assert base["sum"] == out["sum"]


@pytest.mark.parametrize("strategy", [
    PureAsync(), OneOrAll(), LowerThreshold(bt=3),
    GrowingUpperThreshold(initial_upper=4, bt=3),
])
def test_example2_all_strategies(strategy):
    base, out = run_both(example2_program(),
                         {"categories": list(range(60)), "sum": 0},
                         strategy=strategy)
    assert base["sum"] == out["sum"]


def test_example6_rule_b_conditional_query():
    """Paper Example 6: query under an if; Rule B then Rule A."""
    prog = Program(
        inputs=("items", "acc", "emitted"),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Assign(target="v", fn=lambda i: i % 3, args=("i",)),
                Assign(target="is0", fn=lambda v: v == 0, args=("v",)),
                If(pred="is0", then_body=[
                    Query(target="v", query_name="part.lookup", params=("i",)),
                    Assign(target="emitted", fn=add, args=("emitted", "v")),
                ]),
                Assign(target="acc", fn=add, args=("acc", "v")),
            ]),
        ],
    )
    inputs = {"items": list(range(40)), "acc": 0, "emitted": 0}
    base, out = run_both(prog, inputs)
    assert base["acc"] == out["acc"]
    assert base["emitted"] == out["emitted"]


def test_example4_reordering():
    """Paper Example 4/5: accumulator write after the query forces
    statement reordering before fission applies."""
    prog = Program(
        inputs=("cats", "total", "maxv"),
        body=[
            Loop(item_var="c", iter_var="cats", body=[
                Query(target="n", query_name="part.lookup", params=("c",)),
                Assign(target="total", fn=add, args=("total", "n")),
                Assign(target="maxv", fn=max, args=("maxv", "n")),
            ]),
        ],
    )
    inputs = {"cats": list(range(30)), "total": 0, "maxv": -1}
    base, out = run_both(prog, inputs)
    assert base["total"] == out["total"] and base["maxv"] == out["maxv"]
    rep = analyze_applicability(prog)
    assert rep["transformed"] == rep["opportunities"] == 1


def test_true_dependence_cycle_rejected():
    """Query key depends on previous iteration's query result."""
    prog_loop = Loop(item_var="i", iter_var="items", body=[
        Query(target="r", query_name="part.lookup", params=("key",)),
        Assign(target="key", fn=lambda r: r % 100, args=("r",)),
    ])
    with pytest.raises(FissionError):
        apply_rule_a(prog_loop)
    # transform_program leaves it untouched and running
    prog = Program(inputs=("items", "key"), body=[prog_loop])
    inputs = {"items": list(range(10)), "key": 5}
    base = Interpreter(TableService(TABLES)).run(prog, dict(inputs))
    t = transform_program(prog)
    out = Interpreter(TableService(TABLES)).run(t, dict(inputs))
    assert base["key"] == out["key"]
    rep = analyze_applicability(prog)
    assert rep["transformed"] == 0 and rep["opportunities"] == 1


def test_nested_loops():
    prog = Program(
        inputs=("outer", "inner", "total"),
        body=[
            Loop(item_var="i", iter_var="outer", body=[
                Loop(item_var="j", iter_var="inner", body=[
                    Assign(target="k", fn=lambda i, j: (i * 7 + j) % 1000,
                           args=("i", "j")),
                    Query(target="x", query_name="part.lookup", params=("k",)),
                    Assign(target="total", fn=add, args=("total", "x")),
                ]),
            ]),
        ],
    )
    inputs = {"outer": list(range(6)), "inner": list(range(5)), "total": 0}
    base, out = run_both(prog, inputs)
    assert base["total"] == out["total"]


def test_updates_db_not_transformed():
    loop = Loop(item_var="i", iter_var="items", body=[
        Query(target="r", query_name="part.lookup", params=("i",), updates_db=True),
    ])
    with pytest.raises(FissionError):
        apply_rule_a(loop)


def test_two_queries_per_iteration():
    prog = Program(
        inputs=("items", "a", "b"),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Query(target="x", query_name="part.lookup", params=("i",)),
                Assign(target="j", fn=lambda x: (x + 3) % 1000, args=("x",)),
                Query(target="y", query_name="part.lookup", params=("j",)),
                Assign(target="a", fn=add, args=("a", "x")),
                Assign(target="b", fn=add, args=("b", "y")),
            ]),
        ],
    )
    inputs = {"items": list(range(25)), "a": 0, "b": 0}
    base, out = run_both(prog, inputs)
    assert base["a"] == out["a"] and base["b"] == out["b"]
    rep = analyze_applicability(prog)
    assert rep["opportunities"] == 2 and rep["transformed"] == 2


def test_ddg_edges_example2():
    body = example2_program().body[0].body
    ddg = build_ddg(body, loop_body=True)
    kinds = {(e.src, e.dst, e.kind.value) for e in ddg.edges}
    assert (0, 1, "FD") in kinds          # partCount: query → sum
    assert any(k[2] == "LAD" for k in kinds)  # loop-carried anti on partCount


def test_rule_b_guard_grouping_repr():
    body = [If(pred="p", then_body=[Assign(target="x", fn=lambda: 1, args=())],
               else_body=[Assign(target="x", fn=lambda: 2, args=())])]
    flat = apply_rule_b(body)
    # cv assign + 2 guarded statements
    assert len(flat) == 3
    assert flat[1].guard is not None and flat[2].guard_negated


# ---------------------------------------------------------------------------
# interpreter seams: output sink, producer-thread failure
# ---------------------------------------------------------------------------


def _effect_program():
    """A loop whose query result is logged via an effectful Assign."""
    return Program(
        inputs=("categories",),
        body=[
            Loop(item_var="category", iter_var="categories", body=[
                Query(target="partCount", query_name="part.lookup",
                      params=("category",)),
                Assign(target=None, fn=lambda v: v, args=("partCount",),
                       effect="log"),
            ]),
        ],
    )


def test_interpreter_outputs_sink_receives_emissions():
    """Regression: Interpreter.__init__ accepted `outputs` and silently
    dropped it.  The sink must see every (effect, value) pair, in emission
    order, alongside the `emitted` log — on the original AND the
    transformed program."""
    inputs = {"categories": list(range(12))}
    seen: list = []
    interp = Interpreter(TableService(TABLES), outputs=seen.append)
    interp.run(_effect_program(), dict(inputs))
    assert seen == interp.emitted
    assert len(seen) == 12 and all(eff == "log" for eff, _ in seen)

    t = transform_program(_effect_program(), overlap=True)
    rt = AsyncQueryRuntime(TableService(TABLES), n_threads=3)
    seen_t: list = []
    interp_t = Interpreter(rt, outputs=seen_t.append)
    interp_t.run(t, dict(inputs))
    rt.drain()
    rt.shutdown()
    assert seen_t == interp_t.emitted
    assert sorted(v for _, v in seen_t) == sorted(v for _, v in seen)


class _Boom(RuntimeError):
    pass


def _raising_program(n_items: int, raise_at: int):
    """Producer-side Assign (feeds the query's params) raises mid-loop."""

    def key_of(i):
        if i == raise_at:
            raise _Boom(f"producer failed at item {i}")
        return i

    return Program(
        inputs=("items", "total"),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Assign(target="key", fn=key_of, args=("i",)),
                Query(target="v", query_name="part.lookup", params=("key",)),
                Assign(target="total", fn=add, args=("total", "v")),
            ]),
        ],
    )


def test_fissioned_producer_exception_propagates_without_hanging():
    """Regression: an exception on the overlap producer thread skipped
    ``table.close()`` — the consumer's ``for record in table:`` blocked
    forever and the exception was swallowed.  The run must terminate
    promptly and re-raise the producer's exception on the caller."""
    import threading as _threading

    prog = transform_program(_raising_program(30, raise_at=7), overlap=True)
    rt = AsyncQueryRuntime(TableService(TABLES), n_threads=3)
    outcome: list = []

    def drive():
        try:
            Interpreter(rt).run(prog, {"items": list(range(30)), "total": 0})
            outcome.append(("returned", None))
        except _Boom as e:
            outcome.append(("raised", e))
        except BaseException as e:  # noqa: BLE001 — diagnosed below
            outcome.append(("raised-other", e))

    th = _threading.Thread(target=drive, daemon=True)
    th.start()
    th.join(timeout=30)  # pre-fix: blocks forever on the unclosed table
    hung = th.is_alive()
    rt.shutdown()
    assert not hung, "fissioned run hung after a producer exception"
    assert outcome and outcome[0][0] == "raised", outcome
    assert "producer failed at item 7" in str(outcome[0][1])


def test_fissioned_producer_exception_inline_mode_closes_table():
    """Same failure without overlap: the exception propagates before the
    consumer runs (unchanged contract) and the table is still closed."""
    prog = transform_program(_raising_program(10, raise_at=3), overlap=False)
    rt = AsyncQueryRuntime(TableService(TABLES), n_threads=3)
    with pytest.raises(_Boom):
        Interpreter(rt).run(prog, {"items": list(range(10)), "total": 0})
    rt.shutdown()


# ---------------------------------------------------------------------------
# property tests: random programs, transformed ≡ original
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:  # CI installs hypothesis (pip install -e .[dev])
    _OPS = [lambda a, b: a + b, lambda a, b: a - b, lambda a, b: a * b % 997,
            lambda a, b: max(a, b), lambda a, b: min(a, b)]


    @st.composite
    def random_loop_program(draw):
        """Random loop with query + mix of producer/consumer statements."""
        n_pre = draw(st.integers(0, 3))
        n_post = draw(st.integers(1, 4))
        use_if = draw(st.booleans())
        body = []
        live = ["i", "seed"]
        for k in range(n_pre):
            op = draw(st.sampled_from(_OPS))
            a = draw(st.sampled_from(live))
            b = draw(st.sampled_from(live))
            body.append(Assign(target=f"p{k}", fn=op, args=(a, b)))
            live.append(f"p{k}")
        keyvar = draw(st.sampled_from(live))
        body.append(Assign(target="qkey", fn=lambda a: abs(a) % 1000, args=(keyvar,)))
        q = Query(target="qres", query_name="part.lookup", params=("qkey",))
        if use_if:
            body.append(Assign(target="cond", fn=lambda a: a % 2 == 0, args=(keyvar,)))
            body.append(If(pred="cond", then_body=[q]))
            body.append(Assign(target="qres2", fn=lambda c, q_, s: q_ if c else s,
                               args=("cond", "qres", "seed")))
            live.append("qres2")
        else:
            body.append(q)
            live.append("qres")
        for k in range(n_post):
            op = draw(st.sampled_from(_OPS))
            a = draw(st.sampled_from(live + ["acc"]))
            body.append(Assign(target="acc", fn=op, args=("acc", a)))
        n_items = draw(st.integers(1, 20))
        return Program(
            inputs=("items", "acc", "seed", "qres"),
            body=[Loop(item_var="i", iter_var="items", body=body)],
        ), n_items


    @settings(max_examples=40, deadline=None)
    @given(random_loop_program(), st.integers(0, 10_000))
    def test_property_transform_preserves_semantics(prog_items, seed):
        prog, n_items = prog_items
        inputs = {"items": list(range(n_items)), "acc": 1, "seed": seed, "qres": 0}
        base = Interpreter(TableService(TABLES)).run(prog, dict(inputs))
        t = transform_program(prog)
        rt = AsyncQueryRuntime(TableService(TABLES), n_threads=3, strategy=OneOrAll())
        out = Interpreter(rt).run(t, dict(inputs))
        rt.drain()
        rt.shutdown()
        assert base["acc"] == out["acc"]


    @settings(max_examples=15, deadline=None)
    @given(random_loop_program(), st.integers(0, 10_000))
    def test_property_overlap_preserves_semantics(prog_items, seed):
        prog, n_items = prog_items
        inputs = {"items": list(range(n_items)), "acc": 1, "seed": seed, "qres": 0}
        base = Interpreter(TableService(TABLES)).run(prog, dict(inputs))
        t = transform_program(prog, overlap=True)
        rt = AsyncQueryRuntime(TableService(TABLES), n_threads=3)
        out = Interpreter(rt).run(t, dict(inputs))
        rt.drain()
        rt.shutdown()
        assert base["acc"] == out["acc"]
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_property_suite_requires_hypothesis():
        """Placeholder so the dropped property tests surface as a SKIP
        instead of silently disappearing from collection."""


# ---------------------------------------------------------------------------
# negative paths: the transformer must REFUSE, not miscompile
# ---------------------------------------------------------------------------


def _unchanged_and_equivalent(prog, inputs):
    """The whole negative-path contract in one helper: zero fissioned
    statements in the output, applicability agrees, and the (untouched)
    transformed program still runs to the same result."""
    from repro.core.equivalence import check_program, count_fissioned

    t = transform_program(prog)
    assert count_fissioned(t.body) == 0
    rep = analyze_applicability(prog)
    assert rep["transformed"] == 0
    res = check_program(prog, inputs)
    assert res.equivalent, res.mismatches
    return rep


def test_refuses_loop_carried_dependence_on_query_output():
    """key_{i+1} = f(result_i): the submit of iteration i+1 needs the fetch
    of iteration i — fission would read a stale key."""
    prog = Program(
        inputs=("items", "key"),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Query(target="r", query_name="part.lookup", params=("key",)),
                Assign(target="key", fn=lambda r: r % 100, args=("r",)),
            ]),
        ],
    )
    rep = _unchanged_and_equivalent(prog, {"items": list(range(8)), "key": 5})
    assert rep["opportunities"] == 1


def test_refuses_query_under_guard_that_writes_its_own_parameter():
    """The guarded block writes the query's parameter from the query's own
    output: Rule B flattens the If, but the loop-carried flow edge from the
    consumer-side write of ``p`` to the producer-side reads (guard + param)
    survives reordering — refuse."""
    prog = Program(
        inputs=("items", "p", "acc"),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Assign(target="g", fn=lambda p: p % 2 == 0, args=("p",)),
                If(pred="g", then_body=[
                    Query(target="r", query_name="part.lookup",
                          params=("p",)),
                    Assign(target="p", fn=lambda r: (r + 3) % 50,
                           args=("r",)),
                ]),
                Assign(target="acc", fn=add, args=("acc", "p")),
            ]),
        ],
    )
    _unchanged_and_equivalent(
        prog, {"items": list(range(10)), "p": 4, "acc": 0})


def test_refuses_nested_query_feeding_outer_cursor():
    """The inner loop's query result advances the cursor the next inner
    iteration reads: neither the inner loop (loop-carried flow through
    ``cur``) nor the outer loop (no direct query; the inner loop is one
    opaque statement) may be fissioned."""
    prog = Program(
        inputs=("outer", "inner", "cur", "acc"),
        body=[
            Loop(item_var="i", iter_var="outer", body=[
                Loop(item_var="j", iter_var="inner", body=[
                    Query(target="row", query_name="part.lookup",
                          params=("cur",)),
                    Assign(target="cur", fn=lambda row: (row + 7) % 900,
                           args=("row",)),
                ]),
                Assign(target="acc", fn=add, args=("acc", "cur")),
            ]),
        ],
    )
    _unchanged_and_equivalent(
        prog,
        {"outer": list(range(4)), "inner": list(range(5)),
         "cur": 3, "acc": 0})


# ---------------------------------------------------------------------------
# fuzz-found regressions, minimized
# ---------------------------------------------------------------------------


def test_regression_guarded_query_target_not_clobbered_by_restore():
    """Fuzz-found miscompile: a guarded query whose target is read after
    the query (under the same guard) put the target into the split-variable
    set — the context table captured a stale pre-loop value and the
    consumer's unconditional restore clobbered the loop-carried
    previous-iteration value whenever the guard was false.  The last item
    below is odd, so pre-fix the final ``q``/``u`` came from the stale
    snapshot instead of the last even iteration's fetch."""
    from repro.core.equivalence import check_program

    prog = Program(
        inputs=("items", "q", "u"),
        body=[
            Loop(item_var="it", iter_var="items", body=[
                Assign(target="g", fn=lambda it: it % 2 == 0, args=("it",)),
                Query(target="q", query_name="part.lookup", params=("it",),
                      guard="g"),
                Assign(target="u", fn=lambda q: q + 1, args=("q",),
                       guard="g"),
            ]),
        ],
    )
    inputs = {"items": [2, 4, 6, 8, 5], "q": -1, "u": -1}
    res = check_program(prog, inputs, ("q", "u"))
    assert res.equivalent, res.mismatches
    assert res.fissioned == 1
    # and the sync semantics really are the last-even-iteration values
    base = Interpreter(TableService(TABLES)).run(prog, dict(inputs))
    assert base["q"] == TABLES["part"][8] and base["u"] == base["q"] + 1


def test_regression_fresh_names_avoid_program_variables():
    """Programs that already use the transformer's own name shapes
    (``q_``-prefixed targets, ``handle_2``, ``cv_0``, ``t_0`` used OUTSIDE
    the loop) must survive: whole-program transformation reserves every
    program name, so generated fresh names never collide."""
    from repro.core.equivalence import check_program
    from repro.core.hir import collect_names

    prog = Program(
        inputs=("items", "handle_2", "cv_0", "t_0"),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Query(target="q_0", query_name="part.lookup",
                      params=("i",)),
                Assign(target="acc", fn=add, args=("q_0", "q_0")),
            ]),
            # reads AFTER the loop: a colliding fresh name (the shared
            # counter makes the handle pick exactly ``handle_2``) would
            # clobber these between the loop and this statement
            Assign(target="out", fn=lambda a, b, c: a * 10000 + b * 100 + c,
                   args=("handle_2", "cv_0", "t_0")),
        ],
    )
    inputs = {"items": list(range(6)), "handle_2": 11, "cv_0": 22, "t_0": 33}
    res = check_program(prog, inputs, ("out", "acc", "q_0"))
    assert res.equivalent, res.mismatches
    assert res.fissioned == 1
    # every NEW name the transformer minted is disjoint from program names
    t = transform_program(prog)
    minted = collect_names(t.body) - collect_names(prog.body)
    assert minted and not (minted & set(inputs))


def test_regression_precondition_c_conditional_producer_write():
    """Precondition (c): a split variable rewritten by the consumer whose
    only producer-side write is conditional would be restored from a
    guard-dependent snapshot.  Direct Rule A (no reordering) must refuse;
    ``transform_program`` may instead rescue it by reordering (the query
    moves first, the conditional write becomes consumer-side) — and that
    rescue must be equivalent."""
    from repro.core.equivalence import check_program

    def body():
        return [
            Assign(target="g", fn=lambda it: it % 2 == 0, args=("it",)),
            Assign(target="acc", fn=lambda it: it, args=("it",), guard="g"),
            Query(target="q", query_name="part.lookup", params=("it",)),
            Assign(target="acc", fn=add, args=("acc", "q")),
        ]

    with pytest.raises(FissionError, match=r"precondition \(c\)"):
        apply_rule_a(Loop(item_var="it", iter_var="items", body=body()),
                     reorder=False)

    prog = Program(
        inputs=("items", "acc"),
        body=[Loop(item_var="it", iter_var="items", body=body())],
    )
    res = check_program(prog, {"items": [2, 1, 4, 7, 8], "acc": 0})
    assert res.equivalent, res.mismatches
    assert res.fissioned == 1  # reorder_for_fission rescued it


# ---------------------------------------------------------------------------
# Proc/Call: inline-then-fission applicability
# ---------------------------------------------------------------------------


def test_can_inline_refuses_recursion_free_vars_unbound_result():
    from repro.core.hir import Call, Proc, can_inline

    rec = Proc(name="rec", formals=("n",), body=[], result=None)
    rec.body.append(Call(target=None, proc=rec, args=("n",)))
    ok, why = can_inline(rec)
    assert not ok and "recursive" in why

    free = Proc(name="leaky", formals=("a",),
                body=[Assign(target="x", fn=add, args=("a", "outside"))],
                result="x")
    ok, why = can_inline(free)
    assert not ok and "free" in why and "outside" in why

    unbound = Proc(name="nores", formals=("a",),
                   body=[Assign(target="x", fn=lambda a: a, args=("a",))],
                   result="y")
    ok, why = can_inline(unbound)
    assert not ok and "never bound" in why


def test_uninlinable_call_leaves_loop_unfissioned():
    """A recursive query-bearing proc inside a loop: the transformer must
    keep the Call (and the loop) untouched instead of miscompiling."""
    from repro.core.hir import Call, Proc
    from repro.core.equivalence import count_fissioned

    rec = Proc(name="rec", formals=("n",), body=[
        Query(target="r", query_name="part.lookup", params=("n",)),
    ], result="r")
    rec.body.append(
        Call(target=None, proc=rec, args=("n",), guard=None))
    # interpreting recursion would not terminate — only static checks here
    prog = Program(
        inputs=("items",),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Call(target="v", proc=rec, args=("i",)),
            ]),
        ],
    )
    t = transform_program(prog)
    assert count_fissioned(t.body) == 0
    assert isinstance(t.body[0].body[0], Call)
    rep = analyze_applicability(prog)
    assert rep["transformed"] == 0
    assert any("inline refused" in f for f in rep["failures"])


def test_guarded_call_inlines_under_if_and_fissions():
    """A guarded Call wraps its expansion in an If on the (negated) guard;
    Rule B then flattens it and Rule A fissions the query inside."""
    from repro.core.hir import Call, Proc
    from repro.core.equivalence import check_program

    proc = Proc(name="look", formals=("k",), body=[
        Query(target="r", query_name="part.lookup", params=("k",)),
        Assign(target="o", fn=lambda r: r * 2, args=("r",)),
    ], result="o")
    prog = Program(
        inputs=("items", "acc"),
        body=[
            Loop(item_var="i", iter_var="items", body=[
                Assign(target="g", fn=lambda i: i % 2 == 0, args=("i",)),
                Assign(target="v", fn=lambda i: -i, args=("i",)),
                Call(target="v", proc=proc, args=("i",), guard="g",
                     guard_negated=True),
                Assign(target="acc", fn=add, args=("acc", "v")),
            ]),
        ],
    )
    res = check_program(prog, {"items": list(range(12)), "acc": 0})
    assert res.equivalent, res.mismatches
    assert res.fissioned == 1
    assert res.round_trip_win
