"""Mamba-2 mixer — state-space duality (SSD) [arXiv:2405.21060].

Full-sequence form is the *chunked* SSD algorithm (intra-chunk quadratic
attention-like term + inter-chunk state recurrence via ``lax.scan``), which
is the TPU-friendly formulation: every chunk term is an MXU matmul, and the
only sequential dependency is the O(S/Q) chunk-state scan.  Decode is the
O(1) recurrent update.

Layout (n_groups = 1):
  x       (B, S, H, P)     H = ssm_heads, P = ssm_head_dim
  dt      (B, S, H)        softplus(raw + dt_bias)
  A       (H,)             -exp(A_log)
  B, C    (B, S, N)        N = ssm_state (shared across heads, g=1)
  state   (B, H, P, N)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.models.config import ModelConfig
from repro.models.layers import dense_init

__all__ = [
    "ssm_params",
    "ssm_forward",
    "ssm_decode_step",
    "init_ssm_state",
    "ssd_chunked",
    "ssd_reference",
]


def _dims(cfg: ModelConfig):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner = H * P
    d_conv_ch = d_inner + 2 * N  # conv runs over (x, B, C) channels
    return H, P, N, d_inner, d_conv_ch


def ssm_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, P, N, d_inner, d_conv_ch = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj emits [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
    out_dim = 2 * d_inner + 2 * N + H
    p = {
        "in_proj": dense_init(k1, d, out_dim, cfg.pdtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, d_conv_ch)) /
                   math.sqrt(cfg.ssm_conv)).astype(cfg.pdtype),
        "conv_b": jnp.zeros((d_conv_ch,), cfg.pdtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), cfg.pdtype),
        "out_proj": dense_init(k3, d_inner, d, cfg.pdtype),
    }
    return p


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(dA):
    """segsum(dA)[..., i, j] = sum_{j<k<=i} dA[..., k]  (lower-triangular).

    dA: (..., Q) → (..., Q, Q); exp of this is the intra-chunk decay L.
    """
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD (Mamba-2 Listing 1, jnp port with g=1 shared B/C).

    x: (b,l,h,p)  dt: (b,l,h)  A: (h,)  B,C: (b,l,n)
    Returns y: (b,l,h,p), final_state: (b,h,p,n).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l) if l < chunk else chunk
    pad = (-l) % Q
    if pad:
        # dt=0 padding is exact: decay exp(0)=1, update dt·x = 0.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    l_pad = l + pad
    c = l_pad // Q

    f32 = jnp.float32
    xc = x.reshape(b, c, Q, h, p).astype(f32)
    dtc = dt.reshape(b, c, Q, h).astype(f32)
    Bc = B.reshape(b, c, Q, n).astype(f32)
    Cc = C.reshape(b, c, Q, n).astype(f32)
    del x, dt, B, C
    dA = dtc * A[None, None, None, :]  # (b,c,Q,h)
    dA_h = jnp.moveaxis(dA, -1, 2)  # (b,c,h,Q)
    dA_cs = jnp.cumsum(dA_h, axis=-1)  # (b,c,h,Q)

    # ---- intra-chunk (diagonal blocks): attention-like quadratic term ----
    L = jnp.exp(_segsum(dA_h))  # (b,c,h,Q,Q)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,c,Q,Q)
    scores = CB[:, :, None] * L  # (b,c,h,i,j)
    sx = xc * dtc[..., None]  # dt-weighted input
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, sx)

    # ---- chunk states -----------------------------------------------------
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)  # (b,c,h,Q)
    states = jnp.einsum("bcqn,bchq,bcqhp->bchpn", Bc, decay_states, sx)

    # ---- inter-chunk recurrence (sequential scan over chunks) -------------
    chunk_decay = jnp.exp(dA_cs[..., -1])  # (b,c,h)
    s0 = (
        jnp.zeros((b, h, p, n), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def step(carry, inp):
        st_c, dec_c = inp  # (b,h,p,n), (b,h)
        new = carry * dec_c[:, :, None, None] + st_c
        return new, carry  # emit state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,c,h,p,n)

    # ---- off-diagonal contribution from carried-in states ------------------
    state_decay = jnp.exp(dA_cs)  # (b,c,h,Q)
    y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l_pad, h, p)[:, :l]
    return y, final_state


def ssd_reference(x, dt, A, B, C, initial_state=None):
    """O(S·N·P) sequential oracle for tests: plain recurrence."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    f32 = jnp.float32
    s = (
        jnp.zeros((b, h, p, n), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def step(state, t):
        xt, dtt, Bt, Ct = t
        dA = jnp.exp(dtt * A)  # (b,h)
        upd = dtt[..., None, None] * xt[..., None] * Bt[:, None, None, :]
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(B.astype(f32), 1, 0),
        jnp.moveaxis(C.astype(f32), 1, 0),
    )
    final, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1), final


# ---------------------------------------------------------------------------
# full mixer (proj → causal depthwise conv → SSD → gate → out)
# ---------------------------------------------------------------------------


def _split_proj(cfg, proj):
    H, P, N, d_inner, _ = _dims(cfg)
    z, xin, B, C, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, xin, B, C, dt


def _causal_conv(seq, w, b):
    """seq: (B, S, Ch); depthwise causal conv, kernel (K, Ch)."""
    K = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + seq.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out + b[None, None, :]


def ssm_forward(p: dict, cfg: ModelConfig, x, initial_state=None, return_state=False):
    """Full-sequence mamba2 mixer.  x: (B,S,d) → (B,S,d)."""
    cd = cfg.cdtype
    H, P, N, d_inner, d_conv_ch = _dims(cfg)
    Bsz, S, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x.astype(cd), p["in_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
    z, xin, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in.astype(jnp.float32), p["conv_w"].astype(jnp.float32),
                     p["conv_b"].astype(jnp.float32))
    )
    xin, Bm, Cm = (
        conv_out[..., :d_inner],
        conv_out[..., d_inner : d_inner + N],
        conv_out[..., d_inner + N :],
    )
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(Bsz, S, H, P)
    xh = shard_activation(xh, "dp", None, "model", None)
    if return_state:
        # conv tail (pre-activation conv inputs) so decode continues exactly
        K = cfg.ssm_conv
        tail = conv_in[:, -(K - 1):].astype(cd)
        if tail.shape[1] < K - 1:
            tail = jnp.pad(tail, ((0, 0), (K - 1 - tail.shape[1], 0), (0, 0)))
    y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                                 initial_state=initial_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(cd)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_w"].astype(jnp.float32)).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
    out = shard_activation(out, "dp", None, None)
    if return_state:
        return out, {"ssm": final_state, "conv": tail}
    return out


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers=None):
    H, P, N, d_inner, d_conv_ch = _dims(cfg)
    L = n_layers if n_layers is not None else cfg.n_layers
    return {
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, d_conv_ch), cfg.cdtype),
    }


def ssm_decode_step(p: dict, cfg: ModelConfig, x, ssm_state, conv_state):
    """One-token recurrent update.  x: (B,1,d).

    Returns (y, new_ssm_state, new_conv_state).
    """
    cd = cfg.cdtype
    H, P, N, d_inner, d_conv_ch = _dims(cfg)
    Bsz = x.shape[0]
    proj = jnp.einsum("bsd,de->bse", x.astype(cd), p["in_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
    z, xin, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)[:, 0]  # (B, Ch)
    # roll conv window
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # (B,K,Ch)
    new_conv_state = window[:, 1:]
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
        + p["conv_b"].astype(jnp.float32)
    )
    xin = conv_out[:, :d_inner].reshape(Bsz, H, P)
    Bm = conv_out[:, d_inner : d_inner + N]
    Cm = conv_out[:, d_inner + N :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])  # (B,H)
    upd = dt[..., None, None] * xin.astype(jnp.float32)[..., None] * Bm[:, None, None, :]
    new_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(Bsz, 1, d_inner).astype(cd)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_w"].astype(jnp.float32)).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
    return out, new_state, new_conv_state
