"""Shared layers: norms, rotary-embedding variants, initializers.

All computation helpers are pure functions over explicit parameter pytrees
(dicts of jnp arrays) — no framework.  Norms and softmax run in fp32
regardless of the compute dtype (bf16-safe numerics).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "embed_init",
    "rmsnorm",
    "layernorm",
    "nonparam_ln",
    "apply_norm",
    "norm_params",
    "rope_freqs",
    "apply_rope",
    "apply_rope_half",
    "apply_mrope",
    "linear",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def linear(x, w, b=None, compute_dtype=None):
    """x @ w (+ b) with fp32 accumulation on the MXU."""
    cd = compute_dtype or x.dtype
    y = jnp.einsum(
        "...d,df->...f",
        x.astype(cd),
        w.astype(cd),
        preferred_element_type=jnp.float32,
    ).astype(cd)
    if b is not None:
        y = y + b.astype(cd)
    return y


# ---------------------------------------------------------------------------
# norms (fp32 internals)
# ---------------------------------------------------------------------------


def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * weight.astype(jnp.float32)).astype(dt)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def nonparam_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale/bias) [arXiv:2402.00838]."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm_params(kind: str, dim: int, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        return {"w": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}
    if kind == "nonparam_ln":
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, p, x):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    if kind == "layernorm":
        return layernorm(x, p["w"], p["b"])
    if kind == "nonparam_ln":
        return nonparam_ln(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# rotary position embeddings — three published variants
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for a rotary dim (must be even)."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x, cos, sin):
    # x: (..., rot_dim) pairs interleaved as [x0, x1] halves convention
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(q, k, positions, theta: float):
    """Standard RoPE [arXiv:2104.09864] over the full head dim.

    q: (B, S, H, D), k: (B, S, Hkv, D), positions: (B, S) int32.
    """
    d = q.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return (
        _rotate(q.astype(jnp.float32), cos, sin).astype(q.dtype),
        _rotate(k.astype(jnp.float32), cos, sin).astype(k.dtype),
    )


def apply_rope_half(q, k, positions, theta: float):
    """ChatGLM's 2D RoPE: rotary on the first half of the head dim only
    [arXiv:2406.12793 / GLM lineage]."""
    d = q.shape[-1]
    rot = d // 2
    q1, q2 = q[..., :rot], q[..., rot:]
    k1, k2 = k[..., :rot], k[..., rot:]
    q1r, k1r = apply_rope(q1, k1, positions, theta)
    return (
        jnp.concatenate([q1r, q2], axis=-1),
        jnp.concatenate([k1r, k2], axis=-1),
    )


def apply_mrope(q, k, positions3, theta: float, sections: Tuple[int, ...]):
    """Qwen2-VL M-RoPE [arXiv:2409.12191]: the rotary dim is partitioned into
    (temporal, height, width) sections, each rotated by its own position id.

    positions3: (3, B, S) int32 — for text tokens all three rows are equal,
    so M-RoPE degenerates to standard RoPE (as in the paper).
    """
    d = q.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)  # (d/2,)
    # Build per-frequency position selector from the sections.
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (d/2,)
    # select positions3[sec_id[f]] per frequency f:
    # ang[b, s, f] = positions3[sec_id[f], b, s] * inv[f]
    p = positions3.astype(jnp.float32)  # (3, B, S)
    ang = jnp.einsum("kbs,fk->bsf", p, jax.nn.one_hot(sec_id, 3, dtype=jnp.float32))
    ang = ang * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return (
        _rotate(q.astype(jnp.float32), cos, sin).astype(q.dtype),
        _rotate(k.astype(jnp.float32), cos, sin).astype(k.dtype),
    )
