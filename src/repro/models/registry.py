"""Architecture registry: one uniform API over all model families.

``get_arch(name)`` returns an :class:`Arch` bundling the config with
family-appropriate init/forward/prefill/decode functions and the
``input_specs()`` ShapeDtypeStruct stand-ins used by the multi-pod dry-run
(weak-type-correct, shardable, no device allocation).

Modality frontends are STUBS by assignment: ``[vlm]``/``[audio]`` cells feed
precomputed patch/frame embeddings straight into the backbone.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as _encdec
from repro.models import transformer as _tf
from repro.models.config import ModelConfig, ShapeSpec

__all__ = ["Arch", "get_arch", "list_archs", "ARCH_IDS"]

ARCH_IDS = [
    "chatglm3-6b",
    "olmo-1b",
    "llama3-8b",
    "qwen1.5-4b",
    "mamba2-1.3b",
    "hymba-1.5b",
    "qwen2-vl-2b",
    "seamless-m4t-medium",
    "deepseek-moe-16b",
    "kimi-k2-1t-a32b",
]


@dataclasses.dataclass
class Arch:
    cfg: ModelConfig

    # ------------------------------------------------------------------ api
    def init(self, key):
        if self.cfg.is_encoder_decoder:
            return _encdec.init_params_encdec(self.cfg, key)
        return _tf.init_params(self.cfg, key)

    def forward(self, params, batch):
        """Training forward → (logits, aux)."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return _encdec.forward_encdec(cfg, params, batch["src_embeds"], batch["tgt_tokens"])
        if cfg.frontend != "none":
            return _tf.forward(cfg, params, embeds=batch["embeds"],
                               positions=batch.get("positions"))
        return _tf.forward(cfg, params, tokens=batch["tokens"])

    def labels_of(self, batch):
        return batch["labels"]

    def prefill(self, params, batch, max_len=None):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return _encdec.prefill_encdec(cfg, params, batch["src_embeds"],
                                          batch["tgt_tokens"], max_len=max_len)
        if cfg.frontend != "none":
            return _tf.prefill(cfg, params, embeds=batch["embeds"],
                               positions=batch.get("positions"), max_len=max_len)
        return _tf.prefill(cfg, params, tokens=batch["tokens"], max_len=max_len)

    def decode_step(self, params, token, cache, lengths):
        if self.cfg.is_encoder_decoder:
            return _encdec.decode_step_encdec(self.cfg, params, token, cache, lengths)
        return _tf.decode_step(self.cfg, params, token, cache, lengths)

    def init_cache(self, batch: int, max_len: int, src_len: Optional[int] = None):
        if self.cfg.is_encoder_decoder:
            return _encdec.init_cache_encdec(self.cfg, batch, max_len,
                                             src_len or max_len)
        return _tf.init_cache(self.cfg, batch, max_len)

    # ------------------------------------------------------- dry-run specs
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        if shape.kind == "train":
            if cfg.is_encoder_decoder:
                # enc-dec train cell: src frames + tgt tokens, each seq_len/2
                # so the cell's token budget (B × S) is preserved end-to-end.
                s2 = S // 2
                return {
                    "src_embeds": sds((B, s2, cfg.d_model), cfg.cdtype),
                    "tgt_tokens": sds((B, s2), i32),
                    "labels": sds((B, s2), i32),
                }
            if cfg.frontend != "none":
                batch = {
                    "embeds": sds((B, S, cfg.d_model), cfg.cdtype),
                    "labels": sds((B, S), i32),
                }
                if cfg.rope == "mrope":
                    batch["positions"] = sds((3, B, S), i32)
                return batch
            return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

        if shape.kind == "prefill":
            if cfg.is_encoder_decoder:
                s2 = S // 2
                return {
                    "src_embeds": sds((B, s2, cfg.d_model), cfg.cdtype),
                    "tgt_tokens": sds((B, s2), i32),
                }
            if cfg.frontend != "none":
                batch = {"embeds": sds((B, S, cfg.d_model), cfg.cdtype)}
                if cfg.rope == "mrope":
                    batch["positions"] = sds((3, B, S), i32)
                return batch
            return {"tokens": sds((B, S), i32)}

        # decode: one new token against a cache of S
        cache = jax.eval_shape(
            lambda: self.init_cache(B, S, src_len=(S // 2 if cfg.is_encoder_decoder else None))
        )
        return {
            "token": sds((B,), i32),
            "cache": cache,
            "lengths": sds((B,), i32),
        }

    def shapes(self):
        return self.cfg.shapes()


def get_arch(name: str) -> Arch:
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return Arch(cfg=mod.CONFIG)


def list_archs() -> list[str]:
    return list(ARCH_IDS)
