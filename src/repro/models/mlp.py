"""Dense feed-forward blocks: SwiGLU (llama lineage), GELU, GeGLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, linear

__all__ = ["mlp_params", "mlp"]


def mlp_params(key, cfg: ModelConfig, d_ff=None) -> dict:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d, d_ff, cfg.pdtype),
            "w_in": dense_init(k2, d, d_ff, cfg.pdtype),
            "w_out": dense_init(k3, d_ff, d, cfg.pdtype),
        }
    return {
        "w_in": dense_init(k1, d, d_ff, cfg.pdtype),
        "w_out": dense_init(k2, d_ff, d, cfg.pdtype),
    }


def mlp(p: dict, cfg: ModelConfig, x):
    cd = cfg.cdtype
    if cfg.act in ("swiglu", "geglu"):
        g = linear(x, p["w_gate"], compute_dtype=cd)
        h = linear(x, p["w_in"], compute_dtype=cd)
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(g.astype(jnp.float32)).astype(cd) * h
    else:
        h = linear(x, p["w_in"], compute_dtype=cd)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(cd)
    h = shard_activation(h, "dp", None, "model")
    y = linear(h, p["w_out"], compute_dtype=cd)
    return shard_activation(y, "dp", None, None)
