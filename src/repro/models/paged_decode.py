"""One-token decode reading KV directly from a paged physical pool.

Mirrors :func:`repro.models.transformer.decode_step` exactly — same embed,
norms, residuals, MLP/MoE blocks and head — while replacing the dense
per-lane KV cache ``(L, B, S_max, Hkv, hd)`` with shared physical page
arrays ``(L, P, page_size, Hkv, hd)`` addressed through per-request block
tables (the vLLM PagedAttention layout).  Attention goes through
``repro.kernels.registry.dispatch("paged_decode_attention", ...)``: the
Pallas kernel runs on TPU (or under interpret mode), the pure-jnp paged
reference everywhere else — the registry's one dispatch policy, so the
serving engine never re-implements the fallback dance.

Numerical contract: for lanes marked ``active``, the logits are the same
computation the dense path performs — the gather of a lane's pages in
logical order reproduces its dense cache rows exactly, and the masking
(``kpos < length + 1``) admits exactly the rows dense decode admits — so
greedy decode over paged KV is bit-identical at the token level.

Physical page ``P - 1`` is a **trash page**: inactive lanes' KV scatter
writes are routed there, so a fully-batched decode step can never corrupt
a page owned by an active request.  Block tables never reference it, and
the pool (``serving/paged_kv.py``) never allocates it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.models.attention import _project_qkv, _rope
from repro.models.config import ModelConfig
from repro.models.layers import apply_norm
from repro.models.mlp import mlp
from repro.models.moe import moe
from repro.models.transformer import (
    _embed,
    _head,
    _layer_stacks,
    _stack_names,
)

__all__ = ["supports_paged_decode", "paged_decode_step", "sample_tokens"]


def sample_tokens(logits, temps, seeds, lengths):
    """Per-lane next-token selection for the cross-template megabatch.

    One decode dispatch now covers every active lane regardless of
    template, so sampling parameters ride along per lane instead of per
    dispatch: ``temps``/``seeds`` are (B,) float32/int32.  Temperature-0
    lanes take the greedy argmax — bit-identical to the dense engine's
    ``jnp.argmax`` path.  Positive-temperature lanes draw from the
    temperature-scaled categorical under a counter-based per-lane key
    ``fold_in(fold_in(key0, seed), length)``: keyed on the request's own
    *position* (not a global step counter), so the draw at a given token
    index reproduces bit-identically across spill/restore, lane
    reassignment and batch composition changes.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def draw(seed, length, lg, t):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), seed), length)
        return jax.random.categorical(key, lg / jnp.maximum(t, 1e-6))

    sampled = jax.vmap(draw)(seeds, lengths, logits, temps).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def supports_paged_decode(cfg: ModelConfig) -> bool:
    """Whether this config's decode math can run over paged KV.

    Paged decode covers full-context dense/MoE attention stacks —
    sliding-window (ring-buffer) layers, SSM/hybrid state and
    encoder-decoder cross KV keep the dense per-lane layout.
    """
    if cfg.is_encoder_decoder or cfg.attn_window > 0:
        return False
    return all(kind in ("dense", "moe")
               for _name, kind, _n in _stack_names(cfg))


def _paged_attention(p, cfg: ModelConfig, x, k_pages, v_pages, block_tables,
                     lengths, active, *, use_kernel: bool, interpret: bool):
    """One-token GQA attention over pages; mirrors ``decode_attention``.

    ``x`` is (B, 1, d_model); ``k_pages``/``v_pages`` are one layer's
    (P, page_size, Hkv, hd) physical pages (slot ``P - 1`` is the trash
    page); ``block_tables`` is (B, max_pages) int32; ``lengths`` (B,)
    int32; ``active`` (B,) bool.  The new token's KV is scattered into
    the page backing logical position ``min(length, s_max - 1)`` for
    active lanes (trash page otherwise), then attention reads positions
    ``[0, length]`` through the registry's paged kernel/ref pair.
    Returns ``(y, k_pages, v_pages)``.
    """
    cd = cfg.cdtype
    b = x.shape[0]
    n_phys, ps = k_pages.shape[0], k_pages.shape[1]
    s_max = block_tables.shape[1] * ps
    q, k_new, v_new = _project_qkv(p, cfg, x)
    if cfg.rope != "none":
        rope_pos = lengths[:, None]  # (B, 1) true positions
        if cfg.rope == "mrope":
            rope_pos = jnp.broadcast_to(rope_pos[None], (3, b, 1))
        q, k_new = _rope(cfg, q, k_new, rope_pos)

    # Same write position as the dense path (min(lengths, s_max-1)),
    # translated to (physical page, in-page offset).  Inactive lanes write
    # the trash page so the batched scatter cannot clobber live pages.
    slot = jnp.minimum(lengths, s_max - 1)
    logical = slot // ps
    phys = jnp.where(active, block_tables[jnp.arange(b), logical], n_phys - 1)
    off = slot % ps
    k_pages = k_pages.at[phys, off].set(k_new[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[phys, off].set(v_new[:, 0].astype(v_pages.dtype))

    # Valid rows per lane: [0, length] inclusive of the token just written
    # (identical to the dense mask idx <= min(lengths, s_max-1)); inactive
    # lanes attend nothing and their output rows are discarded.
    att_len = jnp.where(active, jnp.minimum(lengths + 1, s_max), 0)
    out = registry.dispatch(
        "paged_decode_attention",
        (q[:, 0], k_pages, v_pages, block_tables, att_len),
        use_kernel=use_kernel, interpret=interpret)
    out = out.reshape(b, 1, cfg.n_heads, cfg.hd)
    y = jnp.einsum("bshd,hdm->bsm", out.astype(cd), p["wo"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    return y, k_pages, v_pages


def _paged_block_decode(p, cfg: ModelConfig, kind: str, x, k_pages, v_pages,
                        block_tables, lengths, active, *,
                        use_kernel: bool, interpret: bool):
    """One transformer block's decode step over paged KV (dense/moe only)."""
    h = apply_norm(cfg.norm, p["ln1"], x)
    a, k_pages, v_pages = _paged_attention(
        p["attn"], cfg, h, k_pages, v_pages, block_tables, lengths, active,
        use_kernel=use_kernel, interpret=interpret)
    x = x + a
    h2 = apply_norm(cfg.norm, p["ln2"], x)
    y = moe(p["moe"], cfg, h2)[0] if kind == "moe" else mlp(p["mlp"], cfg, h2)
    return x + y, k_pages, v_pages


def paged_decode_step(cfg: ModelConfig, params, token, cache: dict,
                      block_tables, lengths, active, *,
                      use_kernel: bool = True, interpret: bool = False):
    """Batched one-token decode over paged KV.

    ``token``/``lengths`` are (B,) int32, ``active`` (B,) bool; ``cache``
    is ``{stack: {"k": (L, P, ps, Hkv, hd), "v": ...}}`` and
    ``block_tables`` (B, max_pages) int32 shared by every layer.  Returns
    ``(logits (B, V), new_cache)`` — the same contract as
    :func:`~repro.models.transformer.decode_step`, over pages.
    """
    x = _embed(cfg, params, token[:, None])
    new_caches = {}
    for (name, kind, _n), (stacked, _k2, _n2) in zip(
        _stack_names(cfg), _layer_stacks(cfg, params)
    ):
        def body(h, inp, kind=kind):
            lp, slc = inp
            h, kp, vp = _paged_block_decode(
                lp, cfg, kind, h, slc["k"], slc["v"], block_tables, lengths,
                active, use_kernel=use_kernel, interpret=interpret)
            return h, {"k": kp, "v": vp}

        x, new_c = jax.lax.scan(body, x, (stacked, cache[name]))
        new_caches[name] = new_c
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = _head(cfg, params, x)[:, 0]
    return logits, new_caches
