"""Encoder-decoder backbone (seamless-m4t-medium [arXiv:2308.11596]).

Bidirectional encoder over (stub) audio-frame embeddings; causal decoder
with cross-attention over encoder memory.  LayerNorm (pre-LN), GELU FFN,
standard RoPE on self-attention; cross-attention is position-free (the
NLLB/seamless convention approximated — see DESIGN.md §Arch-applicability).

Decode path: self-attn KV cache + per-layer cross-KV computed once from the
encoder memory at prefill.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.models.attention import attention, attn_params, decode_attention
from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, dense_init, embed_init, norm_params
from repro.models.mlp import mlp, mlp_params

__all__ = [
    "init_params_encdec",
    "encode",
    "forward_encdec",
    "prefill_encdec",
    "decode_step_encdec",
    "init_cache_encdec",
]


def _enc_block_params(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
        "attn": attn_params(k1, cfg),
        "ln2": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
        "mlp": mlp_params(k2, cfg),
    }


def _dec_block_params(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
        "attn": attn_params(k1, cfg),
        "ln_cross": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
        "cross_attn": attn_params(k2, cfg),
        "ln2": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
        "mlp": mlp_params(k3, cfg),
    }


def init_params_encdec(cfg: ModelConfig, key) -> dict:
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": {"table": embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.pdtype)},
        "enc_layers": jax.vmap(lambda k: _enc_block_params(k, cfg))(enc_keys),
        "enc_norm": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
        "layers": jax.vmap(lambda k: _dec_block_params(k, cfg))(dec_keys),
        "final_norm": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
        "lm_head": {"w": dense_init(kh, cfg.d_model, cfg.vocab_size, cfg.pdtype)},
    }


def encode(cfg: ModelConfig, params, src_embeds):
    """src_embeds: (B, S_src, d) from the (stub) audio frontend."""
    x = src_embeds.astype(cfg.cdtype)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = shard_activation(x, "dp", None, None)

    def body(h, lp):
        hn = apply_norm(cfg.norm, lp["ln1"], h)
        h = h + attention(lp["attn"], cfg, hn, pos, causal=False)
        hn = apply_norm(cfg.norm, lp["ln2"], h)
        return h + mlp(lp["mlp"], cfg, hn), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_block(lp, cfg, x, pos, memory):
    hn = apply_norm(cfg.norm, lp["ln1"], x)
    x = x + attention(lp["attn"], cfg, hn, pos, causal=True)
    hn = apply_norm(cfg.norm, lp["ln_cross"], x)
    x = x + attention(lp["cross_attn"], cfg, hn, pos, causal=False, kv_x=memory)
    hn = apply_norm(cfg.norm, lp["ln2"], x)
    return x + mlp(lp["mlp"], cfg, hn)


def forward_encdec(cfg: ModelConfig, params, src_embeds, tgt_tokens):
    """Training forward: encode once, teacher-forced decoder.  → logits."""
    memory = encode(cfg, params, src_embeds)
    x = jnp.take(params["embed"]["table"], tgt_tokens, axis=0).astype(cfg.cdtype)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(h, lp):
        return _dec_block(lp, cfg, h, pos, memory), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"].astype(cfg.cdtype),
                        preferred_element_type=jnp.float32)
    return shard_activation(logits, "dp", None, "model"), jnp.float32(0.0)


def init_cache_encdec(cfg: ModelConfig, batch: int, max_len: int, src_len: int):
    L, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch, max_len, hkv, hd), cfg.cdtype),
        "v": jnp.zeros((L, batch, max_len, hkv, hd), cfg.cdtype),
        "cross_k": jnp.zeros((L, batch, src_len, hkv, hd), cfg.cdtype),
        "cross_v": jnp.zeros((L, batch, src_len, hkv, hd), cfg.cdtype),
    }


def prefill_encdec(cfg: ModelConfig, params, src_embeds, tgt_tokens,
                   max_len: Optional[int] = None):
    """Encode + decoder prefill.  Returns (last_logits, cache)."""
    memory = encode(cfg, params, src_embeds)
    x = jnp.take(params["embed"]["table"], tgt_tokens, axis=0).astype(cfg.cdtype)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(h, lp):
        hn = apply_norm(cfg.norm, lp["ln1"], h)
        a, (k, v) = attention(lp["attn"], cfg, hn, pos, causal=True, return_kv=True)
        h = h + a
        hn = apply_norm(cfg.norm, lp["ln_cross"], h)
        c, (ck, cv) = attention(lp["cross_attn"], cfg, hn, pos, causal=False,
                                kv_x=memory, return_kv=True)
        h = h + c
        hn = apply_norm(cfg.norm, lp["ln2"], h)
        return h + mlp(lp["mlp"], cfg, hn), {"k": k, "v": v, "cross_k": ck, "cross_v": cv}

    x, cache = jax.lax.scan(body, x, params["layers"])
    if max_len is not None and max_len > S:
        pad = max_len - S
        cache["k"] = jnp.pad(cache["k"], ((0, 0),) * 2 + ((0, pad),) + ((0, 0),) * 2)
        cache["v"] = jnp.pad(cache["v"], ((0, 0),) * 2 + ((0, pad),) + ((0, 0),) * 2)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["lm_head"]["w"].astype(cfg.cdtype),
                        preferred_element_type=jnp.float32)
    return logits, cache


def decode_step_encdec(cfg: ModelConfig, params, token, cache, lengths):
    """One decoder token with cached self-KV and cross-KV."""
    x = jnp.take(params["embed"]["table"], token[:, None], axis=0).astype(cfg.cdtype)

    def body(h, inp):
        lp, ck, cv, xk, xv = inp
        hn = apply_norm(cfg.norm, lp["ln1"], h)
        a, nk, nv = decode_attention(lp["attn"], cfg, hn, ck, cv, lengths)
        h = h + a
        hn = apply_norm(cfg.norm, lp["ln_cross"], h)
        # cross-attention against fixed memory KV (no cache update)
        c = _cross_decode(lp["cross_attn"], cfg, hn, xk, xv)
        h = h + c
        hn = apply_norm(cfg.norm, lp["ln2"], h)
        return h + mlp(lp["mlp"], cfg, hn), (nk, nv)

    x, (new_k, new_v) = jax.lax.scan(
        body, x,
        (params["layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    new_cache = dict(cache, k=new_k, v=new_v)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["lm_head"]["w"].astype(cfg.cdtype),
                        preferred_element_type=jnp.float32)
    return logits, new_cache


def _cross_decode(p, cfg, x, xk, xv):
    """Single-query cross-attention over precomputed memory KV."""
    import math
    cd = cfg.cdtype
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    hkv = xk.shape[2]
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, hkv, g, cfg.hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        xk.astype(jnp.float32)) / math.sqrt(cfg.hd)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(cd), xv)
    out = out.reshape(b, 1, cfg.n_heads, cfg.hd)
    return jnp.einsum("bshd,hdm->bsm", out.astype(cd), p["wo"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
