"""Grouped-query attention with RoPE variants, KV cache, sliding window.

Pure-jnp reference implementation used by training, prefill and decode.
The Pallas kernels in ``repro.kernels`` implement the same math
(``flash_attention`` for prefill, ``decode_attention`` for decode) and are
validated against this module; on TPU the serving/training step builders can
swap them in via ``repro.kernels.ops``.

Shapes:
  x          (B, S, d_model)
  q          (B, S, H, hd)      k/v (B, S, Hkv, hd)
  cache k/v  (B, S_max, Hkv, hd)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    apply_rope_half,
    dense_init,
)

__all__ = ["attn_params", "attention", "decode_attention", "init_kv_cache"]

NEG_INF = -1e30


def attn_params(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, cfg.pdtype).reshape(d, cfg.n_heads, hd),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, cfg.pdtype).reshape(d, cfg.n_kv_heads, hd),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, cfg.pdtype).reshape(d, cfg.n_kv_heads, hd),
        "wo": dense_init(ko, cfg.n_heads * hd, d, cfg.pdtype).reshape(cfg.n_heads, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), cfg.pdtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), cfg.pdtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), cfg.pdtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_x=None):
    cd = cfg.cdtype
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    k = jnp.einsum("bsd,dhk->bshk", kv_x.astype(cd), p["wk"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    v = jnp.einsum("bsd,dhk->bshk", kv_x.astype(cd), p["wv"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def _rope(cfg: ModelConfig, q, k, positions):
    if cfg.rope == "standard":
        return apply_rope(q, k, positions, cfg.rope_theta)
    if cfg.rope == "half":
        return apply_rope_half(q, k, positions, cfg.rope_theta)
    if cfg.rope == "mrope":
        pos3 = positions if positions.ndim == 3 else jnp.broadcast_to(
            positions[None], (3,) + positions.shape
        )
        return apply_mrope(q, k, pos3, cfg.rope_theta, cfg.mrope_sections)
    if cfg.rope == "none":
        return q, k
    raise ValueError(cfg.rope)


def _gqa_scores(q, k):
    """(B,S,H,hd) x (B,T,Hkv,hd) -> (B,Hkv,G,S,T) with G = H // Hkv."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    return jnp.einsum(
        "bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)


def attention(
    p: dict,
    cfg: ModelConfig,
    x,
    positions,
    *,
    causal: bool = True,
    kv_x=None,
    kv_positions=None,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill / encoder / cross).

    ``kv_x`` != None → cross-attention (no RoPE on cross, per seamless-m4t).
    Sliding-window mask applied when ``cfg.attn_window > 0`` and causal.
    """
    cd = cfg.cdtype
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if kv_x is None and cfg.rope != "none":
        q, k = _rope(cfg, q, k, positions)
    q = shard_activation(q, "dp", None, "model", None)
    k = shard_activation(k, "dp", None, "model", None)

    b, s, h, hd = q.shape
    hkv = k.shape[2]
    qpos = None
    if causal and kv_x is None:
        qpos = positions if positions.ndim == 2 else positions[0]

    if cfg.attn_chunk and s > cfg.attn_chunk and s % cfg.attn_chunk == 0:
        # Chunked (flash-style) scores: scan query blocks so the biggest
        # intermediate is (B,Hkv,G,C,S) instead of (B,Hkv,G,S,S).  Exact —
        # softmax rows are independent.
        C = cfg.attn_chunk
        g = h // hkv
        qg = q.reshape(b, s // C, C, hkv, g, hd)
        qc = jnp.moveaxis(qg, 1, 0)  # (nc, b, C, hkv, g, hd)
        pc = (
            jnp.moveaxis(qpos.reshape(b, s // C, C), 1, 0)
            if qpos is not None else jnp.zeros((s // C, b, C), jnp.int32)
        )
        kpos = qpos if qpos is not None else None

        def chunk_fn(_, inp):
            q_blk, p_blk = inp  # (b,C,hkv,g,hd), (b,C)
            sc = jnp.einsum(
                "bskgd,btkd->bkgst", q_blk.astype(jnp.float32),
                k.astype(jnp.float32)) / math.sqrt(hd)
            if qpos is not None:
                m = p_blk[:, None, None, :, None] >= kpos[:, None, None, None, :]
                if cfg.attn_window > 0:
                    m &= (p_blk[:, None, None, :, None]
                          - kpos[:, None, None, None, :]) < cfg.attn_window
                sc = jnp.where(m, sc, NEG_INF)
            pr = jax.nn.softmax(sc, axis=-1)
            o = jnp.einsum("bkgst,btkd->bskgd", pr.astype(cd), v)
            return _, o.reshape(b, q_blk.shape[1], h, hd)

        _, out_c = jax.lax.scan(chunk_fn, 0, (qc, pc))
        out = jnp.moveaxis(out_c, 0, 1).reshape(b, s, h, hd)
    else:
        scores = _gqa_scores(q, k)  # (B,Hkv,G,S,T)
        if qpos is not None:
            kpos = qpos
            m = qpos[:, None, None, :, None] >= kpos[:, None, None, None, :]
            if cfg.attn_window > 0:
                m &= (
                    qpos[:, None, None, :, None] - kpos[:, None, None, None, :]
                    < cfg.attn_window
                )
            scores = jnp.where(m, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(cd), v)
        out = out.reshape(b, s, h, hd)
    y = jnp.einsum("bshd,hdm->bsm", out.astype(cd), p["wo"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    y = shard_activation(y, "dp", None, None)
    if return_kv:
        return y, (k, v)
    return y


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers=None):
    """Stacked-over-layers KV cache (L, B, S, Hkv, hd)."""
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, cfg.cdtype),
        "v": jnp.zeros(shape, cfg.cdtype),
    }


def decode_attention(
    p: dict,
    cfg: ModelConfig,
    x,                      # (B, 1, d_model)
    cache_k,                # (B, S_max, Hkv, hd) — this layer's slice
    cache_v,
    lengths,                # (B,) int32: current context length per request
    *,
    window: Optional[int] = None,
):
    """One-token decode with KV-cache append.

    The new KV is written at ``lengths % S_max`` (a ring buffer when
    ``window`` is set — hymba's sliding-window layers — and a plain append
    otherwise).  Attention masks out slots ≥ length (or outside the window).
    Returns (y, new_cache_k, new_cache_v).
    """
    cd = cfg.cdtype
    b, one, d = x.shape
    s_max = cache_k.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x)
    if cfg.rope != "none":
        rope_pos = lengths[:, None]  # (B,1) true positions
        if cfg.rope == "mrope":
            rope_pos = jnp.broadcast_to(rope_pos[None], (3, b, 1))
        q, k_new = _rope(cfg, q, k_new, rope_pos)

    slot = (lengths % s_max)[:, None] if window else jnp.minimum(lengths, s_max - 1)[:, None]
    # Scatter-update ONE slot per lane (O(B·Hkv·hd) traffic, in-place with
    # buffer donation).  The earlier one_hot read-modify-write streamed the
    # whole cache per step AND invited GSPMD to reshard it (a 2×34 GiB
    # all-gather appeared in the decode HLO) — see EXPERIMENTS.md §Perf.
    b_ix = jnp.arange(b)[:, None]
    cache_k = cache_k.at[b_ix, slot].set(k_new.astype(cache_k.dtype))
    cache_v = cache_v.at[b_ix, slot].set(v_new.astype(cache_v.dtype))

    scores = _gqa_scores(q, cache_k)  # (B,Hkv,G,1,S_max)
    idx = jnp.arange(s_max)
    if window:
        # ring buffer: valid slots are the last `window` positions
        valid = (idx[None, :] * 0 + 1).astype(bool)
        age = (slot[:, :1] - idx[None, :]) % s_max  # distance backwards
        valid = age < jnp.minimum(lengths + 1, window)[:, None]
    else:
        valid = idx[None, :] <= jnp.minimum(lengths, s_max - 1)[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    hkv, g = cache_k.shape[2], cfg.n_heads // cfg.n_kv_heads
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(cd), cache_v)
    out = out.reshape(b, 1, cfg.n_heads, cfg.hd)
    y = jnp.einsum("bshd,hdm->bsm", out.astype(cd), p["wo"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    return y, cache_k, cache_v
