"""ModelConfig — one dataclass that spans all ten assigned architectures.

Every field corresponds to a published architecture choice (see
``src/repro/configs/<id>.py`` for citations).  ``reduced()`` derives the
small smoke-test variant required by the brief (same family, tiny widths).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell: ``kind`` selects which step gets lowered."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned LM shape set (identical for every arch, with per-family skips).
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | vlm | audio | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention details
    qkv_bias: bool = False
    attn_window: int = 0  # 0 = full attention; >0 = sliding window
    rope: str = "standard"  # standard | half (chatglm 2d) | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()

    # norms / activations
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"  # swiglu | gelu | geglu
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4

    # hybrid (hymba): attention and SSM heads run in parallel per block
    hybrid: bool = False

    # encoder-decoder (seamless-m4t)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: the backbone consumes precomputed embeddings
    frontend: str = "none"  # none | patch_stub | audio_stub

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # paper integration: route the embedding lookup through async_query so
    # loops over microbatches can be fissioned into one batched gather.
    query_embedding: bool = False

    # activation checkpointing policy for scan-over-layers
    remat: bool = True

    # chunked (flash-style) attention: bound the score materialization to
    # (B, H, attn_chunk, S) by scanning query blocks — exact same math,
    # O(S·chunk) memory instead of O(S²).  0 = off (one-shot scores).
    attn_chunk: int = 0

    # ----------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def supports_long_context(self) -> bool:
        """long_500k needs sub-quadratic attention: SSM or windowed hybrid."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec included)

    def shapes(self) -> list[ShapeSpec]:
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
        if self.supports_long_context:
            out.append(SHAPES["long_500k"])
        return out

    # ----------------------------------------------------------- param count
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        att = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.qkv_bias:
            att += (nh + 2 * nkv) * hd
        per_layer = 0
        n_attn_layers = self.n_layers if self.family != "ssm" else 0
        n_moe_layers = max(0, self.n_layers - self.first_dense_layers) if self.is_moe else 0
        n_dense_ff_layers = self.n_layers - n_moe_layers if not self.is_ssm else 0
        # attention + norms
        if self.family != "ssm":
            per_layer += att + 2 * (d if self.norm != "nonparam_ln" else 0)
        # dense FFN
        ff_params = 3 * d * self.d_ff if self.act in ("swiglu", "geglu") else 2 * d * self.d_ff
        n_total = n + n_attn_layers * (att + (2 * d if self.norm != "nonparam_ln" else 0))
        n_total += n_dense_ff_layers * ff_params
        if self.is_moe:
            e_ff = 3 * d * self.moe_d_ff
            n_total += n_moe_layers * (
                self.n_experts * e_ff
                + self.n_shared_experts * e_ff
                + d * self.n_experts  # router
            )
        if self.family in ("ssm", "hybrid"):
            sh, sp, ns = self.ssm_heads, self.ssm_head_dim, self.ssm_state
            d_inner = sh * sp
            ssm = (
                d * (2 * d_inner + 2 * ns + sh)  # in_proj (x, z, B, C, dt)
                + d_inner * d  # out_proj
                + self.ssm_conv * (d_inner + 2 * ns)  # conv
                + 2 * sh  # A_log, D
            )
            n_total += self.n_layers * ssm
        if self.is_encoder_decoder:
            # encoder layers + cross attention in decoder
            enc = self.n_enc_layers * (att + ff_params + 2 * d)
            cross = self.n_layers * att
            n_total += enc + cross
        return int(n_total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = max(0, self.n_layers - self.first_dense_layers)
        e_ff = 3 * self.d_model * self.moe_d_ff
        inactive = n_moe_layers * (self.n_experts - self.top_k) * e_ff
        return int(full - inactive)

    # ------------------------------------------------------------- reduction
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            remat=False,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.is_moe:
            scale.update(n_experts=4, n_shared_experts=min(self.n_shared_experts, 1),
                         top_k=2, moe_d_ff=32,
                         first_dense_layers=min(self.first_dense_layers, 1))
        if self.family in ("ssm", "hybrid"):
            scale.update(ssm_state=8, ssm_heads=4, ssm_head_dim=8, ssm_chunk=8)
        if self.is_encoder_decoder:
            scale.update(n_enc_layers=2)
        if self.attn_window:
            scale.update(attn_window=16)
        if self.mrope_sections:
            scale.update(mrope_sections=(2, 3, 3))
        return dataclasses.replace(self, name=self.name + "-reduced", **scale)
