"""Decoder-only LM covering the dense / MoE / SSM / hybrid / VLM families.

Scan-over-layers everywhere: layer parameters are stacked along a leading
layer axis and the depth loop is one ``lax.scan`` — constant-size HLO
regardless of depth (61-layer MoE dry-runs compile in seconds) and the
idiomatic TPU form.  MoE archs keep their ``first_dense_layers`` in a
separate (smaller) stack, matching DeepSeekMoE/Kimi-K2.

Entry points:
  init_params(cfg, key)                       → params pytree
  forward(cfg, params, tokens | embeds, pos)  → (logits, aux_loss)
  prefill(cfg, params, tokens, pos)           → (logits, cache)
  decode_step(cfg, params, token, cache, len) → (logits, cache)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.query import async_query, table_gather_spec
from repro.distributed.sharding import shard_activation
from repro.models.attention import (
    attention,
    attn_params,
    decode_attention,
    init_kv_cache,
)
from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, dense_init, embed_init, norm_params
from repro.models.mlp import mlp, mlp_params
from repro.models.moe import moe, moe_params
from repro.models.ssm import (
    init_ssm_state,
    ssm_decode_step,
    ssm_forward,
    ssm_params,
)

__all__ = [
    "init_params",
    "forward",
    "prefill",
    "decode_step",
    "init_cache",
    "block_kind",
]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def block_kind(cfg: ModelConfig, moe_stack: bool) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.hybrid:
        return "hybrid"
    if cfg.is_moe and moe_stack:
        return "moe"
    return "dense"


def _block_params(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {}
    if kind != "ssm":
        p["ln1"] = norm_params(cfg.norm, cfg.d_model, cfg.pdtype)
        p["attn"] = attn_params(ks[0], cfg)
        p["ln2"] = norm_params(cfg.norm, cfg.d_model, cfg.pdtype)
        if kind == "moe":
            p["moe"] = moe_params(ks[1], cfg)
        else:
            p["mlp"] = mlp_params(ks[1], cfg)
    else:
        p["ln1"] = norm_params(cfg.norm, cfg.d_model, cfg.pdtype)
    if kind in ("ssm", "hybrid"):
        p["ssm"] = ssm_params(ks[2], cfg)
        if kind == "hybrid":
            p["ssm_branch_norm"] = norm_params("rmsnorm", cfg.d_model, cfg.pdtype)
            p["attn_branch_norm"] = norm_params("rmsnorm", cfg.d_model, cfg.pdtype)
    return p


def _block_forward(p, cfg: ModelConfig, kind: str, x, positions):
    """Full-sequence block (training / prefill w/o cache).  → (x, aux)."""
    aux = jnp.float32(0.0)
    if kind == "ssm":
        h = apply_norm(cfg.norm, p["ln1"], x)
        x = x + ssm_forward(p["ssm"], cfg, h)
        return x, aux
    h = apply_norm(cfg.norm, p["ln1"], x)
    if kind == "hybrid":
        # Hymba [arXiv:2411.13676]: attention and SSM heads in parallel on
        # the same input; per-branch RMSNorm, then mean.
        a = attention(p["attn"], cfg, h, positions, causal=True)
        s = ssm_forward(p["ssm"], cfg, h)
        a = apply_norm("rmsnorm", p["attn_branch_norm"], a)
        s = apply_norm("rmsnorm", p["ssm_branch_norm"], s)
        x = x + 0.5 * (a + s)
    else:
        x = x + attention(p["attn"], cfg, h, positions, causal=True)
    h2 = apply_norm(cfg.norm, p["ln2"], x)
    if kind == "moe":
        y, aux = moe(p["moe"], cfg, h2)
    else:
        y = mlp(p["mlp"], cfg, h2)
    return x + y, aux


def _block_prefill(p, cfg: ModelConfig, kind: str, x, positions):
    """→ (x, aux, (k, v) or None).  SSM state from prefill is produced by
    running ssm_forward with return_state."""
    aux = jnp.float32(0.0)
    kv = None
    ssm_state = None
    if kind == "ssm":
        h = apply_norm(cfg.norm, p["ln1"], x)
        y, ssm_state = ssm_forward(p["ssm"], cfg, h, return_state=True)
        return x + y, aux, kv, ssm_state
    h = apply_norm(cfg.norm, p["ln1"], x)
    if kind == "hybrid":
        a, kv = attention(p["attn"], cfg, h, positions, causal=True, return_kv=True)
        s, ssm_state = ssm_forward(p["ssm"], cfg, h, return_state=True)
        a = apply_norm("rmsnorm", p["attn_branch_norm"], a)
        s = apply_norm("rmsnorm", p["ssm_branch_norm"], s)
        x = x + 0.5 * (a + s)
    else:
        a, kv = attention(p["attn"], cfg, h, positions, causal=True, return_kv=True)
        x = x + a
    h2 = apply_norm(cfg.norm, p["ln2"], x)
    y, aux = (moe(p["moe"], cfg, h2) if kind == "moe" else (mlp(p["mlp"], cfg, h2), aux))
    return x + y, aux, kv, ssm_state


def _block_decode(p, cfg: ModelConfig, kind: str, x, cache_slice, lengths):
    """One-token decode.  cache_slice: per-layer dict of cache arrays."""
    new_cache = dict(cache_slice)
    if kind == "ssm":
        h = apply_norm(cfg.norm, p["ln1"], x)
        y, s, c = ssm_decode_step(p["ssm"], cfg, h, cache_slice["ssm"], cache_slice["conv"])
        new_cache["ssm"], new_cache["conv"] = s, c
        return x + y, new_cache
    h = apply_norm(cfg.norm, p["ln1"], x)
    window = cfg.attn_window if cfg.attn_window > 0 else None
    if kind == "hybrid":
        a, ck, cv = decode_attention(
            p["attn"], cfg, h, cache_slice["k"], cache_slice["v"], lengths, window=window
        )
        s, st, cc = ssm_decode_step(p["ssm"], cfg, h, cache_slice["ssm"], cache_slice["conv"])
        a = apply_norm("rmsnorm", p["attn_branch_norm"], a)
        s = apply_norm("rmsnorm", p["ssm_branch_norm"], s)
        x = x + 0.5 * (a + s)
        new_cache.update(k=ck, v=cv, ssm=st, conv=cc)
    else:
        a, ck, cv = decode_attention(
            p["attn"], cfg, h, cache_slice["k"], cache_slice["v"], lengths, window=window
        )
        x = x + a
        new_cache.update(k=ck, v=cv)
    h2 = apply_norm(cfg.norm, p["ln2"], x)
    y = moe(p["moe"], cfg, h2)[0] if kind == "moe" else mlp(p["mlp"], cfg, h2)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, kd, kh, kf = jax.random.split(key, 5)
    n_moe = cfg.n_layers - cfg.first_dense_layers if cfg.is_moe else 0
    n_main = n_moe if cfg.is_moe else cfg.n_layers
    main_kind = block_kind(cfg, moe_stack=True)

    def stack_init(k, n, kind):
        keys = jax.random.split(k, n)
        return jax.vmap(lambda kk: _block_params(kk, cfg, kind))(keys)

    p = {
        "embed": {"table": embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.pdtype)},
        "layers": stack_init(kl, n_main, main_kind),
        "final_norm": norm_params(cfg.norm, cfg.d_model, cfg.pdtype),
    }
    if cfg.is_moe and cfg.first_dense_layers > 0:
        p["dense_layers"] = stack_init(kd, cfg.first_dense_layers, "dense")
    if not cfg.tie_embeddings:
        p["lm_head"] = {"w": dense_init(kh, cfg.d_model, cfg.vocab_size, cfg.pdtype)}
    return p


def _embed(cfg: ModelConfig, params, tokens):
    table = params["embed"]["table"]
    if cfg.query_embedding:
        # the paper's "query": a per-step table lookup, batchable by fission
        emb = async_query(table_gather_spec, table, tokens)
    else:
        emb = jnp.take(table, tokens, axis=0)
    return emb.astype(cfg.cdtype)


def _head(cfg: ModelConfig, params, x):
    w = (
        params["embed"]["table"].T
        if cfg.tie_embeddings
        else params["lm_head"]["w"]
    )
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(cfg.cdtype), w.astype(cfg.cdtype),
        preferred_element_type=jnp.float32,
    )
    return shard_activation(logits, "dp", None, "model")


def _layer_stacks(cfg: ModelConfig, params):
    """[(stacked_params, kind, n_layers)] in execution order."""
    out = []
    if cfg.is_moe and cfg.first_dense_layers > 0:
        out.append((params["dense_layers"], "dense", cfg.first_dense_layers))
    n_main = cfg.n_layers - (cfg.first_dense_layers if cfg.is_moe else 0)
    out.append((params["layers"], block_kind(cfg, True), n_main))
    return out


def _run_stack(cfg, stacked, kind, x, positions, mode, cache=None, lengths=None):
    """Scan one layer stack.  mode: 'forward' | 'prefill' | 'decode'."""

    if mode == "forward":

        def body(h, lp):
            h, aux = _block_forward(lp, cfg, kind, h, positions)
            return h, aux

        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, stacked)
        return x, auxs.sum(), None

    if mode == "prefill":

        def body(h, lp):
            h, aux, kv, ssm_state = _block_prefill(lp, cfg, kind, h, positions)
            ys = {}
            if kv is not None:
                ys["k"], ys["v"] = kv
            if ssm_state is not None:
                ys.update(ssm_state)  # {"ssm": ..., "conv": ...}
            return h, (aux, ys)

        if cfg.remat:
            body = jax.checkpoint(body)
        x, (auxs, cache_out) = jax.lax.scan(body, x, stacked)
        return x, auxs.sum(), cache_out

    # decode
    def body(h, inp):
        lp, cache_slice = inp
        h, new_slice = _block_decode(lp, cfg, kind, h, cache_slice, lengths)
        return h, new_slice

    x, new_cache = jax.lax.scan(body, x, (stacked, cache))
    return x, jnp.float32(0.0), new_cache


def forward(cfg: ModelConfig, params, tokens=None, positions=None, embeds=None):
    """Training forward.  tokens (B,S) int32 or embeds (B,S,d) for stub
    frontends.  → (logits (B,S,V) fp32, aux_loss)."""
    x = _embed(cfg, params, tokens) if embeds is None else embeds.astype(cfg.cdtype)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = shard_activation(x, "dp", None, None)
    aux_total = jnp.float32(0.0)
    for stacked, kind, _n in _layer_stacks(cfg, params):
        x, aux, _ = _run_stack(cfg, stacked, kind, x, positions, "forward")
        aux_total = aux_total + aux
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return _head(cfg, params, x), aux_total


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Stacked decode cache for every stack, keyed by stack name."""
    caches = {}
    for name, kind, n in _stack_names(cfg):
        c: dict = {}
        if kind in ("dense", "moe", "hybrid"):
            kv_len = min(max_len, cfg.attn_window) if cfg.attn_window > 0 else max_len
            kv = init_kv_cache(cfg, batch, kv_len, n_layers=n)
            c["k"], c["v"] = kv["k"], kv["v"]
        if kind in ("ssm", "hybrid"):
            s = init_ssm_state(cfg, batch, n_layers=n)
            c["ssm"], c["conv"] = s["ssm"], s["conv"]
        caches[name] = c
    return caches


def _stack_names(cfg: ModelConfig):
    out = []
    if cfg.is_moe and cfg.first_dense_layers > 0:
        out.append(("dense_layers", "dense", cfg.first_dense_layers))
    n_main = cfg.n_layers - (cfg.first_dense_layers if cfg.is_moe else 0)
    out.append(("layers", block_kind(cfg, True), n_main))
    return out


def prefill(cfg: ModelConfig, params, tokens=None, positions=None, embeds=None,
            max_len: Optional[int] = None, return_all_logits: bool = False):
    """Full-sequence prefill.  → (logits (B,V) at the last position — or
    (B,S,V) with ``return_all_logits`` for right-padded serving batches —
    and the cache).

    ``max_len`` pads the KV cache to the decode capacity (serving); windowed
    caches are re-laid out as ring buffers of size ``cfg.attn_window``.
    Right-padded prompts are safe: causal masking keeps pad keys invisible
    to real queries, and decode overwrites pad KV slots before attending
    them (per-lane ``lengths`` gate validity).
    """
    x = _embed(cfg, params, tokens) if embeds is None else embeds.astype(cfg.cdtype)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = shard_activation(x, "dp", None, None)
    caches = {}
    for (name, kind, _n), (stacked, kind2, _n2) in zip(
        _stack_names(cfg), _layer_stacks(cfg, params)
    ):
        x, _aux, cache_out = _run_stack(cfg, stacked, kind, x, positions, "prefill")
        c = dict(cache_out or {})
        if kind in ("dense", "moe", "hybrid"):
            if cfg.attn_window > 0:
                # Ring-buffer re-layout: keep the last W tokens, placing
                # token p at slot p % W (what decode expects).
                W = cfg.attn_window
                if S >= W:
                    lk, lv = c["k"][:, :, -W:], c["v"][:, :, -W:]
                    shift = S % W
                    c["k"] = jnp.roll(lk, shift, axis=2)
                    c["v"] = jnp.roll(lv, shift, axis=2)
                else:  # S < W: slots p = p, pad tail
                    pad = W - S
                    c["k"] = jnp.pad(c["k"], ((0, 0),) * 2 + ((0, pad),) + ((0, 0),) * 2)
                    c["v"] = jnp.pad(c["v"], ((0, 0),) * 2 + ((0, pad),) + ((0, 0),) * 2)
            elif max_len is not None and max_len > S:
                pad = max_len - S
                c["k"] = jnp.pad(c["k"], ((0, 0),) * 2 + ((0, pad),) + ((0, 0),) * 2)
                c["v"] = jnp.pad(c["v"], ((0, 0),) * 2 + ((0, pad),) + ((0, 0),) * 2)
        caches[name] = c
    x = apply_norm(cfg.norm, params["final_norm"], x)
    if return_all_logits:
        return _head(cfg, params, x), caches
    logits = _head(cfg, params, x[:, -1:])[:, 0]
    return logits, caches


def decode_step(cfg: ModelConfig, params, token, cache: dict, lengths):
    """token (B,) int32, lengths (B,) int32 → (logits (B,V), new_cache)."""
    x = _embed(cfg, params, token[:, None])
    new_caches = {}
    for (name, kind, _n), (stacked, _k2, _n2) in zip(
        _stack_names(cfg), _layer_stacks(cfg, params)
    ):
        x, _aux, new_c = _run_stack(
            cfg, stacked, kind, x, None, "decode", cache=cache[name], lengths=lengths
        )
        new_caches[name] = new_c
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = _head(cfg, params, x)[:, 0]
    return logits, new_caches
