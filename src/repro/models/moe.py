"""Mixture-of-Experts layer — DeepSeekMoE-style fine-grained experts with
shared experts [arXiv:2401.06066], used by deepseek-moe-16b (64e top-6,
2 shared) and kimi-k2 (384e top-8, 1 shared) [arXiv:2501.kimi2].

TPU-native dispatch (GShard/Switch capacity model, scatter form), hardened
through three §Perf iterations (full log in EXPERIMENTS.md):

  B1  a combine that *gathers* eo[b, e_ix, c_ix] across the EP-sharded
      expert axis made GSPMD materialize a replicated (B,S,K,d) tensor and
      all-reduce 1.4 TB per site — replaced by an inverse-map scatter-add;
  B2  sharding constraints on the zero-filled scatter targets are folded
      away with the constant, so GSPMD still replicated the dispatch — the
      lesson: *constraint propagation cannot express masked-local scatter*;
  B3  the dispatch/expert/combine block therefore runs under an explicit
      ``shard_map`` over (dp × model): every device scatters only the
      tokens routed to ITS experts (out-of-range expert ids fall out of
      bounds and are dropped — locality for free), computes its expert FFNs,
      scatter-adds partial token outputs, and ONE ``psum`` over ``model``
      combines them.  Per layer the only collective is that (B_loc, S, d)
      all-reduce — the all-to-all-equivalent floor for capacity-style MoE.

Routing runs in fp32; the Switch-style load-balance aux loss is returned
for training.  Without an ambient mesh (smoke tests, single device) the
same local function runs over the full expert range (e_offset=0, psum
skipped) — one code path, two execution layouts.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_mesh, shard_activation
from repro.models.config import ModelConfig
from repro.models.layers import dense_init

__all__ = ["moe_params", "moe"]


def moe_params(key, cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    kr, ke, ks = jax.random.split(key, 3)

    def expert_stack(k, n):
        k1, k2, k3 = jax.random.split(k, 3)
        s = 1.0 / math.sqrt(d)
        return {
            "w_gate": (jax.random.normal(k1, (n, d, ff)) * s).astype(cfg.pdtype),
            "w_in": (jax.random.normal(k2, (n, d, ff)) * s).astype(cfg.pdtype),
            "w_out": (jax.random.normal(k3, (n, ff, d)) * (1.0 / math.sqrt(ff))).astype(cfg.pdtype),
        }

    p = {
        "router": {"w": dense_init(kr, d, E, jnp.float32)},
        "experts": expert_stack(ke, E),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = expert_stack(ks, cfg.n_shared_experts)
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4, floor 4


def _route(router_w, cfg: ModelConfig, x, C):
    """fp32 routing → (expert_idx, gate_vals, pos).  Deterministic given x,
    so every model-shard computes identical assignments (no comm)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # position-in-expert: exclusive running count over the (S·K) stream
    flat_idx = expert_idx.reshape(B, S * K)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(pos_all, flat_idx[..., None], axis=-1)[..., 0]
    pos = pos.reshape(B, S, K)
    gate_vals = gate_vals * (pos < C).astype(jnp.float32)
    return probs, expert_idx, gate_vals, pos


def _experts_local(weights, cfg, x, expert_idx, gate_vals, pos, C,
                   e_offset, E_loc):
    """Dispatch→FFN→combine for experts [e_offset, e_offset+E_loc).

    Locality trick: expert ids are shifted by -e_offset; ids outside
    [0, E_loc) (another shard's experts) go OUT OF BOUNDS and XLA's
    mode="drop" discards them — masked-local scatter with no mask tensor.
    Over-capacity positions (pos ≥ C) drop the same way.
    Returns the f32 partial (B,S,d); summing over shards = full MoE.
    """
    cd = cfg.cdtype
    B, S, d = x.shape
    K = cfg.top_k
    b_ix = jnp.arange(B)[:, None, None]
    e_loc = expert_idx - e_offset  # OOB for other shards' experts
    xk = jnp.broadcast_to(x[:, :, None, :], (B, S, K, d)).astype(cd)

    buf = jnp.zeros((B, E_loc, C, d), cd).at[b_ix, e_loc, pos].add(xk, mode="drop")

    w_gate, w_in, w_out = (weights[k].astype(cd) for k in ("w_gate", "w_in", "w_out"))
    g = jnp.einsum("becd,edf->becf", buf, w_gate, preferred_element_type=jnp.float32).astype(cd)
    h = jnp.einsum("becd,edf->becf", buf, w_in, preferred_element_type=jnp.float32).astype(cd)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * h
    eo = jnp.einsum("becf,efd->becd", h, w_out, preferred_element_type=jnp.float32)

    # inverse maps: which token fills each (e, c) slot, with which gate
    s_ids = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, K))
    token_of = jnp.zeros((B, E_loc, C), jnp.int32).at[b_ix, e_loc, pos].set(
        s_ids, mode="drop")
    gate_of = jnp.zeros((B, E_loc, C), jnp.float32).at[b_ix, e_loc, pos].set(
        gate_vals, mode="drop")
    weighted = eo.astype(jnp.float32) * gate_of[..., None]
    b_full = jnp.arange(B)[:, None, None]
    y = jnp.zeros((B, S, d), jnp.float32).at[b_full, token_of].add(weighted)
    return y


def moe(p: dict, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → (y, aux_loss)."""
    cd = cfg.cdtype
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, S)

    # aux loss on the full (replicated-routing) probabilities
    probs, expert_idx, gate_vals, pos = _route(p["router"]["w"], cfg, x, C)
    assign1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    frac = assign1.mean(axis=(0, 1))
    mprob = probs.mean(axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(frac * mprob)

    mesh = current_mesh()
    dp_axes = tuple(a for a in ("pod", "data") if mesh and a in mesh.axis_names)
    dp_size = 1
    if mesh:
        for a in dp_axes:
            dp_size *= mesh.shape[a]
    use_shard_map = (
        mesh is not None
        and "model" in mesh.axis_names
        and E % mesh.shape["model"] == 0
        and B % max(dp_size, 1) == 0
    )

    if use_shard_map:
        from jax.experimental.shard_map import shard_map

        n_model = mesh.shape["model"]
        E_loc = E // n_model
        dp_spec = dp_axes if dp_axes else None

        def block(x_l, ei_l, gv_l, pos_l, wg, wi, wo):
            e_off = jax.lax.axis_index("model") * E_loc
            y_part = _experts_local(
                {"w_gate": wg, "w_in": wi, "w_out": wo}, cfg,
                x_l, ei_l, gv_l, pos_l, C, e_off, E_loc)
            return jax.lax.psum(y_part, "model")

        y = shard_map(
            block, mesh=mesh,
            in_specs=(
                P(dp_spec, None, None),        # x
                P(dp_spec, None, None),        # expert_idx
                P(dp_spec, None, None),        # gates
                P(dp_spec, None, None),        # pos
                P("model", None, None),        # w_gate
                P("model", None, None),        # w_in
                P("model", None, None),        # w_out
            ),
            out_specs=P(dp_spec, None, None),
            check_rep=False,
        )(x, expert_idx, gate_vals, pos,
          p["experts"]["w_gate"], p["experts"]["w_in"], p["experts"]["w_out"])
    else:
        y = _experts_local(p["experts"], cfg, x, expert_idx, gate_vals, pos,
                           C, 0, E)
    y = y.astype(cd)

    # ---- shared experts (dense path over all tokens) -----------------------
    if "shared" in p:
        sw_g, sw_i, sw_o = (p["shared"][k].astype(cd) for k in ("w_gate", "w_in", "w_out"))
        sg = jnp.einsum("bsd,ndf->bsnf", x.astype(cd), sw_g, preferred_element_type=jnp.float32).astype(cd)
        sh = jnp.einsum("bsd,ndf->bsnf", x.astype(cd), sw_i, preferred_element_type=jnp.float32).astype(cd)
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(cd) * sh
        y = y + jnp.einsum("bsnf,nfd->bsd", sh, sw_o, preferred_element_type=jnp.float32).astype(cd)

    return shard_activation(y, "dp", None, None), aux
