"""SSD inter-chunk state scan (Mamba-2's only sequential dependency) on TPU.

The chunked SSD algorithm (``repro.models.ssm.ssd_chunked``) reduces the
whole sequence to per-chunk state contributions; what remains sequential is
the tiny first-order recurrence

    s_{c+1} = s_c * decay_c + states_c            (per (batch, head))

XLA lowers the ``lax.scan`` form as a while loop whose per-step kernels
re-launch and round-trip the (P, N) state through HBM every chunk.  This
kernel walks the chunk axis in the GRID (TPU grids execute sequentially per
core) and keeps the running state in VMEM scratch — one kernel launch, the
state never leaves VMEM, and each step streams exactly one (P, N) chunk
contribution in and one out.

Grid: (B·H, C), chunk minor.  Block shapes: states/prev (1, 1, P, N) with
P=64..128, N=64..256 → MXU/VPU-aligned lanes; decay is a (1, 1) SMEM-like
block.  VMEM working set: 3 × P·N·4 B ≈ 200 KiB at P=128, N=128.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(states_ref, decay_ref, prev_ref, final_ref, carry_ref):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    # emit the state ENTERING this chunk, then advance the recurrence
    prev_ref[0, 0] = carry_ref[...].astype(prev_ref.dtype)
    carry_ref[...] = (
        carry_ref[...] * decay_ref[0, 0]
        + states_ref[0, 0].astype(jnp.float32)
    )

    @pl.when(ci == nc - 1)
    def _final():
        final_ref[0] = carry_ref[...].astype(final_ref.dtype)


def ssd_scan(states, decay, *, interpret: bool = False):
    """states: (B, C, H, P, N); decay: (B, C, H) →
    (prev_states (B, C, H, P, N), final_state (B, H, P, N))."""
    b, c, h, p, n = states.shape
    sts = jnp.moveaxis(states, 2, 1).reshape(b * h, c, p, n)
    dec = jnp.moveaxis(decay, 2, 1).reshape(b * h, c)

    prev, final = pl.pallas_call(
        _kernel,
        grid=(b * h, c),
        in_specs=[
            pl.BlockSpec((1, 1, p, n), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, p, n), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, c, p, n), states.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(sts, dec)

    prev = jnp.moveaxis(prev.reshape(b, h, c, p, n), 1, 2)
    final = final.reshape(b, h, p, n)
    return prev, final
