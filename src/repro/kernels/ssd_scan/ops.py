"""jit'd wrapper for the SSD chunk-state scan (registry-dispatched)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import registry
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

__all__ = ["ssd_scan_op"]


def _sample(key) -> registry.OpSample:
    ks = jax.random.split(key, 2)
    states = jax.random.normal(ks[0], (2, 8, 4, 16, 32))
    decay = jax.nn.sigmoid(jax.random.normal(ks[1], (2, 8, 4)))
    return registry.OpSample(args=(states, decay))


registry.register("ssd_scan", ref=ssd_scan_ref, kernel=ssd_scan,
                  sample=_sample)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def ssd_scan_op(states, decay, *, use_kernel=True, interpret=False):
    """Inter-chunk SSD state scan → (state entering each chunk, final)."""
    return registry.dispatch("ssd_scan", (states, decay),
                             use_kernel=use_kernel, interpret=interpret)
