"""jit'd wrapper for the SSD chunk-state scan."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

__all__ = ["ssd_scan_op"]


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def ssd_scan_op(states, decay, *, use_kernel=True, interpret=False):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel and (on_tpu or interpret):
        return ssd_scan(states, decay, interpret=interpret or not on_tpu)
    return ssd_scan_ref(states, decay)
