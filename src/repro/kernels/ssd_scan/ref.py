"""Pure-jnp oracle for the SSD inter-chunk state recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(states, decay, initial_state=None):
    """states: (B, C, H, P, N) per-chunk contributions;
    decay: (B, C, H) per-chunk decays.

    Returns (prev_states (B, C, H, P, N) — the state ENTERING each chunk —
    and final_state (B, H, P, N)):
        s_0 = initial (zeros); s_{c+1} = s_c * decay_c + states_c
    """
    b, c, h, p, n = states.shape
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st_c, dec_c = inp
        new = carry * dec_c[:, :, None, None] + st_c
        return new, carry

    final, prev = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(decay.astype(jnp.float32), 1, 0)),
    )
    return jnp.moveaxis(prev, 0, 1), final
