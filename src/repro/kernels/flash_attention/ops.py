"""jit'd public wrapper for the flash-attention kernel.

``use_kernel=False`` (or a non-TPU backend without ``interpret``) falls back
to the jnp oracle, so models can call :func:`attention_op` unconditionally.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention_op"]


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "use_kernel", "interpret"))
def attention_op(q, k, v, *, causal=True, bq=512, bk=512, use_kernel=True,
                 interpret=False):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel and (on_tpu or interpret):
        return flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=interpret or not on_tpu)
    return attention_ref(q, k, v, causal=causal)
