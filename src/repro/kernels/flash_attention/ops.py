"""jit'd public wrapper for the flash-attention kernel (registry-dispatched).

``use_kernel=False`` (or a non-TPU backend without ``interpret``) falls back
to the jnp oracle, so models can call :func:`attention_op` unconditionally.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import registry
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention_op"]


def _sample(key) -> registry.OpSample:
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    return registry.OpSample(args=(q, k, v), common={"causal": True},
                             kernel={"bq": 32, "bk": 32})


registry.register("flash_attention", ref=attention_ref,
                  kernel=flash_attention, sample=_sample)


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "use_kernel", "interpret"))
def attention_op(q, k, v, *, causal=True, bq=512, bk=512, use_kernel=True,
                 interpret=False):
    """Batched multi-head (GQA) attention over full sequences."""
    return registry.dispatch("flash_attention", (q, k, v),
                             common={"causal": causal},
                             kernel_kwargs={"bq": bq, "bk": bk},
                             use_kernel=use_kernel, interpret=interpret)
