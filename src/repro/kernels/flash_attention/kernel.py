"""Blockwise causal GQA attention (FlashAttention-2 schedule) for TPU.

Grid: (B·Hq, S/bq, T/bk) — the kv axis is the minor (fastest) grid dim, so
on TPU the per-(head, q-block) online-softmax state lives in VMEM scratch
across kv steps (TPU grids execute sequentially on a core; scratch persists
between grid steps — the standard Pallas TPU accumulation idiom).

BlockSpecs keep one q block (bq×D), one kv block (bk×D each for K and V),
the f32 accumulator (bq×D) and the m/l statistics in VMEM.  With the
defaults (bq=bk=512, D=128, bf16 in / f32 acc) the working set is

    q 512·128·2 + k/v 2·512·128·2 + acc 512·128·4 + p 512·512·4  ≈ 1.7 MiB

well under the ~16 MiB VMEM budget, and every matmul is MXU-aligned
(contraction dims 128, tiles ≥ 128).  GQA is done by the index maps: the
kv block for q-head h comes from kv-head h // (Hq/Hkv) — no K/V duplication
in HBM, which is the point of GQA.

Causality skips fully-masked kv blocks via ``pl.when`` (upper-triangular
blocks cost nothing but the grid step) and applies the elementwise mask on
the diagonal blocks only.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, sm_scale,
            bq, bk, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk

    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # Skip kv blocks strictly above the diagonal.
        pl.when(k_start <= q_start + bq - 1)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512, bk: int = 512,
                    interpret: bool = False):
    """q: (B, Hq, S, D), k/v: (B, Hkv, T, D) → (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    sm_scale = 1.0 / math.sqrt(d)

    grid = (b * hq, s // bq, t // bk)

    def q_map(h, qi, ki):
        return (h, qi, 0)

    def kv_map(h, qi, ki):
        return (h // g, ki, 0)  # GQA: share the kv head across the group

    qs = q.reshape(b * hq, s, d)
    ks = k.reshape(b * hkv, t, d)
    vs = v.reshape(b * hkv, t, d)

    # flatten (b, h) jointly: q index h in [0, b*hq) maps to kv index
    # (h // hq) * hkv + (h % hq) // g
    def kv_map_joint(h, qi, ki):
        return ((h // hq) * hkv + (h % hq) // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, sm_scale=sm_scale, bq=bq, bk=bk, causal=causal
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map_joint),
            pl.BlockSpec((1, bk, d), kv_map_joint),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),   # l (running sum)
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return out.reshape(b, hq, s, d)
