"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Hq, S, D), k/v: (B, Hkv, T, D), GQA by head grouping.

    Returns (B, Hq, S, D) in q.dtype; softmax in fp32.
    """
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        m = qpos >= kpos
        if window > 0:
            m &= qpos - kpos < window
        scores = jnp.where(m[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)
