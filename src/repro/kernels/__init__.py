"""Pallas kernel packages behind one registry-backed facade.

Importing this package populates :mod:`repro.kernels.registry` with every
``(ref, kernel)`` pair — each subpackage's ``ops.py`` registers itself at
import — and re-exports the jit'd public wrappers.  Callers use the
wrappers (``decode_op`` etc.) for normal work and ``registry`` for
introspection (the parity test sweeps ``registry.names()``).

The ``registry`` import must stay FIRST: the ops modules import it back
out of this partially-initialized package.
"""
from repro.kernels import registry  # noqa: I001  (must precede ops imports)

from repro.kernels.batched_gather.ops import gather_op
from repro.kernels.decode_attention.ops import decode_op
from repro.kernels.flash_attention.ops import attention_op
from repro.kernels.paged_attention.ops import paged_decode_op
from repro.kernels.ssd_scan.ops import ssd_scan_op

__all__ = [
    "attention_op",
    "decode_op",
    "gather_op",
    "paged_decode_op",
    "registry",
    "ssd_scan_op",
]
