"""jit'd public wrapper for paged decode attention (registry-dispatched)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.paged_attention.kernel import paged_decode_attention_kernel
from repro.kernels.paged_attention.ref import paged_decode_ref

__all__ = ["paged_decode_op"]


def _sample(key) -> registry.OpSample:
    b, np_, ps, hkv, d = 2, 8, 16, 2, 64
    n_pages = b * np_ + 1  # page 0 reserved so padding slots stay valid
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, 4, d))
    k_pages = jax.random.normal(ks[1], (n_pages, ps, hkv, d))
    v_pages = jax.random.normal(ks[2], (n_pages, ps, hkv, d))
    # A shuffled (non-contiguous) physical page assignment per request.
    perm = jax.random.permutation(ks[3], jnp.arange(1, n_pages))
    tables = perm.reshape(b, np_).astype(jnp.int32)
    lengths = jax.random.randint(ks[4], (b,), 1, np_ * ps + 1)
    return registry.OpSample(args=(q, k_pages, v_pages, tables, lengths))


registry.register("paged_decode_attention", ref=paged_decode_ref,
                  kernel=paged_decode_attention_kernel, sample=_sample)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_decode_op(q, k_pages, v_pages, block_tables, lengths, *,
                    use_kernel=True, interpret=False):
    """Single-token GQA decode attention over a paged KV pool."""
    return registry.dispatch(
        "paged_decode_attention", (q, k_pages, v_pages, block_tables, lengths),
        use_kernel=use_kernel, interpret=interpret)
