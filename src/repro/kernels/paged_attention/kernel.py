"""Paged split-KV decode attention (vLLM-style PagedAttention on TPU).

Same flash-decoding structure as :mod:`repro.kernels.decode_attention` —
grid walks KV blocks sequentially per (batch, kv-head) with the GQA
group's online-softmax state in VMEM scratch — but the KV operand is a
global page pool ``(P, page_size, Hkv, D)`` instead of a dense per-request
cache.  The per-request block table arrives via scalar prefetch (SMEM)
alongside lengths, and the K/V BlockSpec index_map dereferences it:

    block j of request b  →  physical page  block_tables[b, j]

so the Pallas pipeline DMAs exactly the pages the request owns, in table
order, with no host-side gather.  Scalar-prefetched operands are available
to index_maps *before* the grid runs — that is what lets the DMA schedule
itself be data-dependent (the whole point of paging: fragmentation-free
allocation without ever materializing a dense copy).

Tail masking is identical to the dense kernel: block j covers key
positions [j*ps, (j+1)*ps) and ``pl.when(k_start < length)`` skips pages
past the request's length, so padded table slots (conventionally page 0)
are never read.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_decode_attention_kernel"]

NEG_INF = -1e30


def _kernel(lengths_ref, tables_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, sm_scale, page_size):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    npages = pl.num_programs(2)
    length = lengths_ref[b]

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = pi * page_size

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)      # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)   # (ps, D)
        v = v_ref[0, :, 0].astype(jnp.float32)   # (ps, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                              # (G, ps)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]                       # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(pi == npages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pages, v_pages, block_tables, lengths,
                                  *, interpret: bool = False):
    """q: (B, Hq, D); k/v_pages: (P, ps, Hkv, D); block_tables: (B, NP).

    ``lengths``: (B,) int32 valid tokens (attends [0, lengths)); padded
    table entries must be valid page ids (they are skipped, not read).
    Returns (B, Hq, D) in q.dtype.
    """
    b, hq, d = q.shape
    ps, hkv = k_pages.shape[1], k_pages.shape[2]
    npages = block_tables.shape[1]
    g = hq // hkv
    sm_scale = 1.0 / math.sqrt(d)

    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, npages)

    def kv_map(b_, h, pi, lens, tabs):
        return (tabs[b_, pi], 0, h, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, sm_scale=sm_scale, page_size=ps),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda b_, h, pi, lens, tabs: (b_, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, d), kv_map),
                pl.BlockSpec((1, ps, 1, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda b_, h, pi, lens, tabs: (b_, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
