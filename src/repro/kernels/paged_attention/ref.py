"""Pure-jnp oracle for paged single-token decode attention.

The paged layout stores KV in fixed-size pages shared across requests; a
per-request block table maps logical page slot ``j`` to physical page
``block_tables[b, j]``.  The oracle materializes the dense per-request
cache by gathering pages and defers to the dense decode oracle — so paged
and dense attention agree bit-for-bit by construction on the masked range.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_ref

__all__ = ["paged_decode_ref"]


def paged_decode_ref(q, k_pages, v_pages, block_tables, lengths):
    """q: (B, Hq, D); k/v_pages: (P, ps, Hkv, D); block_tables: (B, NP) int32.

    ``lengths``: (B,) valid tokens per request (attends slots
    [0, lengths)); table entries past ``ceil(length/ps)`` are padding and
    may hold any valid page id — masking keeps them unread.  Returns
    (B, Hq, D) in q.dtype.
    """
    b, np_ = block_tables.shape
    ps, hkv, d = k_pages.shape[1:]
    kd = k_pages[block_tables].reshape(b, np_ * ps, hkv, d)
    vd = v_pages[block_tables].reshape(b, np_ * ps, hkv, d)
    return decode_ref(q, kd, vd, lengths.astype(jnp.int32))
