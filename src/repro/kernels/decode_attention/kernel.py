"""Split-KV decode attention (flash-decoding [arXiv:2311.01282] on TPU).

Decode is memory-bound: one query token must stream the whole KV cache from
HBM.  The kernel's only job is to hit HBM bandwidth — so the grid splits the
cache length T into blocks and walks them sequentially per (batch, kv-head)
while the online-softmax state for ALL q-heads of that kv head (the GQA
group) sits in VMEM scratch.  Grid: (B, Hkv, T/bk); the group dim G = Hq/Hkv
rides inside the block so the q@k product is an (G×D)·(D×bk) MXU matmul
instead of G vector dots.

Per-lane variable lengths come in via scalar prefetch (SMEM) and mask the
tail block; fully-invalid blocks are skipped with ``pl.when`` so a
short-context lane in a long-cache batch does not pay for the whole cache
sweep (the straggler-friendly property the serving engine relies on).

VMEM working set (bk=512, D=128, G=8, bf16 kv): k/v 2·512·128·2 = 256 KiB,
acc G·D·4 = 4 KiB — trivially fits; bk can grow to 2048 for long caches.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_kernel"]

NEG_INF = -1e30


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, sm_scale, bk):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    length = lengths_ref[b]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ki * bk

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)      # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)   # (bk, D)
        v = v_ref[0, :, 0].astype(jnp.float32)   # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                              # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]                       # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, lengths, *, bk: int = 512,
                            interpret: bool = False):
    """q: (B, Hq, D); k/v: (B, T, Hkv, D); lengths: (B,) → (B, Hq, D)."""
    b, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bk = min(bk, t)
    assert t % bk == 0, (t, bk)
    sm_scale = 1.0 / math.sqrt(d)

    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, t // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, sm_scale=sm_scale, bk=bk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda b_, h, ki, lens: (b_, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, d), lambda b_, h, ki, lens: (b_, ki, h, 0)),
                pl.BlockSpec((1, bk, 1, d), lambda b_, h, ki, lens: (b_, ki, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, ki, lens: (b_, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(b, hq, d)
