"""Pure-jnp oracle for single-token GQA decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["decode_ref"]


def decode_ref(q, k, v, lengths):
    """q: (B, Hq, D) one token; k/v: (B, T, Hkv, D); lengths: (B,) int32.

    Attends slots [0, lengths); returns (B, Hq, D) in q.dtype.
    """
    b, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    valid = jnp.arange(t)[None, :] < lengths[:, None]  # (B, T)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
