"""jit'd public wrapper for the split-KV decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_ref

__all__ = ["decode_op"]


@partial(jax.jit, static_argnames=("bk", "use_kernel", "interpret"))
def decode_op(q, k, v, lengths, *, bk=512, use_kernel=True, interpret=False):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel and (on_tpu or interpret):
        return decode_attention_kernel(q, k, v, lengths, bk=bk,
                                       interpret=interpret or not on_tpu)
    return decode_ref(q, k, v, lengths)
