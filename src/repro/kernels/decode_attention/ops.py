"""jit'd public wrapper for the split-KV decode kernel (registry-dispatched)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import registry
from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_ref

__all__ = ["decode_op"]


def _sample(key) -> registry.OpSample:
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (2, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    lengths = jax.random.randint(ks[3], (2,), 1, 129)
    return registry.OpSample(args=(q, k, v, lengths), kernel={"bk": 32})


registry.register("decode_attention", ref=decode_ref,
                  kernel=decode_attention_kernel, sample=_sample)


@partial(jax.jit, static_argnames=("bk", "use_kernel", "interpret"))
def decode_op(q, k, v, lengths, *, bk=512, use_kernel=True, interpret=False):
    """Single-token GQA decode attention over a dense KV cache."""
    return registry.dispatch("decode_attention", (q, k, v, lengths),
                             kernel_kwargs={"bk": bk},
                             use_kernel=use_kernel, interpret=interpret)
