"""jit'd wrapper (registry-dispatched); also registers the kernel as the
set-oriented executor of the ``table_gather`` QuerySpec on TPU (the fission
pass then emits ONE kernel launch with pipelined DMAs for the whole
loop-context table)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import registry
from repro.kernels.batched_gather.kernel import batched_gather
from repro.kernels.batched_gather.ref import gather_ref

__all__ = ["gather_op"]


def _supports(table, ids, *, bn=256) -> bool:
    # The kernel tiles ids into bn-row blocks: a ragged tail block would
    # read past the array, so non-divisible id counts take the reference.
    return ids.shape[0] % min(bn, ids.shape[0]) == 0


def _sample(key) -> registry.OpSample:
    ks = jax.random.split(key, 2)
    table = jax.random.normal(ks[0], (128, 32))
    ids = jax.random.randint(ks[1], (64,), 0, 128)
    return registry.OpSample(args=(table, ids), kernel={"bn": 16}, tol=None)


registry.register("batched_gather", ref=gather_ref, kernel=batched_gather,
                  supports=_supports, sample=_sample)


@partial(jax.jit, static_argnames=("bn", "use_kernel", "interpret"))
def gather_op(table, ids, *, bn=256, use_kernel=True, interpret=False):
    """Batched row gather ``table[ids]`` (the loop-context table fetch)."""
    return registry.dispatch("batched_gather", (table, ids),
                             kernel_kwargs={"bn": bn},
                             use_kernel=use_kernel, interpret=interpret)
