"""jit'd wrapper; also registers the kernel as the set-oriented executor of
the ``table_gather`` QuerySpec on TPU (the fission pass then emits ONE
kernel launch with pipelined DMAs for the whole loop-context table)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.batched_gather.kernel import batched_gather
from repro.kernels.batched_gather.ref import gather_ref

__all__ = ["gather_op"]


@partial(jax.jit, static_argnames=("bn", "use_kernel", "interpret"))
def gather_op(table, ids, *, bn=256, use_kernel=True, interpret=False):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel and (on_tpu or interpret) and ids.shape[0] % min(bn, ids.shape[0]) == 0:
        return batched_gather(table, ids, bn=bn, interpret=interpret or not on_tpu)
    return gather_ref(table, ids)
