"""Pure-jnp oracle: row gather (the set-oriented table query)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gather_ref"]


def gather_ref(table, ids):
    """table: (V, D); ids: (N,) int32 → (N, D)."""
    return jnp.take(table, ids, axis=0)
