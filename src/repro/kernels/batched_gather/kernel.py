"""Batched row gather — the paper's set-oriented query execution as a TPU
kernel (one kernel, many in-flight DMA descriptors).

The fissioned loop hands us ALL row ids at once (the loop-context table).
The original loop's execution pattern — one scalar-driven gather per scan
step — costs a full HBM round trip per row with no pipelining.  Here the
ids arrive via scalar prefetch (SMEM), the table stays in HBM
(``memory_space=ANY``, never copied wholesale), and the kernel issues the
row DMAs HBM→VMEM back-to-back with ``pltpu.make_async_copy``, keeping
``PIPE`` descriptors in flight before the first wait — the amortization the
paper gets from its one set-oriented SQL query, restated in DMA terms.

Grid: (N / bn,); each step fills one (bn × D) VMEM output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["batched_gather"]

PIPE = 8  # DMA descriptors kept in flight

# Renamed across JAX versions (MemorySpace <-> TPUMemorySpace).
_MEMSPACE = getattr(pltpu, "TPUMemorySpace", None) or pltpu.MemorySpace


def _kernel(ids_ref, table_ref, o_ref, sems, *, bn):
    blk = pl.program_id(0)
    base = blk * bn

    def start(i):
        row = ids_ref[base + i]
        pltpu.make_async_copy(
            table_ref.at[row], o_ref.at[i], sems.at[i % PIPE]
        ).start()

    def wait(i):
        row = ids_ref[base + i]
        pltpu.make_async_copy(
            table_ref.at[row], o_ref.at[i], sems.at[i % PIPE]
        ).wait()

    # prologue: fill the pipe
    for i in range(min(PIPE, bn)):
        start(i)
    # steady state: wait one, start the next — PIPE copies always in flight
    def body(i, _):
        wait_i = i
        nxt = i + PIPE

        @pl.when(nxt < bn)
        def _():
            row = ids_ref[base + nxt]
            pltpu.make_async_copy(
                table_ref.at[row], o_ref.at[nxt], sems.at[nxt % PIPE]
            ).start()

        row = ids_ref[base + wait_i]
        pltpu.make_async_copy(
            table_ref.at[row], o_ref.at[wait_i], sems.at[wait_i % PIPE]
        ).wait()
        return 0

    jax.lax.fori_loop(0, bn, body, 0)


def batched_gather(table, ids, *, bn: int = 256, interpret: bool = False):
    """table: (V, D); ids: (N,) int32 → (N, D).  N must divide by bn."""
    v, d = table.shape
    n = ids.shape[0]
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)

    out = pl.pallas_call(
        functools.partial(_kernel, bn=bn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n // bn,),
            in_specs=[pl.BlockSpec(memory_space=_MEMSPACE.ANY)],
            out_specs=pl.BlockSpec((bn, d), lambda blk, ids: (blk, 0)),
            scratch_shapes=[pltpu.SemaphoreType.DMA((PIPE,))],
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
    return out
