"""Kernel registry: one dispatch policy for every Pallas kernel package.

Every ``kernels/*/ops.py`` used to hand-roll the same fallback dance::

    on_tpu = jax.default_backend() == "tpu"
    if use_kernel and (on_tpu or interpret):
        return kernel(..., interpret=interpret or not on_tpu)
    return ref(...)

Four copies of that predicate is four places for the TPU/CPU/interpret
semantics to drift.  This module centralizes it: each package registers a
:class:`KernelOp` — a uniform ``(ref, kernel)`` pair plus an optional
``supports`` eligibility gate (e.g. the gather kernel's block-divisibility
requirement) and a ``sample`` input factory the parity test harness sweeps
— and its ``ops.py`` wrapper becomes one :func:`dispatch` call.

Dispatch semantics (identical to the historical per-op wrappers):

* ``use_kernel=False`` → the jnp reference, always (models may call ops
  unconditionally).
* On TPU the Pallas kernel runs compiled; off-TPU it runs only when
  ``interpret=True`` is reachable (the kernel body executes on CPU exactly
  as it would on the TPU grid — the test path), and ``interpret`` is
  forced on so a CPU caller can never launch an uncompiled TPU kernel.
* An op whose ``supports`` predicate rejects the concrete operands falls
  back to the reference — a shape outside the kernel's envelope is a
  fallback, not an error.

Registration happens at import of each package's ``ops.py``; the package
facade (:mod:`repro.kernels`) imports them all, so ``import repro.kernels``
yields a fully-populated registry.  ``names()``/``get()`` drive the
registry-wide ref-vs-kernel parity sweep in ``tests/test_kernels.py`` —
registering an op automatically buys it the parity gate.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

import jax

__all__ = ["KernelOp", "OpSample", "register", "get", "names", "dispatch",
           "interpret_default"]


def interpret_default() -> bool:
    """Whether dispatch callers should default ``interpret=True``.

    Controlled by the ``REPRO_KERNEL_INTERPRET`` environment variable
    (``1``/``true``/``yes``): CI's CPU-only ``kernels`` job sets it so the
    serving engine's decode ticks execute the Pallas kernel bodies under
    interpret mode on every PR, instead of only on TPU.  Off by default —
    off-TPU callers then take the pure-jnp reference path.
    """
    return os.environ.get("REPRO_KERNEL_INTERPRET", "").strip().lower() in (
        "1", "true", "yes")


@dataclasses.dataclass(frozen=True)
class OpSample:
    """One representative invocation for the registry parity harness.

    ``args`` are positional operands; ``common`` keywords go to BOTH the
    kernel and the reference (semantic switches like ``causal``);
    ``kernel`` keywords go to the kernel only (tuning knobs like block
    sizes).  ``tol=None`` demands bit-exact agreement (integer gathers);
    otherwise ``(rtol, atol)`` for float comparison.
    """

    args: tuple
    common: dict = dataclasses.field(default_factory=dict)
    kernel: dict = dataclasses.field(default_factory=dict)
    tol: Optional[tuple[float, float]] = (2e-5, 2e-5)


@dataclasses.dataclass(frozen=True)
class KernelOp:
    """A registered ``(ref, kernel)`` pair with uniform dispatch metadata.

    ``kernel`` must accept ``interpret=``; ``ref`` is a pure-jnp oracle
    with the same positional signature (plus any ``common`` keywords).
    ``supports(*args, **kwargs)`` gates kernel eligibility per call —
    ``None`` means the kernel handles every shape the op accepts.
    ``sample(key)`` builds an :class:`OpSample` for the parity sweep.
    """

    name: str
    ref: Callable
    kernel: Callable
    supports: Optional[Callable[..., bool]] = None
    sample: Optional[Callable[[jax.Array], OpSample]] = None


_OPS: dict[str, KernelOp] = {}


def register(name: str, *, ref: Callable, kernel: Callable,
             supports: Optional[Callable[..., bool]] = None,
             sample: Optional[Callable[[jax.Array], OpSample]] = None
             ) -> KernelOp:
    """Register one kernel package's ``(ref, kernel)`` pair under ``name``.

    Re-registration with identical callables is a no-op (module reloads);
    conflicting re-registration raises — two packages must not claim one
    name.  Returns the registered :class:`KernelOp`.
    """
    op = KernelOp(name, ref, kernel, supports, sample)
    prev = _OPS.get(name)
    if prev is not None and (prev.ref, prev.kernel) != (ref, kernel):
        raise ValueError(f"kernel op {name!r} already registered with "
                         "different callables")
    _OPS[name] = op
    return op


def get(name: str) -> KernelOp:
    """Look up a registered op (KeyError with the known names on a miss)."""
    try:
        return _OPS[name]
    except KeyError:
        raise KeyError(f"unknown kernel op {name!r}; registered: "
                       f"{sorted(_OPS)}") from None


def names() -> list[str]:
    """Sorted names of every registered op (parity-harness parametrize)."""
    return sorted(_OPS)


def dispatch(name: str, args: tuple, *, common: Optional[dict] = None,
             kernel_kwargs: Optional[dict] = None, use_kernel: bool = True,
             interpret: bool = False):
    """Run ``name`` on ``args`` through the shared kernel/ref policy.

    ``common`` keywords reach both implementations; ``kernel_kwargs``
    reach the kernel only.  See the module docstring for the exact
    fallback semantics.
    """
    op = get(name)
    ck = common or {}
    kk = kernel_kwargs or {}
    on_tpu = jax.default_backend() == "tpu"
    eligible = (op.supports is None or op.supports(*args, **ck, **kk))
    if use_kernel and (on_tpu or interpret) and eligible:
        return op.kernel(*args, **ck, **kk, interpret=interpret or not on_tpu)
    return op.ref(*args, **ck)
