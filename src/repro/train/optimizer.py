"""AdamW with optional block-quantized 8-bit moments.

8-bit moments (block-wise absmax quantization, block=64 along the flattened
last axis — the 8-bit-Adam recipe [arXiv:2110.02861] adapted to JAX) cut
optimizer-state HBM from 8 bytes/param (fp32 m+v) to ~2.1 bytes/param,
which is what lets kimi-k2-1t (1.03e12 params) train on 512 chips
(napkin math in EXPERIMENTS.md §Dry-run).  States are stored per-tensor as
``{"q": int8[...], "scale": f32[..., n_blocks]}``; m uses signed absmax, v
uses unsigned (v ≥ 0).

Also here: global-norm clipping and the cosine/linear LR schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
    "clip_by_global_norm",
]

_BLOCK = 64


# ---------------------------------------------------------------------------
# block-wise int8 quantization
# ---------------------------------------------------------------------------


def _pad_to_block(x):
    """Block along the LAST axis, keeping the leading structure intact so
    the quantized state inherits the parameter's sharding (a flat layout
    forced whole-fleet reshards of TB-scale tensors in the kimi dry-run —
    EXPERIMENTS.md §Perf C1)."""
    last = x.shape[-1]
    pad = (-last) % _BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(*x.shape[:-1], -1, _BLOCK), pad


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """int8 blocks + fp32 scales; shape/pad/domain are STATIC aux data so
    the object flows through jit/scan/pjit like any array pair."""

    def __init__(self, q, scale, *, shape, pad, sqrt_domain):
        self.q = q
        self.scale = scale
        self.shape = tuple(shape)
        self.pad = pad
        self.sqrt_domain = sqrt_domain

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, self.pad, self.sqrt_domain)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        shape, pad, sqrt_domain = aux
        return cls(q, scale, shape=shape, pad=pad, sqrt_domain=sqrt_domain)


def _quantize(x, signed: bool = True):
    """Blockwise absmax int8.  Unsigned tensors (the v moment, v ≥ 0) are
    stored in the SQRT domain: v spans many orders of magnitude within a
    block, and linear quantization collapses small entries to exactly 0 —
    then ``m/(sqrt(v)+eps)`` explodes.  sqrt halves the dynamic range in
    exponent terms (the same reason bitsandbytes uses a non-linear map)."""
    if not signed:
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    blocks, pad = _pad_to_block(x)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q, scale[..., 0], shape=x.shape, pad=pad,
                           sqrt_domain=not signed)


def _dequantize(s: "QuantizedTensor"):
    x = s.q.astype(jnp.float32) * s.scale[..., None]
    x = x.reshape(*s.shape[:-1], -1)  # merge (nb, BLOCK) → padded last axis
    out = x[..., : s.shape[-1]]
    if s.sqrt_domain:
        out = out * out
    return out


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moments_dtype: str = "float32"  # float32 | int8
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


def adamw_init(cfg: AdamWConfig, params):
    def one(p):
        if cfg.moments_dtype == "int8":
            z = jnp.zeros(p.shape, jnp.float32)
            return {"m": _quantize(z), "v": _quantize(z, signed=False)}
        return {
            "m": jnp.zeros(p.shape, jnp.float32),
            "v": jnp.zeros(p.shape, jnp.float32),
        }

    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(one, params),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), tree), g


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """→ (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def one(g, mu, p):
        gf = g.astype(jnp.float32)
        if cfg.moments_dtype == "int8":
            m = _dequantize(mu["m"])
            v = _dequantize(mu["v"])
        else:
            m, v = mu["m"], mu["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m / bc1
        vhat = v / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + cfg.weight_decay * pf)
        if cfg.moments_dtype == "int8":
            new_mu = {"m": _quantize(m), "v": _quantize(v, signed=False)}
        else:
            new_mu = {"m": m, "v": v}
        return pf.astype(p.dtype), new_mu

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_p = tdef.flatten_up_to(params)
    new_p, new_mu = [], []
    for g, mu, p in zip(flat_g, flat_mu, flat_p):
        np_, nmu = one(g, mu, p)
        new_p.append(np_)
        new_mu.append(nmu)
    new_params = jax.tree_util.tree_unflatten(tdef, new_p)
    new_state = {"step": step, "mu": jax.tree_util.tree_unflatten(tdef, new_mu)}
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, new_state, metrics


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, float(warmup))
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, float(total - warmup)), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)

    return fn
