"""Train-step builder: loss, microbatched gradient accumulation, gradient
compression, and pjit wiring against the production mesh.

The microbatch loop is a ``lax.scan`` — and when ``cfg.query_embedding`` is
on, the per-microbatch embedding gathers inside it are *queries* in the
paper's sense: :func:`repro.core.fission.fission_scan` pulls them out into
one batched gather (Rule A on device code).  ``make_train_step`` exposes
``fission=True/False`` so benchmarks can compare the paper-faithful
per-iteration form against the fissioned one.

Gradient compression (distributed-optimization trick): optional int8
quantization with error feedback applied to the gradients before the
optimizer — with DP meshes this shrinks the all-reduce payload 4× (the
quantized tensor is what crosses the ICI); the residual is carried in the
step state so the compression is unbiased over time (EF-SGD lineage,
1-bit Adam [arXiv:2102.02888]).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.fission import scan_with_queries
from repro.distributed.sharding import (
    param_shardings,
)
from repro.models.registry import Arch
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["cross_entropy", "make_loss_fn", "make_train_step", "TrainStepConfig"]


def cross_entropy(logits, labels):
    """Mean token CE in fp32.  logits (B,S,V), labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(arch: Arch):
    def loss_fn(params, batch):
        logits, aux = arch.forward(params, batch)
        labels = arch.labels_of(batch)
        # next-token prediction: shift by one
        ce = cross_entropy(logits[:, :-1], labels[:, 1:])
        return ce + aux, {"ce": ce, "aux": aux}

    return loss_fn


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    grad_compression: str = "none"  # none | int8_ef
    fission: bool = True  # apply device Rule A to the microbatch scan
    donate: bool = True


def _quant_int8_ef(g, residual):
    """int8 quantize with error feedback.  Returns (deq, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), gf - deq


def make_train_step(
    arch: Arch,
    opt_cfg: AdamWConfig,
    ts_cfg: TrainStepConfig = TrainStepConfig(),
    mesh=None,
):
    """Returns (init_state_fn, train_step_fn[, shardings])."""
    loss_fn = make_loss_fn(arch)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def init_state(params):
        state = {"opt": adamw_init(opt_cfg, params)}
        if ts_cfg.grad_compression == "int8_ef":
            state["ef"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return state

    def compute_grads(params, batch):
        if ts_cfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        n = ts_cfg.microbatches

        def split(x):
            b = x.shape[0]
            # leading batch axis except enc-dec positions (3,B,S) style
            if x.ndim >= 1 and b % n == 0:
                return x.reshape((n, b // n) + x.shape[1:])
            return jnp.broadcast_to(x, (n,) + x.shape)

        mbatch = jax.tree_util.tree_map(split, batch)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / n, acc, grads
            )
            return (acc, loss_acc + loss / n), metrics

        (grads, loss), metricss = scan_with_queries(
            body, (zero_g, jnp.float32(0.0)), mbatch, fission=ts_cfg.fission
        )
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metricss)
        return loss, metrics, grads

    def train_step(params, state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if ts_cfg.grad_compression == "int8_ef":
            flat_g, tdef = jax.tree_util.tree_flatten(grads)
            flat_e = tdef.flatten_up_to(state["ef"])
            out = [_quant_int8_ef(g, e) for g, e in zip(flat_g, flat_e)]
            grads = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
            new_ef = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state["opt"], params
        )
        new_state = {"opt": new_opt}
        if ts_cfg.grad_compression == "int8_ef":
            new_state["ef"] = new_ef
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_state, metrics

    if mesh is None:
        return init_state, jax.jit(train_step, donate_argnums=(0, 1) if ts_cfg.donate else ())

    # pjit against the mesh: params/opt-state sharded by the rule table,
    # batch over dp, metrics replicated.
    def make_shardings(params_sds, state_sds, batch_sds):
        p_sh = param_shardings(mesh, params_sds)
        s_sh = jax.tree_util.tree_map(
            lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            state_sds,
        )
        # opt moments follow the param sharding where shapes match
        return p_sh, s_sh

    return init_state, train_step  # caller jits with explicit shardings (dryrun)
