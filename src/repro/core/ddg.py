"""Data-dependence analysis over jaxprs (§3.1 of the paper, on device code).

A jaxpr is SSA and pure, so *within one loop iteration* only flow
dependencies exist (anti/output dependencies are artifacts of mutable
storage, which jaxprs do not have — the paper's Table-t renaming is, in
compiler terms, exactly the conversion to SSA that JAX already performs).
The loop-carried structure survives, though: a ``lax.scan`` body maps carry
*outputs* of iteration *t* to carry *inputs* of iteration *t+1*.  Those are
the ``LFD`` edges of the paper, and they are what Rule A's precondition (a)
is about.

:class:`ScanBodyDDG` gives the fission pass (and tests/benchmarks) the
queries it needs:

* ``downstream(eqn_idx)`` — all equations transitively flow-dependent on an
  equation (the paper's ``ss2`` side of the split);
* carry classification — which carry positions are produced on the
  producer vs consumer side of a split, iterated to a fixed point through
  pass-through outputs;
* precondition check — does a loop-carried flow dependence cross the split
  (consumer-produced carry read by the producer side)?
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

from jax.extend import core as jex_core

__all__ = ["ScanBodyDDG", "FissionPreconditionError"]


class FissionPreconditionError(ValueError):
    """Rule A precondition violated on the device loop (see message)."""


def _is_literal(v) -> bool:
    return isinstance(v, jex_core.Literal) or type(v).__name__ == "Literal"


@dataclasses.dataclass
class ScanBodyDDG:
    """DDG of a scan body jaxpr.

    ``jaxpr`` has invars ``[*carry_in, *x]`` and outvars ``[*carry_out, *y]``
    with ``len(carry_in) == n_carry``.
    """

    jaxpr: Any  # jex_core.Jaxpr
    n_carry: int

    def __post_init__(self):
        self.eqns = list(self.jaxpr.eqns)
        self.carry_in = list(self.jaxpr.invars[: self.n_carry])
        self.x_in = list(self.jaxpr.invars[self.n_carry :])
        self.carry_out = list(self.jaxpr.outvars[: self.n_carry])
        self.y_out = list(self.jaxpr.outvars[self.n_carry :])
        self.consts = list(self.jaxpr.constvars)

        # var -> producing eqn index (SSA def site); inputs/consts absent.
        self.def_site: dict[Any, int] = {}
        for i, eqn in enumerate(self.eqns):
            for ov in eqn.outvars:
                self.def_site[ov] = i

        # eqn -> eqn flow edges (def → use).
        self.succ: dict[int, set[int]] = {i: set() for i in range(len(self.eqns))}
        for i, eqn in enumerate(self.eqns):
            for iv in eqn.invars:
                if _is_literal(iv):
                    continue
                d = self.def_site.get(iv)
                if d is not None and d != i:
                    self.succ[d].add(i)

    # ------------------------------------------------------------------ sets
    def upstream_of_vars(self, vars: Iterable[Any]) -> set[int]:
        """Equations transitively needed to compute ``vars`` (def-site
        closure) — the statements that must stay on the producer side of a
        split because the query's inputs flow through them."""
        seen: set[int] = set()
        stack = [self.def_site[v] for v in vars if v in self.def_site]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            for iv in self.eqns[cur].invars:
                if _is_literal(iv):
                    continue
                d = self.def_site.get(iv)
                if d is not None:
                    stack.append(d)
        return seen

    def downstream(self, idx: int) -> set[int]:
        """Equations transitively flow-dependent on equation ``idx``
        (including ``idx`` itself) — the consumer side of a split at idx."""
        seen = {idx}
        stack = [idx]
        while stack:
            cur = stack.pop()
            for nxt in self.succ[cur]:
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return seen

    def eqn_reads(self, idx: int) -> set[Any]:
        return {v for v in self.eqns[idx].invars if not _is_literal(v)}

    def side_reads(self, eqn_idxs: Iterable[int]) -> set[Any]:
        out: set[Any] = set()
        for i in eqn_idxs:
            out |= self.eqn_reads(i)
        return out

    # ----------------------------------------------------- carry classification
    def classify_carry(self, consumer_eqns: set[int]) -> tuple[set[int], set[int]]:
        """Split carry positions into (producer_positions, consumer_positions).

        A position is *consumer* if its carry-out value is produced by a
        consumer equation, or (fixed point) if its carry-out is a
        pass-through of the carry-in of a consumer position (the recurrence
        then lives wholly on the consumer side).
        """
        n = self.n_carry
        consumer_pos: set[int] = set()
        for j in range(n):
            ov = self.carry_out[j]
            if _is_literal(ov):
                continue
            d = self.def_site.get(ov)
            if d is not None and d in consumer_eqns:
                consumer_pos.add(j)
        changed = True
        while changed:
            changed = False
            consumer_carry_in = {self.carry_in[j] for j in consumer_pos}
            for j in range(n):
                if j in consumer_pos:
                    continue
                ov = self.carry_out[j]
                if not _is_literal(ov) and ov in consumer_carry_in:
                    consumer_pos.add(j)
                    changed = True
        producer_pos = set(range(n)) - consumer_pos
        return producer_pos, consumer_pos

    # ----------------------------------------------------------- precondition
    def check_split(
        self, query_idx: int, consumer_eqns: set[int], consumer_pos: set[int]
    ) -> None:
        """Rule A precondition (a) on the device loop: no loop-carried flow
        dependence may cross the split.  Concretely: a carry position whose
        *output* is computed by the consumer side must not have its *input*
        read by the producer side (including the query's own arguments) —
        that would make iteration t+1's submission depend on iteration t's
        consumption.

        Precondition (b) (external anti/output deps) is discharged
        structurally: jaxprs are pure, so the only external state is the
        ordered effect system; we reject bodies with effectful equations on
        the producer/consumer boundary elsewhere (see fission._check_effects).
        """
        producer_eqns = set(range(len(self.eqns))) - consumer_eqns
        producer_reads = self.side_reads(producer_eqns | {query_idx})
        for j in sorted(consumer_pos):
            civ = self.carry_in[j]
            if civ in producer_reads:
                raise FissionPreconditionError(
                    f"loop-carried flow dependence crosses the split: carry "
                    f"position {j} is produced by the consumer side but its "
                    f"previous-iteration value is read by the producer side "
                    f"(query inputs depend on query results across "
                    f"iterations). Rule A is inapplicable — the query lies "
                    f"on a true-dependence cycle (paper §4.1)."
                )
        # A query argument produced by the consumer side is the
        # intra-iteration version of the same cycle.
        for v in self.eqn_reads(query_idx):
            d = self.def_site.get(v)
            if d is not None and d in consumer_eqns and d != query_idx:
                raise FissionPreconditionError(
                    "query argument depends on the query's own result within "
                    "an iteration — true-dependence cycle, Rule A inapplicable."
                )
