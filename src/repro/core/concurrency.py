"""Lock-sharding primitives for the runtime's hot path.

The paper's premise is that asynchronous submission wins only when
submission itself is cheap.  A runtime that funnels every ``submit`` /
``fetch`` / worker pick through ONE ``threading.Lock`` re-serializes the
"asynchronous" path at high producer counts — the Fig. 5/8 plateau, but
caused by the client library instead of the server.  These primitives let
the :class:`~repro.core.runtime.AsyncQueryRuntime` shard its
synchronization to match its already-sharded data:

* :class:`ShardedCounter` — an add-mostly counter striped across N locks
  keyed by the calling thread, so 32 producers bumping ``stats.submitted``
  do not convoy on one lock.  Reads sum the stripes (racy-consistent,
  exact once writers quiesce) and the object compares/converts like a
  number so existing ``stats.x == n`` call sites keep working.
* :class:`ReadyLanes` — a duplicate-suppressing MPMC queue of lane keys
  that have pending work.  Workers block here instead of polling a global
  condition variable and scanning idle lanes; a push wakes at most one
  parked worker.  An optional ``select`` callable (the policy's
  weighted-fair ``lane_min``) picks which ready lane a pop returns.
* :class:`QuotaGate` — a counted admission gate with its own condition
  variable.  Submissions blocked on a tenant/lane/global bound sleep on
  THAT bound's CV and are woken by the release that frees a slot — no
  fixed-interval polling anywhere in the quota path.

Lock-ordering rules for users of this module are documented in
ROADMAP.md ("Locking model").
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Iterable, Optional

__all__ = ["ShardedCounter", "ReadyLanes", "QuotaGate"]


def _as_number(x):
    return x.value if isinstance(x, ShardedCounter) else x


class ShardedCounter:
    """Per-thread-celled add-mostly counter.

    Each writer thread owns a private cell (created on first ``add``), so
    ``cell[0] += n`` is a single-writer update — no lock on the hot path at
    all; the GIL makes the in-place add safe and the only lock is taken
    once per (thread, counter) pair to register the cell.  ``value`` sums
    the cells without locking: each element read is atomic under the GIL,
    so the sum is racy-consistent while writers are active and exact once
    they stop.

    Cell count is capped (``MAX_CELLS``): once that many writer threads
    have registered, later threads fall back to one shared lock-guarded
    overflow cell, so thread-churn deployments (thread-per-request
    producers) bound both memory and the O(cells) cost of ``value`` reads
    instead of leaking a cell per dead thread.

    Instances behave like numbers for comparison/arithmetic so stats
    fields can switch from plain ints without breaking callers.
    """

    __slots__ = ("_local", "_cells", "_lock", "_overflow")

    MAX_CELLS = 64

    def __init__(self):
        self._local = threading.local()
        self._cells: list = []
        self._lock = threading.Lock()
        self._overflow = [0]

    def add(self, n=1) -> None:
        try:
            cell = self._local.cell
        except AttributeError:
            with self._lock:
                if len(self._cells) < self.MAX_CELLS:
                    cell = [0]
                    self._cells.append(cell)
                else:
                    cell = None  # cell budget spent: use the shared cell
            self._local.cell = cell
        if cell is not None:
            cell[0] += n  # single writer per cell: GIL-atomic, no lock
        else:
            with self._lock:
                self._overflow[0] += n

    @property
    def value(self):
        return sum(c[0] for c in self._cells) + self._overflow[0]

    # ---- number-like views (stats consumers treat counters as numbers)
    def __int__(self):
        return int(self.value)

    def __float__(self):
        return float(self.value)

    def __index__(self):
        return int(self.value)

    def __bool__(self):
        return self.value != 0

    def __eq__(self, other):
        return self.value == _as_number(other)

    # Defining __eq__ sets __hash__ to None (unhashable); counters compare
    # by value but must still be usable as dict keys / set members (e.g. a
    # stats registry keyed by counter object), so restore identity hashing.
    # Value-based hashing would be wrong: the value mutates under add().
    __hash__ = object.__hash__

    def __ne__(self, other):
        return self.value != _as_number(other)

    def __lt__(self, other):
        return self.value < _as_number(other)

    def __le__(self, other):
        return self.value <= _as_number(other)

    def __gt__(self, other):
        return self.value > _as_number(other)

    def __ge__(self, other):
        return self.value >= _as_number(other)

    def __add__(self, other):
        return self.value + _as_number(other)

    __radd__ = __add__

    def __sub__(self, other):
        return self.value - _as_number(other)

    def __rsub__(self, other):
        return _as_number(other) - self.value

    def __mul__(self, other):
        return self.value * _as_number(other)

    __rmul__ = __mul__

    def __truediv__(self, other):
        return self.value / _as_number(other)

    def __rtruediv__(self, other):
        return _as_number(other) / self.value

    def __repr__(self):
        return f"ShardedCounter({self.value})"


class ReadyLanes:
    """Duplicate-suppressing queue of lane keys with pending work.

    ``push`` is idempotent while the key is queued (membership set), so a
    burst of submissions to one lane costs one queue slot and at most one
    worker wakeup.  ``pop`` blocks until a key is available or the queue
    is closed; with ``select`` (e.g. the policy's weighted-fair
    ``lane_min``) the lowest-virtual-time ready lane is returned instead
    of FIFO.  FIFO pop + re-push at the tail is round-robin over busy
    lanes, matching the old global-lock scan order without ever visiting
    an idle lane.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: deque = deque()
        self._member: set = set()
        self._waiters = 0
        self._closed = False

    def push(self, key) -> None:
        with self._cv:
            if key not in self._member:
                self._member.add(key)
                self._queue.append(key)
                if self._waiters:
                    # Only wake a parked worker; busy workers re-check the
                    # queue before they ever wait, so skipping the notify
                    # when nobody is parked loses no wakeup and spares the
                    # futex traffic of notifying into a busy pool.
                    self._cv.notify()

    def push_all(self, keys: Iterable) -> None:
        with self._cv:
            added = 0
            for key in keys:
                if key not in self._member:
                    self._member.add(key)
                    self._queue.append(key)
                    added += 1
            if added and self._waiters:
                self._cv.notify(added)

    def peek(self, select: Optional[Callable[[list], Any]] = None):
        """The key :meth:`pop` would return next, WITHOUT removing it (or
        ``None`` when no lane is ready).  Never blocks.

        This is the speculation primitive: the serving scheduler peeks the
        next ready lane while a decode tick runs and dispatches its prefill
        early, but the lane stays queued — so if the speculative take turns
        out to be 0 (strategy says wait, no KV capacity) nothing has to be
        re-pushed and the lane keeps its FIFO position.  A later ``pop``
        with the same ``select`` returns the same key as long as no push /
        pop / weight change intervened (single-threaded schedulers get this
        for free; concurrent users must treat the peek as a hint).
        """
        with self._lock:
            if not self._queue:
                return None
            if select is None or len(self._queue) == 1:
                return self._queue[0]
            return select(list(self._queue))

    def pop(self, select: Optional[Callable[[list], Any]] = None,
            block: bool = True):
        """Next ready lane key, or ``None`` when closed (or empty with
        ``block=False``).  ``select`` picks ONE key from the current ready
        keys (e.g. the policy's O(n) weighted-fair ``lane_min``) — a
        single selection, not a sort, since only the winner is popped."""
        with self._cv:
            while True:
                if self._queue:
                    if select is None or len(self._queue) == 1:
                        key = self._queue.popleft()
                    else:
                        key = select(list(self._queue))
                        self._queue.remove(key)
                    self._member.discard(key)
                    return key
                if self._closed or not block:
                    return None
                self._waiters += 1
                try:
                    self._cv.wait()
                finally:
                    self._waiters -= 1

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def __len__(self):
        with self._lock:
            return len(self._queue)

    def __contains__(self, key):
        with self._lock:
            return key in self._member


class QuotaGate:
    """Counted admission slots behind one condition variable.

    One gate per bound (a tenant, a lane, or the global ``max_pending``):
    a submission blocked at ITS bound sleeps on that bound's CV and is
    woken by :meth:`release` when a slot frees — never by a timer.  The
    100 ms busy-poll this replaces woke every blocked producer every tick
    whether or not anything changed.
    """

    __slots__ = ("_cv", "count", "_waiters", "dead")

    def __init__(self):
        self._cv = threading.Condition()
        self.count = 0
        self._waiters = 0
        self.dead = False  # retired out of its registry (see try_gc)

    def try_acquire(self, limit: Optional[int]) -> bool:
        """Take one slot iff under ``limit`` (``None`` = unbounded)."""
        with self._cv:
            if limit is not None and self.count >= limit:
                return False
            self.count += 1
            return True

    def release(self, n: int = 1) -> None:
        with self._cv:
            self.count -= n
            if self._waiters:
                # One freed slot admits one waiter — and a woken waiter
                # that gives the slot back (multi-gate retry) re-notifies
                # on ITS release, so the chain never under-wakes.  Waking
                # everyone per slot would be the thundering herd this
                # module exists to remove.
                self._cv.notify(n)

    def wait_below(self, limit: int, should_stop: Callable[[], bool]) -> None:
        """Sleep until ``count < limit`` might hold (woken by release), the
        gate is retired, or ``should_stop()``.  The caller re-runs its
        acquire protocol after waking — this is a signal, not a
        reservation (and a retired gate's releases happen on its registry
        successor, so waiting on one would strand the waiter)."""
        with self._cv:
            self._waiters += 1
            try:
                while (self.count >= limit and not self.dead
                       and not should_stop()):
                    self._cv.wait()
            finally:
                self._waiters -= 1

    def try_gc(self) -> bool:
        """Retire the gate iff it is idle (no slots held, no waiters): the
        owner may then drop it from its registry.  ``dead`` is set in the
        same critical section, so a thread that reaches ``wait_below``
        with a stale reference returns immediately instead of sleeping on
        a CV nothing will ever signal; a stale ``try_acquire`` is caught
        by the owner re-validating the registry entry after acquiring."""
        with self._cv:
            if self.count == 0 and self._waiters == 0:
                self.dead = True
                return True
            return False

    def notify_all(self) -> None:
        """Wake every waiter (shutdown path)."""
        with self._cv:
            self._cv.notify_all()

    def __repr__(self):
        return f"QuotaGate(count={self.count})"
