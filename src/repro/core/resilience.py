"""Failure-domain primitives: retry, deadlines, circuit breakers.

The paper's transformation is only *correct* if the asynchronous program
preserves the synchronous program's exception semantics — a query that
would have raised at its call site must raise at the corresponding fetch
point, and nowhere else.  This module supplies the policy objects the
runtime and the serving scheduler use to keep that guarantee under real
failures, and to degrade gracefully instead of wedging:

* :class:`RetryPolicy` — bounded retries with exponential backoff and
  *deterministic* jitter (hash-derived, so chaos runs replay exactly),
  plus a per-lane :class:`RetryBudget` token bucket that prevents retry
  storms: retries spend tokens, successes earn them back.
* :class:`CircuitBreaker` — the classic closed → open → half-open state
  machine, per lane.  A tripped lane is *shed* to a direct synchronous
  execution path (graceful degradation: no batching, no retries) while
  half-open probes test whether the lane has recovered.
* :class:`Resilience` — the one config object bundling the knobs
  (``retry_budget``, ``deadline``, ``breaker_threshold``, …; see
  ``docs/TUNING.md``); :class:`FailureDomain` instantiates per-lane
  breaker/budget state from it.
* Typed exceptions: :class:`DeadlineExceeded` (raised at the fetch
  point when a request's deadline lapses), :class:`ServiceCardinalityError`
  (a service returned the wrong number of batch results — a protocol
  violation delivered to every waiter instead of stranding them),
  :class:`LaneError` (a device-step failure attributable to one serving
  lane; the scheduler quarantines the lane and salvages its KV), and
  :class:`LaneFailedError` (a lane whose every submission fails, surfaced
  by ``run_until_drained`` with the template and last exception).

Exceptions deriving :class:`NonRetryableError` are never retried — the
failure is deterministic, so a retry only burns budget.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Optional

__all__ = [
    "CircuitBreaker",
    "DeadlineExceeded",
    "FailureDomain",
    "LaneError",
    "LaneFailedError",
    "NonRetryableError",
    "Resilience",
    "RetryBudget",
    "RetryPolicy",
    "ServiceCardinalityError",
]


def hash_unit(*parts) -> float:
    """Deterministic hash of ``parts`` mapped to ``[0, 1)``.

    The jitter/chaos randomness source: derived from the *identity* of
    the decision (seed, key, attempt index), never from global RNG state
    or wall clock, so a seeded run replays bit-identically regardless of
    thread interleaving.
    """
    h = hashlib.blake2b(repr(parts).encode(), digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0**64


class NonRetryableError(Exception):
    """Marker base: failures that are deterministic (retry cannot help)."""


class DeadlineExceeded(NonRetryableError, RuntimeError):
    """A request's deadline lapsed before its result arrived.

    Raised *at the fetch point* (the paper's exception-semantics
    contract): the submitting code sees it exactly where the synchronous
    program would have blocked."""

    def __init__(self, query_name: str, deadline: float, waited: float):
        super().__init__(
            f"deadline of {deadline:.3f}s exceeded fetching {query_name!r} "
            f"(waited {waited:.3f}s)")
        self.query_name = query_name
        self.deadline = deadline
        self.waited = waited


class ServiceCardinalityError(NonRetryableError, RuntimeError):
    """``execute_batch`` returned the wrong number of results.

    A mid-fanout ``IndexError`` from a short result list used to kill the
    worker thread and strand every fetcher; validating the cardinality up
    front turns the protocol violation into an error delivered to each
    waiter."""

    def __init__(self, query_name: str, expected: int, got: int):
        super().__init__(
            f"service returned {got} results for a {expected}-param batch "
            f"of {query_name!r}")
        self.query_name = query_name
        self.expected = expected
        self.got = got


class LaneError(RuntimeError):
    """A device-step failure attributable to ONE serving lane.

    Raised by engines (or :class:`~repro.core.faults.ChaosEngine`) when a
    decode step fails in a way that identifies the offending lane; the
    scheduler's recovery path quarantines exactly that lane, salvages its
    KV through the spill machinery, and re-queues its request — the rest
    of the batch keeps decoding."""

    def __init__(self, lane: int, template: Optional[str] = None,
                 reason: str = "device step failed"):
        super().__init__(f"lane {lane} ({template!r}): {reason}")
        self.lane = lane
        self.template = template


class LaneFailedError(RuntimeError):
    """A serving lane whose every submission is failing.

    The named replacement for the generic stuck-lane diagnosis: carries
    the template and the last underlying exception so the operator sees
    *which* traffic class is down and *why*."""

    def __init__(self, template: str, failures: int,
                 last_error: Optional[BaseException]):
        super().__init__(
            f"lane {template!r} failed {failures} consecutive submissions; "
            f"last error: {last_error!r}")
        self.template = template
        self.failures = failures
        self.last_error = last_error


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff and deterministic jitter.

    ``max_attempts`` counts the first try; ``backoff_for(attempt)`` grows
    ``backoff_base * backoff_multiplier**(attempt-1)`` capped at
    ``backoff_max``, jittered DOWN by up to ``jitter`` (a fraction of the
    interval) via :func:`hash_unit` — deterministic per (key, attempt),
    so seeded chaos runs replay while concurrent retries still decorrelate.
    ``retry_budget``/``budget_earn`` parameterize each lane's
    :class:`RetryBudget` token bucket.
    """

    max_attempts: int = 3
    backoff_base: float = 0.0005
    backoff_multiplier: float = 2.0
    backoff_max: float = 0.05
    jitter: float = 0.5
    retry_budget: float = 64.0
    budget_earn: float = 0.25

    def is_retryable(self, exc: BaseException) -> bool:
        """Whether a retry could plausibly succeed (deterministic
        failures — :class:`NonRetryableError` — never retry)."""
        return not isinstance(exc, NonRetryableError)

    def backoff_for(self, attempt: int, key=None) -> float:
        """Sleep before retry ``attempt`` (1-based), jittered."""
        base = min(self.backoff_max,
                   self.backoff_base * self.backoff_multiplier ** (attempt - 1))
        if self.jitter <= 0.0:
            return base
        return base * (1.0 - self.jitter * hash_unit("backoff", key, attempt))

    def sleep_backoff(self, attempt: int, key=None) -> float:
        """Sleep the backoff for retry ``attempt`` and return it.  Lives
        here — not in the runtime — because backing off IS retry policy:
        the runtime's own waits stay purely signal-driven (no timed sleeps
        in the quota/fetch paths), and this is the one deliberate timed
        pause in the system."""
        delay = self.backoff_for(attempt, key)
        if delay > 0.0:
            time.sleep(delay)
        return delay


class RetryBudget:
    """Token bucket bounding a lane's retries (anti-retry-storm).

    Retries spend one token; successes earn ``earn`` back (capped at
    ``cap``).  When the bucket is dry the failure is delivered instead of
    retried — under a full outage the lane degrades to fail-fast rather
    than multiplying load on the struggling service."""

    def __init__(self, cap: float, earn: float = 0.25):
        self._cap = max(0.0, float(cap))
        self._earn = max(0.0, float(earn))
        self._tokens = self._cap
        self._lock = threading.Lock()

    def try_spend(self) -> bool:
        """Take one token; ``False`` when the budget is exhausted."""
        with self._lock:
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                return True
            return False

    def earn(self) -> None:
        """Credit one success back toward the cap."""
        with self._lock:
            self._tokens = min(self._cap, self._tokens + self._earn)

    @property
    def tokens(self) -> float:
        """Current token balance (introspection)."""
        with self._lock:
            return self._tokens


class CircuitBreaker:
    """Per-lane circuit breaker: closed → open → half-open → closed.

    ``threshold`` consecutive failures trip the breaker (state ``open``);
    for ``cooldown`` seconds :meth:`allow` answers ``"shed"`` — callers
    route the lane to their degraded path.  After the cooldown the
    breaker goes half-open and :meth:`allow` grants up to ``probes``
    concurrent ``"probe"`` calls through the normal path; a probe success
    closes the breaker, a probe failure re-opens it (fresh cooldown).
    Thread-safe; ``transitions`` records every state change (chaos tests
    assert the trip → half-open → close sequence)."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 8, cooldown: float = 0.05,
                 probes: int = 1, on_trip=None):
        if threshold < 1:
            raise ValueError("breaker threshold must be >= 1")
        self.threshold = threshold
        self.cooldown = cooldown
        self.probes = max(1, probes)
        self.on_trip = on_trip
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0       # consecutive failures while closed
        self._open_until = 0.0
        self._probing = 0        # outstanding half-open probes
        self.trips = 0
        self.transitions: list[str] = []

    @property
    def state(self) -> str:
        """Current state (``closed`` / ``open`` / ``half_open``)."""
        with self._lock:
            return self._state

    def _transition(self, state: str) -> None:
        self._state = state
        self.transitions.append(state)

    def allow(self) -> str:
        """Admission decision for one submission: ``"closed"`` (normal
        path), ``"probe"`` (half-open trial through the normal path), or
        ``"shed"`` (degraded path — the breaker is open)."""
        with self._lock:
            if self._state == self.CLOSED:
                return self.CLOSED
            if self._state == self.OPEN:
                if time.monotonic() < self._open_until:
                    return "shed"
                self._transition(self.HALF_OPEN)
                self._probing = 0
            # half-open: bounded concurrent probes, everyone else sheds
            if self._probing < self.probes:
                self._probing += 1
                return "probe"
            return "shed"

    def record_success(self) -> None:
        """Feedback: a normal-path (or probe) call succeeded."""
        with self._lock:
            self._failures = 0
            if self._state != self.CLOSED:
                self._transition(self.CLOSED)
                self._probing = 0

    def record_failure(self) -> None:
        """Feedback: a normal-path (or probe) call failed."""
        trip = False
        with self._lock:
            if self._state == self.HALF_OPEN:
                # failed probe: straight back to open, fresh cooldown
                self._transition(self.OPEN)
                self._open_until = time.monotonic() + self.cooldown
                self._probing = 0
                self.trips += 1
                trip = True
            elif self._state == self.CLOSED:
                self._failures += 1
                if self._failures >= self.threshold:
                    self._transition(self.OPEN)
                    self._open_until = time.monotonic() + self.cooldown
                    self.trips += 1
                    trip = True
        if trip and self.on_trip is not None:
            self.on_trip()


@dataclasses.dataclass(frozen=True)
class Resilience:
    """The failure-domain configuration (see ``docs/TUNING.md``).

    ``breaker_threshold=None`` disables circuit breaking; ``deadline``
    is the default per-request deadline in seconds (``None`` = no
    deadline; ``submit(..., deadline=)`` overrides per request);
    ``fission=False`` keeps batch-wide error delivery (every waiter of a
    failed batch gets the batch's exception) instead of isolating
    failing params by binary fission-retry.  The serving knobs:
    ``quarantine_ticks`` holds a crashed lane out of allocation after
    recovery; ``lane_fail_threshold`` consecutive failures on one
    template raise :class:`LaneFailedError`."""

    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    deadline: Optional[float] = None
    breaker_threshold: Optional[int] = 8
    breaker_cooldown: float = 0.05
    breaker_probes: int = 1
    fission: bool = True
    quarantine_ticks: int = 8
    lane_fail_threshold: int = 32


class FailureDomain:
    """Per-lane breaker + retry-budget registry for one runtime/scheduler.

    Lazily creates a :class:`CircuitBreaker` and :class:`RetryBudget`
    per lane key from the :class:`Resilience` config; ``on_trip`` (if
    given) is invoked once per breaker trip — runtimes wire it to their
    ``breaker_trips`` counter."""

    def __init__(self, config: Resilience, on_trip=None):
        self.config = config
        self.retry = config.retry
        self._on_trip = on_trip
        self._lock = threading.Lock()
        self._breakers: dict = {}
        self._budgets: dict = {}

    def breaker(self, key) -> Optional[CircuitBreaker]:
        """This lane's breaker (``None`` when breaking is disabled)."""
        if self.config.breaker_threshold is None:
            return None
        br = self._breakers.get(key)
        if br is None:
            with self._lock:
                br = self._breakers.get(key)
                if br is None:
                    br = self._breakers[key] = CircuitBreaker(
                        threshold=self.config.breaker_threshold,
                        cooldown=self.config.breaker_cooldown,
                        probes=self.config.breaker_probes,
                        on_trip=self._on_trip,
                    )
        return br

    def budget(self, key) -> RetryBudget:
        """This lane's retry-token bucket (created on first use)."""
        b = self._budgets.get(key)
        if b is None:
            with self._lock:
                b = self._budgets.get(key)
                if b is None:
                    b = self._budgets[key] = RetryBudget(
                        self.retry.retry_budget, self.retry.budget_earn)
        return b

    def snapshot(self) -> dict:
        """Per-lane breaker states + budget balances (introspection)."""
        with self._lock:
            return {
                "breakers": {k: b.state for k, b in self._breakers.items()},
                "budgets": {k: b.tokens for k, b in self._budgets.items()},
            }
