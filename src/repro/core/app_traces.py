"""App-shaped HIR traces for the end-to-end transformed-vs-sync benchmark.

Three programs shaped like the paper's motivating applications (§2, §7's
benchmark suite), written as synchronous HIR — every query blocks — and
auto-transformed by :func:`~repro.core.hir.transform_program` for the
batched side.  Each trace exercises a distinct transformation surface:

* **admin workflow** — a per-user permission audit behind a ``Proc``/
  ``Call`` boundary (inline-then-fission), plus a final summary query;
* **user flow** — an order listing with *nested* per-item lookups: the
  outer loop's head query fissions, and each order's inner price loop
  fissions again inside the consumer (nested Rule A);
* **RAG pipeline** — retrieval phases: per-question retrieve, per-passage
  rerank against the accumulated context, one final generate call.

``benchmarks/bench_lanes.py`` Part 10 drives both forms through the
serving scheduler via :mod:`repro.serving.hir_bridge` and gates the
tokens/s and round-trip ratios; the equivalence harness contract (same
observables, bit-identical) applies here with real request generations as
the observable.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.hir import Assign, Call, Loop, Proc, Program, Query

__all__ = ["AppTrace", "admin_workflow", "user_flow", "rag_pipeline",
           "all_traces"]

_MOD = 10007


def _add(a, b):
    return (_num(a) + _num(b)) % _MOD


def _mix(a, b):
    return (_num(a) * 31 + _num(b) * 17 + 5) % _MOD


def _num(v) -> int:
    """Fold a value (int or generated-token tuple) into a small int —
    query results here are whole token tuples."""
    if isinstance(v, tuple):
        return sum(int(x) for x in v) % _MOD
    return int(v) % _MOD


def _zero():
    return 0


@dataclasses.dataclass
class AppTrace:
    """One benchmark trace: program, inputs, observable variable names."""

    name: str
    program: Program
    inputs: dict[str, Any]
    observe: tuple[str, ...]
    n_queries: int  # synchronous round trips (= total queries executed)


def admin_workflow() -> AppTrace:
    """Per-user permission audit behind a procedure boundary."""
    audit = Proc(
        name="audit",
        formals=("uid",),
        body=[
            Assign(target="key", fn=_mix, args=("uid", "uid")),
            Query(target="perm", query_name="perm_check", params=("key",)),
            Assign(target="score", fn=_add, args=("perm", "uid")),
        ],
        result="score",
    )
    prog = Program(
        inputs=("users",),
        body=[
            Assign(target="flags", fn=_zero, args=()),
            Loop(item_var="u", iter_var="users", body=[
                Call(target="s", proc=audit, args=("u",)),
                Assign(target="flags", fn=_add, args=("flags", "s")),
            ]),
            Query(target="log", query_name="audit_log", params=("flags",)),
        ],
    )
    users = [11, 23, 35, 41, 57, 63, 78, 92]
    return AppTrace(
        name="admin_workflow",
        program=prog,
        inputs={"users": users},
        observe=("flags", "log"),
        n_queries=len(users) + 1,
    )


def user_flow() -> AppTrace:
    """Order listing with nested per-item price lookups."""
    prog = Program(
        inputs=("orders", "line_items"),
        body=[
            Assign(target="revenue", fn=_zero, args=()),
            Loop(item_var="o", iter_var="orders", body=[
                Assign(target="okey", fn=_mix, args=("o", "o")),
                Query(target="head", query_name="order_head",
                      params=("okey",)),
                Loop(item_var="it", iter_var="line_items", body=[
                    Assign(target="ikey", fn=_mix, args=("it", "head")),
                    Query(target="price", query_name="item_price",
                          params=("ikey",)),
                    Assign(target="revenue", fn=_add,
                           args=("revenue", "price")),
                ]),
            ]),
        ],
    )
    orders = [3, 14, 27, 38, 49]
    items = [2, 5, 9, 12]
    return AppTrace(
        name="user_flow",
        program=prog,
        inputs={"orders": orders, "line_items": items},
        observe=("revenue",),
        n_queries=len(orders) * (1 + len(items)),
    )


def rag_pipeline() -> AppTrace:
    """Retrieval-augmented phases: retrieve, rerank, generate."""
    prog = Program(
        inputs=("questions", "passages"),
        body=[
            Assign(target="ctx", fn=_zero, args=()),
            Loop(item_var="q", iter_var="questions", body=[
                Query(target="doc", query_name="retrieve", params=("q",)),
                Assign(target="ctx", fn=_add, args=("ctx", "doc")),
            ]),
            Assign(target="best", fn=_zero, args=()),
            Loop(item_var="p", iter_var="passages", body=[
                Assign(target="pk", fn=_mix, args=("p", "ctx")),
                Query(target="sc", query_name="rerank", params=("pk",)),
                Assign(target="best", fn=_add, args=("best", "sc")),
            ]),
            Query(target="answer", query_name="generate", params=("best",)),
        ],
    )
    questions = [7, 19, 31, 44, 56, 68]
    passages = [4, 13, 22, 37, 46, 55, 64, 73]
    return AppTrace(
        name="rag_pipeline",
        program=prog,
        inputs={"questions": questions, "passages": passages},
        observe=("ctx", "best", "answer"),
        n_queries=len(questions) + len(passages) + 1,
    )


def all_traces() -> list[AppTrace]:
    """Every Part 10 trace, in reporting order."""
    return [admin_workflow(), user_flow(), rag_pipeline()]
