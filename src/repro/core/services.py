"""Query services — what the runtime submits requests *to*.

The paper's "database" generalizes (its §6, Experiment 4 uses a Web
service).  In this framework a service is anything with a blocking
single-request form and (optionally) a set-oriented batched form:

* :class:`SimulatedDBService` — a latency-model service for benchmarks that
  reproduces the paper's cost structure: each individual request pays one
  network round trip plus per-query processing; a batch pays **3 round
  trips** (parameter insert, batched query, temp-table cleanup — §5.2.3)
  plus cheaper per-item set-oriented processing.
* :class:`ModelService` — the ML-serving instantiation: a request is a model
  forward (e.g. score/embed/generate-step) executed by a JAX callable; the
  batched form pads and stacks requests into one device invocation —
  batching amortizes dispatch + kernel-launch + HBM-stream fixed costs the
  same way set-oriented SQL amortizes round trips and random IO.
* :class:`TableService` — an in-memory key→row "database" used for unit
  tests and the HIR interpreter (deterministic, no latency).

Each service also exposes counters (round trips, executed queries, batches)
so tests and benchmarks can assert the *mechanism*, not just timing.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Mapping, Optional, Protocol, Sequence

from repro.core.concurrency import ShardedCounter

__all__ = [
    "QueryService",
    "ServiceStats",
    "TableService",
    "SimulatedDBService",
    "ModelService",
]


class QueryService(Protocol):
    """What the runtime requires of a backing service: a single-request
    call and a set-oriented batch call (the paper's batched query)."""

    def execute(self, query_name: str, params: tuple) -> Any:
        """Execute ONE query — one service round trip."""
        ...

    def execute_batch(self, query_name: str, params_list: Sequence[tuple]) -> list:
        """Execute many parameter sets of one query as a single
        set-oriented call, results in ``params_list`` order."""
        ...


class ServiceStats:
    """Service-side counters, striped across locks
    (:class:`~repro.core.concurrency.ShardedCounter`) so concurrent worker
    threads counting calls never convoy on one stats lock.  Fields
    compare/convert like numbers; ``snapshot`` returns plain values."""

    _COUNTERS = ("round_trips", "single_queries", "batches", "batched_items",
                 "padded_items")

    def __init__(self):
        for name in self._COUNTERS:
            setattr(self, name, ShardedCounter())
        self.busy_time = ShardedCounter()

    def snapshot(self) -> dict:
        """Plain-number copy of every counter."""
        d = {name: int(getattr(self, name)) for name in self._COUNTERS}
        d["busy_time"] = float(self.busy_time)
        return d


class _StatsMixin:
    def __init__(self):
        self.stats = ServiceStats()

    def _count(self, *, round_trips=0, single=0, batches=0, items=0, padded=0,
               busy=0.0):
        st = self.stats
        if round_trips:
            st.round_trips.add(round_trips)
        if single:
            st.single_queries.add(single)
        if batches:
            st.batches.add(batches)
        if items:
            st.batched_items.add(items)
        if padded:
            st.padded_items.add(padded)
        if busy:
            st.busy_time.add(busy)


class TableService(_StatsMixin):
    """Deterministic in-memory database: ``tables[name][key] -> row``.

    ``queries`` maps a query name to ``fn(tables, params) -> result`` so
    tests can define arbitrary deterministic queries.  The default query
    ``"<table>.lookup"`` returns ``tables[table].get(key)``.
    """

    def __init__(
        self,
        tables: Optional[Mapping[str, Mapping[Any, Any]]] = None,
        queries: Optional[Mapping[str, Callable]] = None,
        latency: float = 0.0,
        batch_latency: Optional[Callable[[int], float]] = None,
    ):
        super().__init__()
        self.tables = dict(tables or {})
        self.queries = dict(queries or {})
        self.latency = latency
        self.batch_latency = batch_latency

    def _run(self, query_name: str, params: tuple) -> Any:
        if query_name in self.queries:
            return self.queries[query_name](self.tables, params)
        if query_name.endswith(".lookup"):
            table = query_name[: -len(".lookup")]
            (key,) = params
            return self.tables[table].get(key)
        raise KeyError(f"unknown query {query_name!r}")

    def execute(self, query_name: str, params: tuple) -> Any:
        """One lookup/query (1 round trip; optional fixed latency)."""
        if self.latency:
            time.sleep(self.latency)
        self._count(round_trips=1, single=1)
        return self._run(query_name, params)

    def execute_batch(self, query_name: str, params_list: Sequence[tuple]) -> list:
        """Set-oriented form: one call, 3 round trips (§5.2.3)."""
        if self.batch_latency is not None:
            time.sleep(self.batch_latency(len(params_list)))
        elif self.latency:
            time.sleep(self.latency)
        self._count(round_trips=3, batches=1, items=len(params_list))
        return [self._run(query_name, p) for p in params_list]


class SimulatedDBService(_StatsMixin):
    """Latency-model service reproducing the paper's cost trade-offs.

    Cost model (times in seconds):
      single request : ``rtt + single_proc``           (1 round trip)
      batch of n     : ``3*rtt + batch_fixed + n*batch_proc``  (3 round trips)

    With ``single_proc > batch_proc`` (set-oriented plans beat n random
    probes — §5.2.1 "random IO at the database") and ``concurrency`` limiting
    how many requests the server truly overlaps (its CPUs/disks).  A
    ``threading.Semaphore(concurrency)`` models server capacity, so client
    threads beyond it queue — matching Fig. 5's plateau when threads exceed
    what the server exploits.
    """

    def __init__(
        self,
        rtt: float = 2e-3,
        single_proc: float = 1e-3,
        batch_proc: float = 2e-4,
        batch_fixed: float = 1e-3,
        concurrency: int = 8,
        compute_fn: Optional[Callable[[str, tuple], Any]] = None,
        fail_rate: float = 0.0,
        fail_seed: int = 0,
    ):
        super().__init__()
        self.rtt = rtt
        self.single_proc = single_proc
        self.batch_proc = batch_proc
        self.batch_fixed = batch_fixed
        self._server = threading.Semaphore(concurrency)
        self.compute_fn = compute_fn or (lambda q, p: (q, p))
        self.fail_rate = fail_rate
        self.fail_seed = fail_seed

    def _check_fault(self, query_name: str, params: tuple) -> None:
        """Deterministic failure injection for degraded-mode benchmarks: a
        ``fail_rate`` fraction of ``(query_name, params)`` identities always
        fails — pure in the seed, so A/B runs poison the same requests
        regardless of batching or thread interleaving."""
        if self.fail_rate <= 0.0:
            return
        from repro.core.faults import InjectedParamError
        from repro.core.resilience import hash_unit
        if hash_unit(self.fail_seed, "db", query_name,
                     params) < self.fail_rate:
            raise InjectedParamError(query_name, params)

    def execute(self, query_name: str, params: tuple) -> Any:
        """One simulated request: 1 round trip + single-query processing."""
        t0 = time.perf_counter()
        time.sleep(self.rtt / 2)
        self._check_fault(query_name, params)
        with self._server:
            time.sleep(self.single_proc)
            out = self.compute_fn(query_name, params)
        time.sleep(self.rtt / 2)
        self._count(round_trips=1, single=1, busy=time.perf_counter() - t0)
        return out

    def execute_batch(self, query_name: str, params_list: Sequence[tuple]) -> list:
        """One simulated set-oriented call: 3 round trips + batch costs.

        With ``fail_rate`` set, a batch containing any poisoned param fails
        as a whole (statement-level poisoning) — the runtime's
        fission-retry isolates the culprits."""
        n = len(params_list)
        t0 = time.perf_counter()
        # 3 round trips: parameter insert, batched query, cleanup (§5.2.3).
        time.sleep(self.rtt * 1.5)
        for p in params_list:
            self._check_fault(query_name, p)
        with self._server:
            time.sleep(self.batch_fixed + n * self.batch_proc)
            out = [self.compute_fn(query_name, p) for p in params_list]
        time.sleep(self.rtt * 1.5)
        self._count(round_trips=3, batches=1, items=n, busy=time.perf_counter() - t0)
        return out


class ModelService(_StatsMixin):
    """A JAX model as the query service (the ML-serving instantiation).

    ``single_fn(params...) -> result`` must be a JAX callable; the batched
    form stacks the per-request parameter tuples along a new leading axis and
    runs ``batch_fn`` (default ``jax.vmap(single_fn)``) **once** — one device
    dispatch for the whole batch, the device analogue of the set-oriented
    query.  Results are split back per request.

    With ``pad_batches=True`` the batch axis is padded to a per-lane fixed
    bucket keyed by ``query_name``: a lane's bucket is the power of two of
    the largest batch it has seen, so each lane settles on ONE compiled
    shape instead of recompiling ``batch_fn`` for every distinct batch size
    the strategy emits (the jit-cache analogue of the paper's prepared
    statement).  ``lane_buckets`` exposes the current bucket per lane and
    ``stats.padded_items`` counts the filler rows paid for shape stability.
    """

    def __init__(self, single_fn: Callable, batch_fn: Optional[Callable] = None,
                 pad_batches: bool = False):
        super().__init__()
        import jax
        import jax.numpy as jnp

        self._jnp = jnp
        self.single_fn = jax.jit(single_fn)
        self.batch_fn = jax.jit(batch_fn) if batch_fn is not None else jax.jit(
            jax.vmap(single_fn)
        )
        self.pad_batches = pad_batches
        self.lane_buckets: dict[str, int] = {}

    def execute(self, query_name: str, params: tuple) -> Any:
        """One model forward, blocking until the device result is ready."""
        self._count(round_trips=1, single=1)
        out = self.single_fn(*params)
        return jax_block(out)

    def execute_batch(self, query_name: str, params_list: Sequence[tuple]) -> list:
        """One device dispatch for the whole batch; blocks for the results.

        Equivalent to ``execute_batch_async(...)()`` — dispatch + resolve
        in one call."""
        return self.execute_batch_async(query_name, params_list)()

    def execute_batch_async(self, query_name: str,
                            params_list: Sequence[tuple]) -> Callable[[], list]:
        """Dispatch the batched forward WITHOUT blocking; returns a resolver.

        JAX dispatch is asynchronous: the jitted call returns as soon as
        the computation is enqueued on the device.  This split exposes
        that to callers — the paper's "results already fetched by the time
        they are consumed", at the service layer (the same shape as
        :meth:`InferenceEngine.prefill_dispatch` /
        :meth:`~repro.serving.engine.InferenceEngine.commit_prefill` one
        level up): dispatch the batch, overlap host-side work, then call
        the returned zero-arg resolver to block on and split the results.
        """
        jnp = self._jnp
        n = len(params_list)
        n_pad = 0
        if self.pad_batches:
            bucket = max(self.lane_buckets.get(query_name, 1),
                         1 << (n - 1).bit_length())
            self.lane_buckets[query_name] = bucket
            n_pad = bucket - n
            # Repeat the last request as filler: same shapes/dtypes, results
            # beyond n are sliced away below.
            params_list = list(params_list) + [params_list[-1]] * n_pad
        stacked = tuple(
            jnp.stack([p[i] for p in params_list]) for i in range(len(params_list[0]))
        )
        self._count(round_trips=3, batches=1, items=n, padded=n_pad)
        pending = self.batch_fn(*stacked)  # async dispatch: not yet blocked

        def resolve() -> list:
            """Block on the dispatched batch and split it per request."""
            import jax

            out = jax_block(pending)
            return [jax.tree_util.tree_map(lambda a: a[i], out)
                    for i in range(n)]

        return resolve


def jax_block(x):
    """Block until every device array in the pytree is materialized."""
    import jax

    return jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a, x
    )
