"""Per-lane policy engine: learned per-template batching, tenant quotas,
weighted fairness, and cross-template (projection) sharing.

PR 1's sharded :class:`~repro.core.runtime.AsyncQueryRuntime` gave every
query template its own lane, but all lanes still shared ONE global
:class:`~repro.core.strategies.BatchingStrategy` (so a single
:class:`~repro.core.strategies.AdaptiveCost` fit one blended cost model for
services whose templates have very different cost structures), one global
``max_pending`` bound, and strict round-robin over lanes.  This module is
the per-lane brain the runtime and the serving scheduler both consult:

* **Per-lane strategies.**  Each lane owns its strategy *instance*.  Cold
  lanes (few submissions) default to :class:`PureAsync` — a trickle never
  benefits from waiting, and a batch's fixed overhead is pure loss.  A lane
  crossing ``hot_threshold`` total submissions is promoted to a fresh
  instance from ``hot_factory`` (default :class:`AdaptiveCost`), which then
  learns THAT lane's fixed-vs-per-item cost model from that lane's own
  ``observe`` feedback.  ``overrides`` pins a specific lane to a specific
  strategy instance regardless of temperature.
* **Admission quotas.**  Instead of one global ``max_pending``, submission
  is bounded per tenant (``tenant_quotas`` / ``default_tenant_quota``) and
  per lane (``lane_quota``): a whale tenant flooding one template backs off
  at ITS bound while everyone else keeps submitting.
* **Weighted fairness.**  Lane service order is weighted fair queueing via
  per-lane virtual time: picking ``k`` requests from a lane advances its
  vtime by ``k / weight``, and the next pick goes to the backlogged lane
  with the smallest vtime.  A lane with weight 2 gets twice the service of
  a weight-1 lane under contention; new lanes join at the current minimum
  vtime so they neither starve nor monopolize.
* **Cross-template sharing** (SharedDB, "one thousand queries with one
  stone"): templates that differ only in *projection* are registered via
  :meth:`share` and canonicalized onto one shared lane.  The runtime
  executes the canonical (superset) query once; each handle applies its own
  projection at fan-out, so ``users.sel_name`` and ``users.sel_email`` for
  the same key cost ONE service round trip.
* **Auto-detected sharing** from query metadata: :meth:`describe` records
  which relation a template reads (``base``) and which ``columns`` it
  projects; :meth:`resolve` then derives the canonical template and the
  projector itself, so explicit :meth:`share` registration becomes
  optional.  An explicit ``share`` always wins over an auto-derived one.

The engine is deliberately runtime-agnostic: the
:class:`~repro.core.runtime.AsyncQueryRuntime` consults it under its own
lock, the :class:`~repro.serving.scheduler.ContinuousBatchingScheduler`
from its single-threaded tick loop, so every method here takes the policy's
own lock and strategy objects keep theirs.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Iterable, Mapping, Optional

from repro.core.strategies import AdaptiveCost, BatchingStrategy, PureAsync

__all__ = ["LanePolicy", "PrefixIndex"]


class PrefixIndex:
    """Page-aligned token-prefix index for cross-request KV sharing.

    The prefix-granular generalization of :meth:`LanePolicy.share`'s
    exact-key machinery: where ``share`` canonicalizes whole templates
    that differ only in projection, ``PrefixIndex`` detects that a *new
    prompt* begins with the same tokens as KV already resident on some
    decode lane, so the engine can alias those page-aligned rows instead
    of recomputing them (SharedDB's global batch window applied to the
    prefill side of serving).

    An owner registers its (truncated) prompt with :meth:`insert`; every
    full-page prefix ``tokens[: k * page_size]`` becomes a lookup key.
    :meth:`lookup` returns ``(owner, k_pages)`` for the LONGEST
    registered full-page prefix of a candidate prompt that is *strictly
    proper* (``k * page_size < len(tokens)``): at least one novel token
    always remains, so the tail prefill that produces the request's first
    output token never degenerates to an empty scan.  Matching is exact
    token-tuple equality — positions are cache-relative (0-based after
    prompt truncation) on both sides, so identical token prefixes imply
    bit-identical KV rows under the same parameters and RoPE.

    Thread-safe (one lock), though the serving engine only consults it
    from the synchronous admission path.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        self._lock = threading.Lock()
        # owner -> registered full-page prefix tuples (for removal).
        self._owners: dict[Any, list[tuple]] = {}
        # full-page prefix tuple -> owner keys, insertion-ordered.
        self._index: dict[tuple, list] = {}
        self.hits = 0
        self.misses = 0

    def insert(self, key, tokens: Iterable[int]) -> None:
        """Register ``key`` as the resident owner of ``tokens``' KV."""
        toks = tuple(int(t) for t in tokens)
        ps = self.page_size
        prefixes = [toks[: k * ps] for k in range(1, len(toks) // ps + 1)]
        with self._lock:
            if key in self._owners:
                self._remove_locked(key)
            self._owners[key] = prefixes
            for pf in prefixes:
                self._index.setdefault(pf, []).append(key)

    def remove(self, key) -> None:
        """Forget ``key`` (its lane retired or its KV left the pool)."""
        with self._lock:
            self._remove_locked(key)

    def _remove_locked(self, key) -> None:
        for pf in self._owners.pop(key, ()):
            owners = self._index.get(pf)
            if owners is None:
                continue
            try:
                owners.remove(key)
            except ValueError:
                pass
            if not owners:
                del self._index[pf]

    def lookup(self, tokens: Iterable[int],
               exclude: Iterable = ()) -> Optional[tuple[Any, int]]:
        """Longest strictly-proper full-page prefix match, or ``None``.

        Returns ``(owner, k_pages)``; counts a hit/miss either way.
        ``exclude`` skips owners (e.g. a lane being replaced).
        """
        toks = tuple(int(t) for t in tokens)
        skip = set(exclude)
        ps = self.page_size
        kmax = (len(toks) - 1) // ps  # strictly proper: k*ps <= len-1
        with self._lock:
            for k in range(kmax, 0, -1):
                for owner in self._index.get(toks[: k * ps], ()):
                    if owner not in skip:
                        self.hits += 1
                        return owner, k
            self.misses += 1
            return None

    def __len__(self) -> int:
        """Number of registered owners."""
        return len(self._owners)


class LanePolicy:
    """Per-lane strategy selection + quotas + fairness + projection sharing.

    Parameters
    ----------
    cold_factory / hot_factory:
        Zero-arg callables producing a fresh strategy per lane.  Cold lanes
        (fewer than ``hot_threshold`` submissions) use ``cold_factory``
        (default ``PureAsync``); once promoted a lane gets its own
        ``hot_factory`` instance (default ``AdaptiveCost``) fed only by that
        lane's observations.
    hot_threshold:
        Total submissions after which a lane is considered hot.  ``0``
        makes every lane hot from the first submission.
    overrides:
        ``{lane: strategy_instance}`` — pins a lane to a given strategy
        regardless of temperature (e.g. force ``PureBatch`` for a
        report-generation template).
    lane_weights / default_weight:
        Weighted-fair-queueing weights; higher weight → proportionally more
        service under contention.
    tenant_quotas / default_tenant_quota:
        Max *outstanding* (submitted, unresolved) requests per tenant.
        ``tenant_quotas`` maps specific tenants; ``default_tenant_quota``
        applies to any other named tenant.  ``None`` disables the bound.
    lane_quota:
        Max outstanding requests per lane (any tenant), replacing the
        single global ``max_pending`` with per-template back-pressure.
    spill_budget / spill_budgets:
        Serving-side host-KV spill bounds: how many evicted-lane KV
        entries the engine's :class:`~repro.serving.engine.HostSpillPool`
        may hold per template (``spill_budgets`` names specific lanes,
        ``spill_budget`` is the default for the rest; ``None`` leaves the
        pool's own global bound as the only limit, ``0`` fences a lane
        out of the pool entirely).  Consumed via :meth:`spill_budget_for`
        — pass it as the pool's ``budget_for`` so spill residency follows
        the same per-lane policy as scheduling and KV reservations.
    """

    def __init__(
        self,
        cold_factory: Callable[[], BatchingStrategy] = PureAsync,
        hot_factory: Callable[[], BatchingStrategy] = AdaptiveCost,
        hot_threshold: int = 32,
        overrides: Optional[Mapping[str, BatchingStrategy]] = None,
        lane_weights: Optional[Mapping[str, float]] = None,
        default_weight: float = 1.0,
        tenant_quotas: Optional[Mapping[str, int]] = None,
        default_tenant_quota: Optional[int] = None,
        lane_quota: Optional[int] = None,
        max_lanes: int = 4096,
        spill_budget: Optional[int] = None,
        spill_budgets: Optional[Mapping[str, int]] = None,
    ):
        if hot_threshold < 0:
            raise ValueError("hot_threshold must be >= 0")
        if default_weight <= 0:
            raise ValueError("default_weight must be > 0")
        for lane, w in (lane_weights or {}).items():
            if w <= 0:
                raise ValueError(f"lane_weights[{lane!r}] must be > 0, got {w}")
        if max_lanes < 1:
            raise ValueError("max_lanes must be >= 1")
        if spill_budget is not None and spill_budget < 0:
            raise ValueError("spill_budget must be >= 0")
        for lane, b in (spill_budgets or {}).items():
            if b < 0:
                raise ValueError(f"spill_budgets[{lane!r}] must be >= 0, got {b}")
        self.cold_factory = cold_factory
        self.hot_factory = hot_factory
        self.hot_threshold = hot_threshold
        self.overrides = dict(overrides or {})
        self.lane_weights = dict(lane_weights or {})
        self.default_weight = default_weight
        self.tenant_quotas = dict(tenant_quotas or {})
        self.default_tenant_quota = default_tenant_quota
        self.lane_quota = lane_quota
        self.max_lanes = max_lanes
        self.spill_budget = spill_budget
        self.spill_budgets = dict(spill_budgets or {})

        self._lock = threading.Lock()
        self._strategies: dict[str, BatchingStrategy] = {}
        self._hot: set[str] = set()
        self._hot_inst: set[str] = set()  # lanes whose instance is hot_factory's
        self._submits: dict[str, int] = {}
        self._vtime: dict[str, float] = {}
        self._join_seq: dict[str, int] = {}  # deterministic vtime tie-break
        self._last_use: dict[str, int] = {}  # eviction order under max_lanes
        self._next_seq = 0
        self._use_seq = 0
        # projection sharing: variant template -> (canonical, projector)
        self._shared: dict[str, tuple[str, Callable[[Any], Any]]] = {}
        self._auto_shared: set[str] = set()  # derived (not explicit) entries
        self._auto_miss: set[str] = set()    # memoized "no superset" results
        # query metadata: template -> (base relation, columns | None=full row)
        self._meta: dict[str, tuple[str, Optional[tuple[str, ...]]]] = {}

    # -------------------------------------------------------- lane strategy
    def note_submit(self, lane: str) -> None:
        """Record one submission on ``lane`` (drives hot/cold promotion and
        the least-recently-used eviction order under ``max_lanes``)."""
        with self._lock:
            self._note_submit_locked(lane)

    def _note_submit_locked(self, lane: str) -> None:
        self._submits[lane] = self._submits.get(lane, 0) + 1
        self._use_seq += 1
        self._last_use[lane] = self._use_seq
        if len(self._submits) > self.max_lanes:
            self._evict_coldest_locked(keep=lane)

    def resolve_submit(self, query_name: str) -> tuple[str, Optional[Callable]]:
        """:meth:`resolve` + :meth:`note_submit` on the canonical lane in
        ONE lock acquisition — the policy-mode submit hot path.

        The two-call form took ``_lock`` twice per submit (resolve, then
        note); at 32 producers that is a second contended acquire for pure
        bookkeeping.  The fold notes the submission on the *canonical*
        lane (the lane the request actually runs on), which is also what
        the two-call form did.  Callers that shard lanes differently from
        the query name (``sharded=False`` compatibility mode) must keep
        using the two separate calls with their own lane key."""
        with self._lock:
            hit = self._resolve_locked(query_name)
            lane = query_name if hit is None else hit[0]
            self._note_submit_locked(lane)
        if hit is None:
            return query_name, None
        return hit

    def _evict_coldest_locked(self, keep: str) -> None:
        """Drop the least-recently-submitted lane's tracked state so
        high-cardinality template churn cannot grow the policy without
        bound (the runtime GCs its drained lanes for the same reason).
        Pinned (override) lanes are never evicted."""
        victims = sorted(
            (lk for lk in self._submits
             if lk != keep and lk not in self.overrides),
            key=lambda lk: self._last_use.get(lk, 0),
        )
        for lk in victims[: len(self._submits) - self.max_lanes]:
            for d in (self._submits, self._strategies, self._vtime,
                      self._join_seq, self._last_use):
                d.pop(lk, None)
            self._hot.discard(lk)
            self._hot_inst.discard(lk)

    def is_hot(self, lane: str) -> bool:
        """Whether ``lane`` has crossed ``hot_threshold`` submissions (and
        therefore owns — or is about to own — a ``hot_factory`` strategy
        instance).  Promotion is one-way."""
        with self._lock:
            return self._is_hot_locked(lane)

    def _is_hot_locked(self, lane: str) -> bool:
        if lane in self._hot:
            return True
        if self._submits.get(lane, 0) >= self.hot_threshold:
            self._hot.add(lane)  # promotion is one-way
            return True
        return False

    def strategy_for(self, lane: str) -> BatchingStrategy:
        """This lane's strategy instance (creating/promoting as needed).

        Promotion swaps the shared cold default for a fresh ``hot_factory``
        instance owned by this lane alone; the instance is stable from then
        on, so its learned state accumulates lane-local evidence only.
        """
        with self._lock:
            pinned = self.overrides.get(lane)
            if pinned is not None:
                return pinned
            cur = self._strategies.get(lane)
            if self._is_hot_locked(lane):
                if cur is None or lane not in self._hot_inst:
                    cur = self.hot_factory()
                    cur.reset()
                    self._strategies[lane] = cur
                    self._hot_inst.add(lane)
                return cur
            if cur is None:
                cur = self.cold_factory()
                cur.reset()
                self._strategies[lane] = cur
            return cur

    def observe(self, lane: str, batch_size: int, duration: float) -> None:
        """Route one service call's ``(batch_size, duration)`` to the lane's
        own model — evidence never crosses lanes."""
        self.strategy_for(lane).observe(batch_size, duration)

    def observe_decode(self, lane: str, duration: float) -> None:
        """Route one decode-tick duration to the lane's model (serving
        feedback: the steady-state per-token cost of this lane's class)."""
        self.strategy_for(lane).observe_decode(duration)

    def observe_abort(self, lane: str, duration: float, depth: int = 1) -> None:
        """Route one wasted speculative prefill (serving feedback: the
        scheduler dispatched ``duration`` seconds of prefill for this lane
        and aborted the bet ``depth`` tick boundaries after staging it) to
        the lane's own model, so a lane whose speculations keep missing
        batches later instead of speculating harder — deep-pipeline misses
        are charged proportionally harder (see
        :meth:`~repro.core.strategies.BatchingStrategy.observe_abort`)."""
        self.strategy_for(lane).observe_abort(duration, depth=depth)

    def observe_failure(self, lane: str, duration: float) -> None:
        """Route one failed service call (or serving submission) to the
        lane's own model: the wasted ``duration`` enters the lane's fixed
        cost as a failure penalty (see
        :meth:`~repro.core.strategies.BatchingStrategy.observe_failure`),
        so a flaky lane batches later while healthy lanes' models stay
        untouched."""
        self.strategy_for(lane).observe_failure(duration)

    # --------------------------------------------------------------- spill
    def spill_budget_for(self, lane: Optional[str]) -> Optional[int]:
        """Max host-spilled KV entries for ``lane`` — the named override,
        else the policy-wide ``spill_budget`` default (``None`` =
        pool-bounded only).  Shaped to plug straight into
        :class:`~repro.serving.engine.HostSpillPool` as ``budget_for``."""
        if lane is not None and lane in self.spill_budgets:
            return self.spill_budgets[lane]
        return self.spill_budget

    # ----------------------------------------------------- weighted fairness
    def weight(self, lane: str) -> float:
        """This lane's fair-share weight (``lane_weights`` entry or the
        ``default_weight``)."""
        return self.lane_weights.get(lane, self.default_weight)

    def lane_order(self, candidates: Iterable[str]) -> list[str]:
        """Candidates sorted by weighted-fair virtual time (lowest first,
        join order breaking ties).  New lanes join at the current minimum
        vtime over ALL tracked lanes — not just today's candidates — so a
        lane arriving while the busy lanes are momentarily drained cannot
        join at 0 and monopolize the picker once they refill."""
        with self._lock:
            cand = list(candidates)
            floor = min(self._vtime.values(), default=0.0)
            for c in cand:
                if c not in self._vtime:
                    self._vtime[c] = floor
                if c not in self._join_seq:
                    self._join_seq[c] = self._next_seq
                    self._next_seq += 1
            return sorted(cand, key=lambda c: (self._vtime[c], self._join_seq[c]))

    def lane_min(self, candidates: Iterable[str]) -> str:
        """The weighted-fair pick alone: the candidate with the smallest
        ``(vtime, join_seq)`` in ONE O(n) pass — what a ready-queue pop
        actually needs, without :meth:`lane_order`'s full sort.  New lanes
        join at the global vtime floor exactly as in ``lane_order``."""
        with self._lock:
            floor = min(self._vtime.values(), default=0.0)
            best_key = best = None
            for c in candidates:
                if c not in self._vtime:
                    self._vtime[c] = floor
                if c not in self._join_seq:
                    self._join_seq[c] = self._next_seq
                    self._next_seq += 1
                k = (self._vtime[c], self._join_seq[c])
                if best_key is None or k < best_key:
                    best_key, best = k, c
            if best is None:
                raise ValueError("lane_min needs at least one candidate")
            return best

    def charge(self, lane: str, n: int) -> None:
        """Account ``n`` picked requests against ``lane``'s fair share."""
        with self._lock:
            base = self._vtime.get(lane)
            if base is None:  # never ordered: join at the global floor
                base = min(self._vtime.values(), default=0.0)
            self._vtime[lane] = base + n / self.weight(lane)

    # -------------------------------------------------------------- quotas
    def tenant_quota(self, tenant: Optional[str]) -> Optional[int]:
        """Max outstanding requests for ``tenant`` (``None`` = unbounded;
        anonymous submissions are never tenant-bounded)."""
        if tenant is None:
            return None
        return self.tenant_quotas.get(tenant, self.default_tenant_quota)

    # ------------------------------------------------- cross-template share
    def share(self, canonical: str,
              projections: Mapping[str, Callable[[Any], Any]]) -> None:
        """Register templates that differ from ``canonical`` only in
        projection.  ``projections[variant]`` maps the canonical query's
        (superset) result to the variant's result.  Subsequent submissions
        of a variant run on the canonical lane and project at fan-out.

        Explicit registration always wins: it silently replaces an
        auto-derived share (see :meth:`describe`), and only conflicts with
        a *different* explicit canonical raise."""
        with self._lock:
            for variant, proj in projections.items():
                if variant == canonical:
                    raise ValueError(f"variant {variant!r} equals its canonical")
                existing = self._shared.get(variant)
                if (existing is not None and existing[0] != canonical
                        and variant not in self._auto_shared):
                    raise ValueError(
                        f"{variant!r} already shared onto {existing[0]!r}")
                self._shared[variant] = (canonical, proj)
                self._auto_shared.discard(variant)

    def describe(self, template: str, *, base: str,
                 columns: Optional[Iterable[str]] = None) -> None:
        """Record query metadata for auto-detected projection sharing.

        ``base`` names the relation/predicate signature the template reads
        (templates are projection-compatible only within one ``base``);
        ``columns`` lists the projected columns, ``None`` meaning the full
        row (the superset query).  By convention a single-column template
        returns the bare column value and a multi-column (or full-row)
        template returns a mapping — the projectors :meth:`resolve` derives
        follow that convention, so ``policy.share`` registration becomes
        optional for described templates.  Explicit ``share`` still wins.
        """
        with self._lock:
            cols = None if columns is None else tuple(columns)
            self._meta[template] = (base, cols)
            # Metadata changed: previously derived routings (and memoized
            # misses) may now be stale (e.g. a fuller superset appeared) —
            # rederive lazily.
            for variant in list(self._auto_shared):
                del self._shared[variant]
            self._auto_shared.clear()
            self._auto_miss.clear()

    def _auto_resolve_locked(self, template: str) -> Optional[tuple]:
        """Derive ``(canonical, projector)`` for a described template, or
        None.  The canonical is the described template over the same base
        with the WIDEST covering column set (full row — ``columns=None`` —
        widest of all), so every variant of a base converges on the same
        shared lane; name breaks ties deterministically."""
        meta = self._meta.get(template)
        if meta is None:
            return None
        base, cols = meta
        if cols is None:
            return None  # already the superset query: nothing to derive
        want = set(cols)
        best = None  # (width, name) — width: #columns, inf for full row
        for other, (obase, ocols) in self._meta.items():
            if other == template or obase != base:
                continue
            if ocols is None:
                width = float("inf")
            elif want <= set(ocols) and len(ocols) > len(cols):
                width = len(ocols)
            else:
                continue
            if (best is None or width > best[0]
                    or (width == best[0] and other < best[1])):
                best = (width, other)
        if best is None:
            return None
        canonical = best[1]
        if len(cols) == 1:
            col = cols[0]
            projector = lambda row, _c=col: row[_c]  # noqa: E731
        else:
            projector = lambda row, _cs=cols: {c: row[c] for c in _cs}  # noqa: E731
        self._shared[template] = (canonical, projector)
        self._auto_shared.add(template)
        return canonical, projector

    def resolve(self, query_name: str) -> tuple[str, Optional[Callable]]:
        """``(canonical_query, projector | None)`` for a submission —
        explicit ``share`` registrations first, then auto-derived routings
        from :meth:`describe` metadata.  Both hits and "no superset"
        misses are memoized (invalidated by :meth:`describe`), so this
        stays O(1) under the policy lock on the submit hot path.  Submit
        paths that also call :meth:`note_submit` should use
        :meth:`resolve_submit` instead (one lock acquisition, not two)."""
        with self._lock:
            hit = self._resolve_locked(query_name)
        if hit is None:
            return query_name, None
        return hit

    def _resolve_locked(self, query_name: str) -> Optional[tuple]:
        """Shared-routing lookup under ``_lock``: ``(canonical, projector)``
        or ``None`` for an unshared template."""
        hit = self._shared.get(query_name)
        if (hit is None and self._meta
                and query_name not in self._auto_miss):
            hit = self._auto_resolve_locked(query_name)
            if hit is None and query_name in self._meta:
                # Memoize "described but no covering superset" so the
                # O(|meta|) scan runs once, not per submit.  Undescribed
                # templates are O(1) rejects and need no entry, which
                # keeps this set bounded by len(_meta).
                self._auto_miss.add(query_name)
        return hit

    # ---------------------------------------------------------------- stats
    def snapshot(self) -> dict:
        """Introspection: per-lane temperature, submissions, vtime, strategy."""
        with self._lock:
            lanes = {}
            for lane in set(self._submits) | set(self._strategies) | set(self._vtime):
                strat = self.overrides.get(lane) or self._strategies.get(lane)
                lanes[lane] = {
                    "hot": lane in self._hot,
                    "submits": self._submits.get(lane, 0),
                    "vtime": self._vtime.get(lane, 0.0),
                    "weight": self.weight(lane),
                    "strategy": type(strat).__name__ if strat else None,
                }
            return {
                "hot_threshold": self.hot_threshold,
                "lane_quota": self.lane_quota,
                "shared_templates": {v: c for v, (c, _) in self._shared.items()},
                "lanes": lanes,
            }

    def __repr__(self) -> str:
        return (f"LanePolicy(hot_threshold={self.hot_threshold}, "
                f"lane_quota={self.lane_quota}, "
                f"tenants={sorted(self.tenant_quotas) or None}, "
                f"shared={len(self._shared)})")
