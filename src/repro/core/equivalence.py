"""Differential equivalence checking for the HIR transformation layer.

The paper's product is a *source-to-source rewrite*; the only acceptable
evidence that a rewrite is safe on programs nobody hand-inspected is a
differential oracle (the "Automated Synthesis of Asynchronizations"
discipline): run the untransformed program on the synchronous
:class:`~repro.core.hir.Interpreter`, run ``transform_program``'s output on
the sharded :class:`~repro.core.runtime.AsyncQueryRuntime` against the
*same* service, and require

* **bit-identical observables** — the final environment restricted to the
  original program's variable names, plus the ordered list of effect
  emissions, and
* **strictly fewer service round trips** whenever the applicability
  analysis claimed a rewrite (a batch costs 3 round trips — §5.2.3 — so
  saving round trips is the transformation's entire point), and
* **analysis/transformer agreement** — ``analyze_applicability`` approves a
  rewrite if and only if the transformed program actually contains a
  fissioned loop (a drifting analysis would make Table-1 style reporting
  meaningless).

A :class:`~repro.core.faults.ChaosService` variant re-checks equivalence
under injected transient faults and latency spikes (the runtime retries;
the synchronous oracle runs against the raw inner service) — the rewrite
must stay invisible even when the service is misbehaving.  Round-trip wins
are not asserted under chaos: retries legitimately add trips.

:func:`synthesize_async` is the synthesis-lite search: enumerate subsets of
the fissionable loop sites (``enumerate_fission_sites``), check each
candidate for equivalence, and keep the cheapest safe rewrite — equivalence
as the search filter rather than a post-hoc assertion.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, Mapping, Optional, Sequence

from repro.core.faults import ChaosPlan, ChaosService, InjectedFault
from repro.core.hir import (
    Interpreter,
    Program,
    Stmt,
    _ProducerConsumer,
    analyze_applicability,
    collect_names,
    enumerate_fission_sites,
    transform_program,
)
from repro.core.resilience import Resilience
from repro.core.services import SimulatedDBService
from repro.core.strategies import PureBatch
from repro.core.runtime import AsyncQueryRuntime

__all__ = [
    "TrialResult",
    "DifferentialReport",
    "SynthesisResult",
    "make_service",
    "count_fissioned",
    "check_program",
    "run_differential",
    "synthesize_async",
]

_UNSET = "<unset>"


def make_service(compute_fn: Optional[Callable[[str, tuple], Any]] = None,
                 ) -> SimulatedDBService:
    """A near-zero-latency simulated database with a deterministic compute
    function — latency would only slow the harness down; the cost model we
    assert on is the round-trip *count*, not wall time."""
    if compute_fn is None:
        from repro.core.services import TableService  # noqa: F401 (doc link)

        def compute_fn(q: str, p: tuple) -> int:
            return (sum((i + 3) * int(v) for i, v in enumerate(p)) * 7 + 1) \
                % 10007
    return SimulatedDBService(rtt=0.0, single_proc=0.0, batch_proc=0.0,
                              batch_fixed=0.0, concurrency=8,
                              compute_fn=compute_fn)


def count_fissioned(stmts: Sequence[Stmt]) -> int:
    """Number of ``_ProducerConsumer`` statements anywhere in the tree."""
    from repro.core.hir import If, Loop

    n = 0
    for s in stmts:
        if isinstance(s, _ProducerConsumer):
            n += 1
            n += count_fissioned([s.producer])
            n += count_fissioned(s.consumer_body)
        elif isinstance(s, Loop):
            n += count_fissioned(s.body)
        elif isinstance(s, If):
            n += count_fissioned(s.then_body) + count_fissioned(s.else_body)
    return n


class _RetryingExecute:
    """Service facade giving the interpreter's *blocking* query path the
    same bounded retry the runtime's lanes already have: consumer-side
    ``Query`` statements call ``runtime.execute`` which is a straight
    pass-through, so a transient chaos fault there would otherwise surface
    where the batched path would have retried and succeeded."""

    def __init__(self, runtime: AsyncQueryRuntime, attempts: int):
        self._runtime = runtime
        self._attempts = max(1, attempts)

    def execute(self, query_name: str, params: tuple):
        """Execute one query, retrying transient injected faults."""
        last: Optional[BaseException] = None
        for _ in range(self._attempts):
            try:
                return self._runtime.execute(query_name, params)
            except InjectedFault as e:  # transient by construction
                last = e
        raise last  # type: ignore[misc]

    def __getattr__(self, name):
        return getattr(self._runtime, name)


@dataclasses.dataclass
class TrialResult:
    """Outcome of one differential trial."""

    equivalent: bool
    fissioned: int                 # _ProducerConsumer count in transformed
    approved: int                  # analyze_applicability()["transformed"]
    sync_round_trips: int
    async_round_trips: int
    chaos: bool
    overlap: bool
    mismatches: list[str] = dataclasses.field(default_factory=list)

    @property
    def round_trip_win(self) -> bool:
        """Strictly fewer round trips than the synchronous oracle."""
        return self.async_round_trips < self.sync_round_trips

    def violations(self) -> list[str]:
        """Everything about this trial that breaks the harness contract."""
        out = list(self.mismatches)
        if (self.approved > 0) != (self.fissioned > 0):
            out.append(
                f"analysis/transformer drift: approved={self.approved} "
                f"but fissioned={self.fissioned}")
        if self.approved > 0 and not self.chaos and not self.round_trip_win:
            out.append(
                f"approved rewrite did not save round trips: sync="
                f"{self.sync_round_trips} async={self.async_round_trips}")
        return out


def _observe(env: Mapping[str, Any], names: Sequence[str]) -> dict[str, Any]:
    return {k: env.get(k, _UNSET) for k in names}


def check_program(
    prog: Program,
    inputs: Mapping[str, Any],
    observe: Optional[Sequence[str]] = None,
    *,
    overlap: bool = False,
    chaos_seed: Optional[int] = None,
    service: Optional[SimulatedDBService] = None,
    n_threads: int = 4,
    sites: Optional[Sequence[int]] = None,
) -> TrialResult:
    """Run one differential trial: synchronous oracle vs. transformed
    program on the async runtime, same backing service.

    ``chaos_seed`` wraps the transformed side's service in a
    :class:`ChaosService` injecting transient faults and latency spikes
    (the oracle keeps the raw service — its results define correctness).
    ``sites`` restricts fission to a site subset (the synthesis search).
    """
    svc = service if service is not None else make_service()
    names = tuple(observe) if observe is not None \
        else tuple(sorted(collect_names(prog.body) | set(prog.inputs)))

    sync_interp = Interpreter(svc)
    rt0 = int(svc.stats.round_trips)
    sync_env = sync_interp.run(prog, dict(inputs))
    rt1 = int(svc.stats.round_trips)

    analysis = analyze_applicability(prog)
    transformed = transform_program(prog, overlap=overlap, sites=sites)
    fissioned = count_fissioned(transformed.body)

    plan = None
    backing = svc
    resilience = None
    if chaos_seed is not None:
        # Transient-only faults: the runtime's bounded retry (default
        # max_attempts=3 > transient_repeats=2) plus batch fission-retry
        # must absorb every injected failure, leaving results bit-identical
        # to the raw-service oracle.  The breaker stays off so no trial
        # drifts into shed mode and changes the round-trip accounting shape.
        plan = ChaosPlan(seed=chaos_seed, transient_rate=0.06,
                         transient_repeats=2, latency_rate=0.05,
                         latency=2e-4)
        backing = ChaosService(svc, plan)
        resilience = Resilience(breaker_threshold=None)
    runtime = AsyncQueryRuntime(backing, n_threads=n_threads,
                                strategy=PureBatch(), resilience=resilience)
    facade = (_RetryingExecute(runtime, plan.transient_repeats + 1)
              if plan is not None else runtime)
    async_interp = Interpreter(facade)
    try:
        async_env = async_interp.run(transformed, dict(inputs))
    finally:
        runtime.drain()
        runtime.shutdown()
    rt2 = int(svc.stats.round_trips)

    mismatches: list[str] = []
    a, b = _observe(sync_env, names), _observe(async_env, names)
    for k in names:
        if a[k] != b[k]:
            mismatches.append(f"env[{k!r}]: sync={a[k]!r} async={b[k]!r}")
    if sync_interp.emitted != async_interp.emitted:
        mismatches.append(
            f"emissions differ: sync={sync_interp.emitted!r} "
            f"async={async_interp.emitted!r}")

    approved = analysis["transformed"] if sites is None else fissioned
    return TrialResult(
        equivalent=not mismatches,
        fissioned=fissioned,
        approved=approved,
        sync_round_trips=rt1 - rt0,
        async_round_trips=rt2 - rt1,
        chaos=chaos_seed is not None,
        overlap=overlap,
        mismatches=mismatches,
    )


@dataclasses.dataclass
class DifferentialReport:
    """Aggregate over a generated-program corpus."""

    n_programs: int = 0
    n_fissioned: int = 0
    n_chaos: int = 0
    n_overlap: int = 0
    n_round_trip_wins: int = 0
    violations: list[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        """True when the whole corpus satisfied the contract."""
        return not self.violations


def run_differential(
    seed: int = 0,
    n_programs: int = 50,
    *,
    chaos_every: int = 5,
    overlap_every: int = 3,
    max_violations: int = 10,
) -> DifferentialReport:
    """Generate ``n_programs`` random HIR programs (deterministic in
    ``seed``) and differential-check every one; every ``chaos_every``-th
    trial re-runs under chaos injection, every ``overlap_every``-th uses
    the §5.1 overlap variant.  Stops early after ``max_violations``."""
    # The generator lives with the tests (it is test infrastructure), the
    # checker with the core; tests put tests/ on sys.path, and so must any
    # other caller of this loop.
    from hir_strategies import gen_program

    rng = random.Random(seed)
    report = DifferentialReport()
    for i in range(n_programs):
        gp = gen_program(rng)
        chaos = chaos_every > 0 and (i % chaos_every == chaos_every - 1)
        overlap = (overlap_every > 0
                   and (i % overlap_every == overlap_every - 1))
        res = check_program(gp.program, gp.inputs, gp.observe,
                            overlap=overlap,
                            chaos_seed=(seed * 1000 + i) if chaos else None)
        report.n_programs += 1
        report.n_fissioned += 1 if res.fissioned else 0
        report.n_chaos += 1 if chaos else 0
        report.n_overlap += 1 if overlap else 0
        report.n_round_trip_wins += 1 if res.round_trip_win else 0
        for v in res.violations():
            report.violations.append(
                f"[seed={seed} program={i} chaos={chaos} overlap={overlap}] "
                f"{v}\n{gp.program!r}")
        if len(report.violations) >= max_violations:
            break
    return report


@dataclasses.dataclass
class SynthesisResult:
    """Outcome of the synthesis-lite search over fission-site subsets."""

    best_sites: tuple[int, ...]
    best_program: Program
    best_round_trips: int
    sync_round_trips: int
    n_candidates: int
    all_equivalent: bool


def synthesize_async(
    prog: Program,
    inputs: Mapping[str, Any],
    observe: Optional[Sequence[str]] = None,
    *,
    max_candidates: int = 16,
    overlap: bool = False,
) -> SynthesisResult:
    """Enumerate *which* loops to asynchronize, with equivalence as the
    filter: try subsets of the fissionable sites, differential-check each
    candidate, and keep the safe rewrite with the fewest round trips.

    The paper transforms everything it can prove safe; the synthesis view
    inverts that — propose, check, keep the best — which also makes the
    harness self-validating (an unsafe site subset would be caught by its
    own equivalence check, not by luck)."""
    ok_sites = [site for site, ok, _ in enumerate_fission_sites(
        prog, overlap=overlap) if ok]
    subsets: list[tuple[int, ...]] = [()]
    if 2 ** len(ok_sites) <= max_candidates:
        for site in ok_sites:
            subsets += [s + (site,) for s in list(subsets)]
        subsets = sorted(set(subsets), key=lambda s: (len(s), s))
    else:  # too many: empty, singletons, everything
        subsets += [(s,) for s in ok_sites] + [tuple(ok_sites)]

    best: Optional[tuple[tuple[int, ...], Program, int]] = None
    sync_rt = 0
    all_equivalent = True
    for sites in subsets:
        res = check_program(prog, inputs, observe, overlap=overlap,
                            sites=sites)
        sync_rt = res.sync_round_trips
        if not res.equivalent:
            all_equivalent = False
            continue
        cand = transform_program(prog, overlap=overlap, sites=sites)
        if best is None or res.async_round_trips < best[2]:
            best = (sites, cand, res.async_round_trips)
    assert best is not None  # the empty subset is always equivalent
    return SynthesisResult(
        best_sites=best[0],
        best_program=best[1],
        best_round_trips=best[2],
        sync_round_trips=sync_rt,
        n_candidates=len(subsets),
        all_equivalent=all_equivalent,
    )
