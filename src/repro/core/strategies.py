"""Batch-size decision strategies for asynchronous batching (paper §5.2.3).

A free worker thread observes the pending-request queue and asks its
strategy *how many requests to take*.  The strategies are exactly the
paper's:

* :class:`PureAsync` — always take 1 (plain asynchronous submission, §3).
* :class:`PureBatch` — take everything, but only once the producer is done
  (classic batching of [1]: one set-oriented execution of the whole loop).
* :class:`OneOrAll` — ``n == 1 → 1`` else take all ``n`` (§5.2.3).
* :class:`LowerThreshold` — take all when ``n > bt`` (``bt ≥ 3``, motivated
  by batching's 3 round trips: param insert, batched query, cleanup), else
  take 1 (§5.2.3).
* :class:`GrowingUpperThreshold` — cap the batch at a doubling upper bound
  so early batches stay small (better time-to-first-response) while later
  batches amortize (§5.2.3).  Orthogonal to the lower threshold; the class
  composes both, as the paper notes.

Beyond the paper's static strategies:

* :class:`AdaptiveCost` — learns the service's cost structure online.  The
  paper fixes ``bt >= 3`` from SQL's 3-round-trip batch overhead; a generic
  service (Web API, model server) has an *unknown* fixed overhead ``F`` and
  per-item cost ``c`` for batches, and single-request latency ``s``.  The
  runtime reports every call's ``(batch_size, duration)`` back through
  :meth:`observe`; the strategy fits ``T_batch(n) = F + n·c`` by
  exponentially-weighted least squares and keeps an EWMA of ``s``, then
  batches exactly when predicted batch time beats individual submission:
  ``F + n·c < n·s  ⇔  n > F/(s − c)`` — a *learned* lower threshold.  When
  the serving scheduler also reports decode-tick durations, their EWMA
  ``d`` enters the comparison as a per-call occupancy amortized by the
  batch (``n > (F + d)/(s + d − c)``), so decode-heavy lanes batch sooner.

``decide`` receives the full queue state; returning ``0`` means "wait".
Since the lock-sharded runtime, "wait" is event-driven, not polled: a
lane whose strategy answered ``0`` is parked and re-asked when that
lane's queue state changes (a new submission, a straggler re-enqueue) or
when ``producer_done`` fires — never on a timer.  A custom strategy's
``0`` must therefore be a function of the observed backlog/producer
state, not of wall-clock time alone, or its lane can park indefinitely.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

__all__ = [
    "BatchingStrategy",
    "PureAsync",
    "PureBatch",
    "OneOrAll",
    "LowerThreshold",
    "GrowingUpperThreshold",
    "AdaptiveCost",
    "from_name",
]


class BatchingStrategy:
    """Decide how many pending requests a free worker should take.

    ``decide`` returning ``0`` parks the lane until its queue state
    changes (new submission / straggler re-enqueue / ``producer_done``) —
    the runtime does not re-poll on a timer, so ``0`` must follow from
    the arguments, not from wall-clock time (see module docstring).
    """

    def decide(self, n_pending: int, producer_done: bool) -> int:
        """How many of ``n_pending`` requests to take now (0 = wait)."""
        raise NotImplementedError

    def reset(self) -> None:
        """Clear per-run state (e.g. a growing threshold, learned fits)."""

    def observe(self, batch_size: int, duration: float) -> None:
        """Feedback from the runtime after each service call.  Static
        strategies ignore it; adaptive ones learn from it."""

    def observe_decode(self, duration: float) -> None:
        """Serving-side feedback: one decode tick's duration while this
        strategy's lane had requests running.  Static strategies ignore it;
        adaptive ones track the lane's steady-state per-token cost."""

    def observe_abort(self, duration: float, depth: int = 1) -> None:
        """Serving-side feedback: a speculative prefill for this strategy's
        lane was dispatched (paying ``duration`` seconds of prefill) but
        aborted before commit — the lane it bet on was never freed, or the
        requests were retired/evicted first — so the work was wasted.
        ``depth`` is the bet's pipeline depth: how many tick boundaries it
        sat staged (1 for the single-bet pipeline), i.e. how long it held
        promised lane capacity that admission could not use.  Each aborted
        bet reports separately, attributed with ITS depth.  Static
        strategies ignore the call; adaptive ones fold the depth-scaled
        wasted time into the lane's fixed cost so a lane whose
        speculations keep missing batches later instead of speculating
        harder."""

    def observe_failure(self, duration: float) -> None:
        """Failure feedback: a service call (or serving submission) for
        this strategy's lane failed after ``duration`` seconds.  Failed
        calls never feed :meth:`observe` (a fast-failing service would
        corrupt the learned latencies), but they are not free either —
        the time was spent and the work must be redone.  Static
        strategies ignore the call; adaptive ones fold the wasted time
        into the lane's fixed cost (like the abort penalty), so a flaky
        lane demands a deeper backlog before batching — each batch risks
        a larger redo."""


@dataclasses.dataclass
class PureAsync(BatchingStrategy):
    """Always take one pending request (plain asynchronous submission, §3)."""

    def decide(self, n_pending: int, producer_done: bool) -> int:
        """One request whenever any is pending."""
        return 1 if n_pending >= 1 else 0


@dataclasses.dataclass
class PureBatch(BatchingStrategy):
    """The [1] baseline: a single set-oriented execution of all requests."""

    def decide(self, n_pending: int, producer_done: bool) -> int:
        """Everything at once — but only after the producer finished."""
        if producer_done and n_pending >= 1:
            return n_pending
        return 0


@dataclasses.dataclass
class OneOrAll(BatchingStrategy):
    """Take one when one is pending, everything otherwise (§5.2.3)."""

    def decide(self, n_pending: int, producer_done: bool) -> int:
        """One when one is pending; the whole backlog otherwise."""
        if n_pending == 0:
            return 0
        return 1 if n_pending == 1 else n_pending


@dataclasses.dataclass
class LowerThreshold(BatchingStrategy):
    """Take all iff ``n > bt``; else take one.  The paper derives ``bt >= 3``
    from batching's fixed 3-round-trip overhead."""

    bt: int = 3

    def __post_init__(self):
        if self.bt < 3:
            raise ValueError("batching threshold bt must be >= 3 (paper §5.2.3)")

    def decide(self, n_pending: int, producer_done: bool) -> int:
        """All pending iff the backlog exceeds ``bt``; one otherwise."""
        if n_pending == 0:
            return 0
        return n_pending if n_pending > self.bt else 1


class GrowingUpperThreshold(BatchingStrategy):
    """Bound batches by an upper threshold that doubles whenever a batch of
    exactly the current threshold size is emitted.  Optionally composed with
    a lower threshold (``bt``): below ``bt`` requests go out individually.
    """

    def __init__(self, initial_upper: int = 200, bt: int | None = None, growth: int = 2):
        if bt is not None and bt < 3:
            raise ValueError("batching threshold bt must be >= 3 (paper §5.2.3)")
        self.initial_upper = initial_upper
        self.bt = bt
        self.growth = growth
        self._lock = threading.Lock()
        self._upper = initial_upper

    def reset(self) -> None:
        """Shrink the upper threshold back to its initial value."""
        with self._lock:
            self._upper = self.initial_upper

    @property
    def upper(self) -> int:
        """The current (doubling) upper batch-size threshold."""
        with self._lock:
            return self._upper

    def decide(self, n_pending: int, producer_done: bool) -> int:
        """Up to the current upper threshold; a full-threshold batch
        doubles the threshold for the batches after it (Fig. 10 ramp)."""
        if n_pending == 0:
            return 0
        if self.bt is not None and n_pending <= self.bt:
            return 1
        with self._lock:
            if n_pending <= self._upper:
                return n_pending
            take = self._upper
            # A full-threshold batch was just formed: grow for future batches.
            self._upper *= self.growth
            return take

    def __repr__(self) -> str:
        return (
            f"GrowingUpperThreshold(initial_upper={self.initial_upper}, "
            f"bt={self.bt}, growth={self.growth})"
        )


class AdaptiveCost(BatchingStrategy):
    """Cost-model-based adaptive batching (learned lower threshold).

    Model (times in seconds, learned online from :meth:`observe`):

      * ``s``  — EWMA latency of single-request executions;
      * ``F, c`` — intercept/slope of ``T_batch(n) = F + n·c``, fit by
        exponentially-decayed least squares over batched executions;
      * ``d``  — EWMA decode-tick latency from :meth:`observe_decode`
        (serving feedback; 0 until the scheduler reports any).

    Draining ``n`` pending requests costs ``n·s`` submitted individually
    (one connection, serialized) vs ``F + n·c`` as one set-oriented call, so
    batching wins iff ``n > F/(s − c)``.  ``decide`` takes everything when
    the backlog clears that learned threshold, else one.

    **Decode occupancy.**  In continuous batching one decode tick serves the
    whole admitted batch at once, so a batch pays the expected decode
    occupancy ``d`` ONCE per service call — exactly like the fixed prefill
    cost ``F`` — while ``n`` individually-submitted requests each pay their
    own ``d``.  With decode evidence the comparison becomes
    ``F + n·c + d  <  n·(s + d)``, i.e. a *learned* threshold
    ``(F + d)/(s + d − c)``: a decode-heavy lane (large ``d``) batches
    sooner, because its per-request cost is dominated by decode ticks that
    batching amortizes.  Without decode evidence (``d`` unobserved) the
    threshold reduces to the paper-style ``F/(s − c)``.

    Until ``min_samples`` observations of each kind exist the strategy
    *explores*: it alternates single executions and take-all batches so both
    sides of the model get data (and batch sizes vary enough to identify the
    slope).  If the data says batching never pays (``s <= c``) it degrades
    to pure async.
    """

    def __init__(self, alpha: float = 0.3, min_samples: int = 3,
                 max_take: Optional[int] = None):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self.min_samples = min_samples
        self.max_take = max_take
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        """Forget all learned evidence (per-run state)."""
        with getattr(self, "_lock", threading.Lock()):
            self._s: Optional[float] = None  # EWMA single latency
            self._d: Optional[float] = None  # EWMA decode-tick latency (serving)
            self._ab: Optional[float] = None  # EWMA wasted spec-prefill time
            self._ab_depth: Optional[float] = None  # EWMA aborted-bet depth
            self._fl: Optional[float] = None  # EWMA wasted failed-call time
            self._n_single = 0
            self._n_batch = 0
            self.aborts = 0  # speculative prefills wasted (observe_abort calls)
            self.failures = 0  # failed service calls (observe_failure calls)
            # decayed least-squares moments for T(n) = F + n*c
            self._w = self._sn = self._st = self._snt = self._snn = 0.0
            self._explore_flip = False

    # ------------------------------------------------------------- learning
    def observe(self, batch_size: int, duration: float) -> None:
        """Fold one service call's ``(batch_size, duration)`` into the model:
        size-1 calls update the single-latency EWMA ``s``; larger ones feed
        the decayed least-squares fit of ``T_batch(n) = F + n·c``.  Each
        *successful* batch also decays the abort penalty (see
        :meth:`observe_abort`) — speculation that has started landing again
        stops being taxed."""
        with self._lock:
            if batch_size <= 1:
                self._n_single += 1
                self._s = (
                    duration if self._s is None
                    else (1 - self.alpha) * self._s + self.alpha * duration
                )
                return
            self._n_batch += 1
            if self._ab:
                self._ab *= 1 - self.alpha  # a landed batch: decay the penalty
            if self._fl:
                self._fl *= 1 - self.alpha  # a healthy call: decay the penalty
            d = 1 - self.alpha  # decay old evidence
            self._w = self._w * d + 1.0
            self._sn = self._sn * d + batch_size
            self._st = self._st * d + duration
            self._snt = self._snt * d + batch_size * duration
            self._snn = self._snn * d + batch_size * batch_size

    def observe_decode(self, duration: float) -> None:
        """Fold one decode-tick duration into the lane's decode EWMA ``d``."""
        with self._lock:
            self._d = (
                duration if self._d is None
                else (1 - self.alpha) * self._d + self.alpha * duration
            )

    def observe_abort(self, duration: float, depth: int = 1) -> None:
        """Charge one wasted speculative prefill to this lane's cost model.

        The wasted cost is the dispatch ``duration`` scaled by the bet's
        pipeline ``depth``: a bet that sat staged for ``d`` tick
        boundaries also held promised lane capacity for ``d`` ticks that
        admission could not use, so a depth-4 miss is charged four times
        the depth-1 miss of the same dispatch — deep pipelines that keep
        missing throttle themselves faster than shallow ones.  The scaled
        cost enters an EWMA ``ab`` that is added to the fixed cost in
        :attr:`threshold` (``(F + d + ab)/(s + d − c)``): the lane
        effectively pays its wasted speculation as extra per-batch setup,
        demanding a deeper backlog before batching/speculating again.
        Successful batches decay the penalty back toward zero
        (:meth:`observe`).  ``abort_depth`` tracks the EWMA of reported
        depths (introspection: how deep this lane's misses run)."""
        cost = duration * max(1, depth)
        with self._lock:
            self.aborts += 1
            self._ab = (
                cost if self._ab is None
                else (1 - self.alpha) * self._ab + self.alpha * cost
            )
            self._ab_depth = (
                float(depth) if self._ab_depth is None
                else (1 - self.alpha) * self._ab_depth + self.alpha * depth
            )

    def observe_failure(self, duration: float) -> None:
        """Charge one failed service call's wasted time to the model.

        Failure feedback enters the same way abort feedback does: an EWMA
        ``fl`` added to the fixed cost in :attr:`threshold`
        (``(F + d + ab + fl)/(s + d − c)``), so a flaky lane batches
        later — every batch on it risks ``fl`` seconds of redone work —
        and successful calls decay the penalty back toward zero
        (:meth:`observe`)."""
        with self._lock:
            self.failures += 1
            self._fl = (
                duration if self._fl is None
                else (1 - self.alpha) * self._fl + self.alpha * duration
            )

    @property
    def failure_penalty(self) -> float:
        """Current EWMA of wasted failed-call time (0.0 when no failure
        has been observed, or once healthy calls decayed it away)."""
        with self._lock:
            return self._fl or 0.0

    @property
    def abort_penalty(self) -> float:
        """Current EWMA of wasted speculative-prefill time (depth-scaled;
        0.0 when no abort has been observed, or once successful batches
        have decayed the penalty away)."""
        with self._lock:
            return self._ab or 0.0

    @property
    def abort_depth(self) -> Optional[float]:
        """EWMA of the pipeline depth at which this lane's speculative
        bets abort (``None`` until any abort is observed) — introspection
        for tuning ``spec_depth``: a lane whose misses run deep wastes
        promised capacity for longer per miss."""
        with self._lock:
            return self._ab_depth

    @property
    def decode_latency(self) -> Optional[float]:
        """EWMA of observed decode-tick durations for this lane (``None``
        until the scheduler reports any) — the per-token side of the lane's
        cost model, alongside the prefill ``F + n·c`` fit."""
        with self._lock:
            return self._d

    def estimates(self) -> Optional[tuple]:
        """``(F, c, s)`` once enough evidence exists, else ``None``."""
        with self._lock:
            if (self._s is None or self._n_single < self.min_samples
                    or self._n_batch < self.min_samples or self._w <= 0):
                return None
            mean_n = self._sn / self._w
            mean_t = self._st / self._w
            var_n = self._snn / self._w - mean_n * mean_n
            if var_n <= 1e-12:  # all batches same size: slope unidentifiable
                return None
            cov = self._snt / self._w - mean_n * mean_t
            c = cov / var_n
            f = mean_t - c * mean_n
            return max(f, 0.0), max(c, 0.0), self._s

    @property
    def threshold(self) -> Optional[float]:
        """The learned batching threshold ``(F + d + ab + fl)/(s + d − c)``
        — decode occupancy ``d``, the speculative-abort penalty ``ab``
        and the failure penalty ``fl`` are amortized by the batch like
        the fixed cost, each individual submission paying its own
        (``F/(s − c)`` while no decode ticks, aborts or failures have
        been observed).  ``inf`` when batching never pays; ``None`` while
        still exploring."""
        est = self.estimates()
        if est is None:
            return None
        f, c, s = est
        d = self.decode_latency or 0.0
        ab = self.abort_penalty
        fl = self.failure_penalty
        if s + d <= c:
            return float("inf")
        return (f + d + ab + fl) / (s + d - c)

    # ------------------------------------------------------------- decision
    def decide(self, n_pending: int, producer_done: bool) -> int:
        """Take everything when the backlog clears the learned threshold,
        one otherwise; alternate single/take-all while still exploring."""
        if n_pending == 0:
            return 0
        cap = self.max_take or n_pending
        bt = self.threshold
        if bt is None:  # explore: feed both sides of the cost model
            if n_pending == 1:
                return 1
            with self._lock:
                self._explore_flip = not self._explore_flip
                take_all = self._explore_flip
            return min(n_pending, cap) if take_all else 1
        if bt == float("inf"):
            return 1
        return min(n_pending, cap) if n_pending > bt else 1

    def __repr__(self) -> str:
        return (f"AdaptiveCost(alpha={self.alpha}, "
                f"min_samples={self.min_samples}, threshold={self.threshold})")


def from_name(name: str, **kw) -> BatchingStrategy:
    """Construct a strategy by its CLI/benchmark name (see ``table``)."""
    table = {
        "async": PureAsync,
        "batch": PureBatch,
        "one_or_all": OneOrAll,
        "lower_threshold": LowerThreshold,
        "growing_upper": GrowingUpperThreshold,
        "adaptive": AdaptiveCost,
    }
    try:
        return table[name](**kw)
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; one of {sorted(table)}") from None
