"""Batch-size decision strategies for asynchronous batching (paper §5.2.3).

A free worker thread observes the pending-request queue and asks its
strategy *how many requests to take*.  The strategies are exactly the
paper's:

* :class:`PureAsync` — always take 1 (plain asynchronous submission, §3).
* :class:`PureBatch` — take everything, but only once the producer is done
  (classic batching of [1]: one set-oriented execution of the whole loop).
* :class:`OneOrAll` — ``n == 1 → 1`` else take all ``n`` (§5.2.3).
* :class:`LowerThreshold` — take all when ``n > bt`` (``bt ≥ 3``, motivated
  by batching's 3 round trips: param insert, batched query, cleanup), else
  take 1 (§5.2.3).
* :class:`GrowingUpperThreshold` — cap the batch at a doubling upper bound
  so early batches stay small (better time-to-first-response) while later
  batches amortize (§5.2.3).  Orthogonal to the lower threshold; the class
  composes both, as the paper notes.

``decide`` receives the full queue state; returning ``0`` means "wait".
"""
from __future__ import annotations

import dataclasses
import threading

__all__ = [
    "BatchingStrategy",
    "PureAsync",
    "PureBatch",
    "OneOrAll",
    "LowerThreshold",
    "GrowingUpperThreshold",
    "from_name",
]


class BatchingStrategy:
    """Decide how many pending requests a free worker should take."""

    def decide(self, n_pending: int, producer_done: bool) -> int:
        raise NotImplementedError

    def reset(self) -> None:  # per-run state (e.g. growing threshold)
        pass


@dataclasses.dataclass
class PureAsync(BatchingStrategy):
    def decide(self, n_pending: int, producer_done: bool) -> int:
        return 1 if n_pending >= 1 else 0


@dataclasses.dataclass
class PureBatch(BatchingStrategy):
    """The [1] baseline: a single set-oriented execution of all requests."""

    def decide(self, n_pending: int, producer_done: bool) -> int:
        if producer_done and n_pending >= 1:
            return n_pending
        return 0


@dataclasses.dataclass
class OneOrAll(BatchingStrategy):
    def decide(self, n_pending: int, producer_done: bool) -> int:
        if n_pending == 0:
            return 0
        return 1 if n_pending == 1 else n_pending


@dataclasses.dataclass
class LowerThreshold(BatchingStrategy):
    """Take all iff ``n > bt``; else take one.  The paper derives ``bt >= 3``
    from batching's fixed 3-round-trip overhead."""

    bt: int = 3

    def __post_init__(self):
        if self.bt < 3:
            raise ValueError("batching threshold bt must be >= 3 (paper §5.2.3)")

    def decide(self, n_pending: int, producer_done: bool) -> int:
        if n_pending == 0:
            return 0
        return n_pending if n_pending > self.bt else 1


class GrowingUpperThreshold(BatchingStrategy):
    """Bound batches by an upper threshold that doubles whenever a batch of
    exactly the current threshold size is emitted.  Optionally composed with
    a lower threshold (``bt``): below ``bt`` requests go out individually.
    """

    def __init__(self, initial_upper: int = 200, bt: int | None = None, growth: int = 2):
        if bt is not None and bt < 3:
            raise ValueError("batching threshold bt must be >= 3 (paper §5.2.3)")
        self.initial_upper = initial_upper
        self.bt = bt
        self.growth = growth
        self._lock = threading.Lock()
        self._upper = initial_upper

    def reset(self) -> None:
        with self._lock:
            self._upper = self.initial_upper

    @property
    def upper(self) -> int:
        with self._lock:
            return self._upper

    def decide(self, n_pending: int, producer_done: bool) -> int:
        if n_pending == 0:
            return 0
        if self.bt is not None and n_pending <= self.bt:
            return 1
        with self._lock:
            if n_pending <= self._upper:
                return n_pending
            take = self._upper
            # A full-threshold batch was just formed: grow for future batches.
            self._upper *= self.growth
            return take

    def __repr__(self) -> str:
        return (
            f"GrowingUpperThreshold(initial_upper={self.initial_upper}, "
            f"bt={self.bt}, growth={self.growth})"
        )


def from_name(name: str, **kw) -> BatchingStrategy:
    table = {
        "async": PureAsync,
        "batch": PureBatch,
        "one_or_all": OneOrAll,
        "lower_threshold": LowerThreshold,
        "growing_upper": GrowingUpperThreshold,
    }
    try:
        return table[name](**kw)
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; one of {sorted(table)}") from None
