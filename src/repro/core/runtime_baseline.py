"""FROZEN PR 2 baseline: the global-lock multi-lane runtime, kept verbatim
for A/B measurement only.

This is the :class:`~repro.core.runtime.AsyncQueryRuntime` as it stood
before the lock-sharded refactor: every ``submit`` / ``fetch`` / worker
pick / cache probe / quota check funnels through ONE ``threading.Lock``,
quota waits busy-poll at 100 ms, and every delivery ``notify_all``s one
global condition variable that every blocked producer and fetcher sleeps
on.  The Part 5 contention scenario in ``benchmarks/bench_lanes.py``
drives this class and the sharded runtime with identical 32-producer /
8-worker traffic and gates the sharded runtime's submissions/s at >= 2x
this baseline in CI.

Do not grow features here — it exists to stay slow in exactly the way
PR 2 was slow.  The API mirrors the sharded runtime (handles are the
shared :class:`~repro.core.runtime.Handle` type) so drivers can swap the
two classes.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Optional

from repro.core.lane_policy import LanePolicy
from repro.core.runtime import Handle
from repro.core.services import QueryService
from repro.core.strategies import BatchingStrategy, PureAsync

__all__ = ["GlobalLockRuntime", "GlobalLockRuntimeStats"]

_SINGLE_LANE = "__single__"  # lane key in sharded=False compatibility mode


@dataclasses.dataclass
class GlobalLockRuntimeStats:
    submitted: int = 0
    completed: int = 0
    single_executions: int = 0
    batch_executions: int = 0
    resubmissions: int = 0
    deduped: int = 0      # submissions coalesced onto a pending/in-flight call
    cache_hits: int = 0   # submissions served from the completed-result LRU
    cache_expired: int = 0  # LRU entries dropped because their TTL lapsed
    shared: int = 0       # submissions rerouted onto a canonical lane (projection)
    quota_waits: int = 0  # submissions that blocked on a quota / back-pressure bound
    batch_trace: list = dataclasses.field(default_factory=list)  # (seq, size)
    # per-lane (seq, size) traces; lane key == query template (or __single__)
    lane_traces: dict = dataclasses.field(default_factory=dict)

    def snapshot(self) -> dict:
        d = dataclasses.asdict(self)
        d["batch_sizes"] = [s for _, s in self.batch_trace if s > 1]
        d["mean_batch_size"] = self.mean_batch_size
        return d

    @property
    def mean_batch_size(self) -> float:
        if not self.batch_trace:
            return 0.0
        return sum(s for _, s in self.batch_trace) / len(self.batch_trace)


class _Entry:
    """One service call's worth of work: a params tuple plus every handle
    key whose submission coalesced onto it (dedup fan-out)."""

    __slots__ = ("keys", "query_name", "params")

    def __init__(self, key: int, query_name: str, params: tuple):
        self.keys = [key]
        self.query_name = query_name
        self.params = params


class GlobalLockRuntime:
    """The runtime library of §4.2 + §5.2, sharded into per-template lanes.

    May be used directly (``submit``/``fetch``) or as the service behind the
    HIR :class:`~repro.core.hir.Interpreter` for transformed programs.
    """

    def __init__(
        self,
        service: QueryService,
        n_threads: int = 10,
        strategy: Optional[BatchingStrategy] = None,
        max_pending: Optional[int] = None,
        straggler_timeout: Optional[float] = None,
        sharded: bool = True,
        dedup: bool = True,
        result_cache_size: int = 0,
        result_cache_ttl: Optional[float] = None,
        policy: Optional[LanePolicy] = None,
    ):
        if policy is not None and strategy is not None:
            raise ValueError(
                "pass either a global `strategy` or a per-lane `policy`, not both"
            )
        self.service = service
        self.policy = policy
        self.strategy = strategy or PureAsync()
        self.strategy.reset()
        self.n_threads = n_threads
        self.max_pending = max_pending
        self.straggler_timeout = straggler_timeout
        self.sharded = sharded
        self.dedup = dedup

        # lane key -> deque[_Entry]; insertion-ordered for round-robin
        self._lanes: "OrderedDict[str, deque[_Entry]]" = OrderedDict()
        self._rr = 0  # round-robin cursor over lanes
        self._n_pending = 0  # total queued entries across lanes
        self._results: dict[int, Any] = {}
        self._errors: dict[int, BaseException] = {}
        self._lock = threading.Lock()
        self._work_cv = threading.Condition(self._lock)  # queue state changed
        self._done_cv = threading.Condition(self._lock)  # a result arrived
        self._next_key = 0
        self._producer_done = False
        self._shutdown = False
        # dedup registries: request identity -> live entry
        self._queued_by_req: dict[tuple, _Entry] = {}
        self._inflight_by_req: dict[tuple, _Entry] = {}
        # handle key -> (query_name, params) while unresolved (stragglers)
        self._inflight_params: dict[int, tuple] = {}
        # LRU maps request identity -> (value, monotonic deadline | None)
        self._cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._cache_size = result_cache_size
        self._cache_ttl = result_cache_ttl
        # per-handle projection (cross-template sharing fan-out)
        self._projections: dict[int, Any] = {}
        # quota accounting: handle key -> (lane key, tenant) while outstanding
        self._accounting: dict[int, tuple] = {}
        self._lane_out: dict[str, int] = {}
        self._tenant_out: dict[str, int] = {}
        self.stats = GlobalLockRuntimeStats()

        self._threads = [
            threading.Thread(target=self._worker, name=f"glr-worker-{i}", daemon=True)
            for i in range(n_threads)
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------ API
    def submit(self, query_name: str, params: tuple,
               tenant: Optional[str] = None) -> Handle:
        """Non-blocking query submission (``submitQuery``).  Blocks only at an
        admission bound: the global ``max_pending`` (§8 producer back-off), or
        — with a :class:`LanePolicy` — this tenant's / this lane's quota.

        With a policy, templates registered via ``policy.share`` are
        canonicalized onto their shared lane here; the submission's own
        projection is applied at result fan-out.
        """
        policy = self.policy
        if policy is not None:
            lane_query, projector = policy.resolve(query_name)
        else:
            lane_query, projector = query_name, None
        with self._lock:
            lk = self._lane_key(lane_query)
            # Back-off bounds OUTSTANDING requests (submitted, unresolved)
            # rather than queued entries, so coalesced duplicates — which
            # enqueue nothing but still hold a handle, a registry slot and
            # eventually a result — cannot grow memory past the bound either.
            blocked = False
            while not self._shutdown:
                tq = policy.tenant_quota(tenant) if policy is not None else None
                lq = policy.lane_quota if policy is not None else None
                if (
                    self.max_pending is not None
                    and self.stats.submitted - self.stats.completed >= self.max_pending
                ):
                    pass
                elif (tq is not None
                        and self._tenant_out.get(tenant, 0) >= tq):
                    pass
                elif lq is not None and self._lane_out.get(lk, 0) >= lq:
                    pass
                else:
                    break
                if not blocked:
                    blocked = True
                    self.stats.quota_waits += 1
                self._done_cv.wait(timeout=0.1)
            if self._shutdown:
                raise RuntimeError("runtime is shut down")
            handle = Handle(self._next_key, query_name)
            self._next_key += 1
            self.stats.submitted += 1
            self._producer_done = False
            if projector is not None:
                self.stats.shared += 1
            if policy is not None:
                policy.note_submit(lk)

            req = self._req_key(lane_query, params)
            # 1) completed-result cache (SharedDB-style reuse across time)
            if req is not None and self._cache_size:
                value, fresh = self._cache_get_locked(req)
                if fresh:
                    self._deliver_locked(handle.key, value, projector)
                    self.stats.cache_hits += 1
                    self.stats.completed += 1
                    self._done_cv.notify_all()
                    return handle
            # 2) in-flight/pending dedup (sharing across concurrent users)
            if req is not None and self.dedup:
                live = self._queued_by_req.get(req) or self._inflight_by_req.get(req)
                if live is not None:
                    live.keys.append(handle.key)
                    self._inflight_params[handle.key] = (lane_query, params)
                    self._register_outstanding_locked(handle.key, lk, tenant, projector)
                    self.stats.deduped += 1
                    return handle
            # 3) enqueue on this template's lane
            entry = _Entry(handle.key, lane_query, params)
            if req is not None and self.dedup:
                self._queued_by_req[req] = entry
            self._inflight_params[handle.key] = (lane_query, params)
            self._register_outstanding_locked(handle.key, lk, tenant, projector)
            self._lane_for(lane_query).append(entry)
            self._n_pending += 1
            self._work_cv.notify()
        return handle

    def producer_done(self) -> None:
        """Signal that no more requests are coming (enables PureBatch and
        lets adaptive strategies drain the tail)."""
        with self._lock:
            self._producer_done = True
            self._work_cv.notify_all()

    def fetch(self, handle: Optional[Handle]) -> Any:
        """Blocking result fetch (``fetchResult`` / ``getResultSet(ctx)``).
        ``None`` handles (guarded-away submissions, Rule B) return ``None``.
        """
        if handle is None:
            return None
        deadline = (
            time.monotonic() + self.straggler_timeout
            if self.straggler_timeout is not None
            else None
        )
        with self._lock:
            while handle.key not in self._results and handle.key not in self._errors:
                timeout = None
                if deadline is not None:
                    timeout = max(0.0, deadline - time.monotonic())
                    if timeout == 0.0:
                        # Straggler: re-enqueue so another lane retries.
                        self._resubmit_locked(handle)
                        deadline = time.monotonic() + self.straggler_timeout
                        timeout = self.straggler_timeout
                self._done_cv.wait(timeout=timeout)
            if handle.key in self._errors:
                raise self._errors[handle.key]
            return self._results[handle.key]

    # The HIR interpreter's synchronous path delegates to the service.
    def execute(self, query_name: str, params: tuple) -> Any:
        return self.service.execute(query_name, params)

    def drain(self) -> None:
        """Block until every submitted request has a result."""
        self.producer_done()
        with self._lock:
            while self.stats.completed < self.stats.submitted:
                self._done_cv.wait(timeout=0.1)

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            self._work_cv.notify_all()
            self._done_cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.drain()
        self.shutdown()
        return False

    # ------------------------------------------------------------ internals
    def _req_key(self, query_name: str, params: tuple) -> Optional[tuple]:
        """Request identity for dedup/caching; None if params unhashable."""
        try:
            hash(params)
        except TypeError:
            return None
        return (query_name, params)

    def _lane_key(self, query_name: str) -> str:
        return query_name if self.sharded else _SINGLE_LANE

    # --------------------------------------------------- cache (TTL + hooks)
    def _cache_get_locked(self, req: tuple) -> tuple:
        """``(value, fresh)`` — expires TTL'd entries on the read path."""
        hit = self._cache.get(req)
        if hit is None:
            return None, False
        value, deadline = hit
        if deadline is not None and time.monotonic() >= deadline:
            del self._cache[req]
            self.stats.cache_expired += 1
            return None, False
        self._cache.move_to_end(req)
        return value, True

    def _cache_put_locked(self, req: tuple, value: Any) -> None:
        deadline = (
            time.monotonic() + self._cache_ttl
            if self._cache_ttl is not None else None
        )
        self._cache[req] = (value, deadline)
        self._cache.move_to_end(req)
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)

    def invalidate(self, query_name: Optional[str] = None,
                   params: Optional[tuple] = None) -> int:
        """Explicit result-cache invalidation hook (the complement of TTL
        expiry, for services whose writes are visible to the caller).

        ``invalidate()`` drops everything; ``invalidate(q)`` drops every
        cached result of template ``q``; ``invalidate(q, params)`` drops one
        entry.  Shared (projection) variants resolve to their canonical
        template first.  Returns the number of entries dropped.
        """
        if query_name is not None and self.policy is not None:
            query_name = self.policy.resolve(query_name)[0]
        with self._lock:
            if query_name is None:
                n = len(self._cache)
                self._cache.clear()
                return n
            if params is not None:
                rk = self._req_key(query_name, params)
                if rk is not None and rk in self._cache:
                    del self._cache[rk]
                    return 1
                return 0
            victims = [k for k in self._cache if k[0] == query_name]
            for k in victims:
                del self._cache[k]
            return len(victims)

    # ------------------------------------------------ quota + share plumbing
    def _register_outstanding_locked(self, key: int, lane_key: str,
                                     tenant: Optional[str],
                                     projector: Optional[Any]) -> None:
        self._accounting[key] = (lane_key, tenant)
        self._lane_out[lane_key] = self._lane_out.get(lane_key, 0) + 1
        if tenant is not None:
            self._tenant_out[tenant] = self._tenant_out.get(tenant, 0) + 1
        if projector is not None:
            self._projections[key] = projector

    def _release_outstanding_locked(self, key: int) -> None:
        acct = self._accounting.pop(key, None)
        if acct is None:
            return
        lane_key, tenant = acct
        left = self._lane_out.get(lane_key, 0) - 1
        if left > 0:
            self._lane_out[lane_key] = left
        else:
            self._lane_out.pop(lane_key, None)
        if tenant is not None:
            left = self._tenant_out.get(tenant, 0) - 1
            if left > 0:
                self._tenant_out[tenant] = left
            else:
                self._tenant_out.pop(tenant, None)

    def _deliver_locked(self, key: int, value: Any, projector) -> None:
        """Resolve one handle, applying its projection (sharing fan-out)."""
        if projector is None:
            self._results[key] = value
            return
        try:
            self._results[key] = projector(value)
        except BaseException as e:  # noqa: BLE001 — surface via fetch
            self._errors[key] = e

    def _observe(self, lane_key: str, batch_size: int, duration: float) -> None:
        """Route service-call feedback to the deciding model: the lane's own
        (policy mode) or the global strategy."""
        if self.policy is not None:
            self.policy.observe(lane_key, batch_size, duration)
        else:
            self.strategy.observe(batch_size, duration)

    def _lane_for(self, query_name: str) -> deque:
        lk = self._lane_key(query_name)
        lane = self._lanes.get(lk)
        if lane is None:
            lane = self._lanes[lk] = deque()
            self.stats.lane_traces.setdefault(lk, [])
        return lane

    def _resubmit_locked(self, handle: Handle) -> None:
        qp = self._inflight_params.get(handle.key)
        if qp is None:
            return  # already resolved
        query_name, params = qp
        lane = self._lane_for(query_name)
        for e in lane:
            if handle.key in e.keys:
                return  # already pending again
        # Bypass dedup on purpose: the point is a racing duplicate call.
        lane.append(_Entry(handle.key, query_name, params))
        self._n_pending += 1
        self.stats.resubmissions += 1
        self._work_cv.notify()

    def _pick_locked(self) -> Optional[tuple]:
        """Pick work from the lanes: weighted-fair order under a
        :class:`LanePolicy` (lowest virtual time first, each lane asked its
        OWN strategy), plain round-robin with the global strategy otherwise.
        The first lane whose strategy grants a take yields
        ``(lane_key, query_name, [entries])``.  None → nothing to do."""
        keys = list(self._lanes.keys())
        if not keys:
            return None
        n_lanes = len(keys)
        if self.policy is not None:
            ordered = self.policy.lane_order(
                [k for k in keys if self._lanes[k]])
        else:
            ordered = [keys[(self._rr + off) % n_lanes] for off in range(n_lanes)]
        for pos, lk in enumerate(ordered):
            lane = self._lanes.get(lk)
            if not lane:
                continue
            strategy = (self.policy.strategy_for(lk) if self.policy is not None
                        else self.strategy)
            take = strategy.decide(len(lane), self._producer_done)
            if take <= 0:
                continue
            if self.policy is None:
                self._rr = (self._rr + pos + 1) % n_lanes
            take = min(take, len(lane))
            # Batches must share a query template.  Sharded lanes are
            # homogeneous by construction; the single-queue compatibility
            # mode splits at the first boundary (the paper's behaviour).
            first_q = lane[0].query_name
            picked: list[_Entry] = []
            while lane and len(picked) < take:
                if lane[0].query_name != first_q:
                    break
                entry = lane.popleft()
                rk = self._req_key(entry.query_name, entry.params)
                if rk is not None and self._queued_by_req.get(rk) is entry:
                    del self._queued_by_req[rk]
                if self.dedup and rk is not None \
                        and rk not in self._inflight_by_req:
                    self._inflight_by_req[rk] = entry
                picked.append(entry)
            self._n_pending -= len(picked)
            if self.policy is not None:
                self.policy.charge(lk, len(picked))
            if not lane:
                # GC empty lanes so high-cardinality template churn doesn't
                # grow the round-robin scan (traces keep the history).
                del self._lanes[lk]
            seq = self.stats.single_executions + self.stats.batch_executions
            self.stats.batch_trace.append((seq, len(picked)))
            self.stats.lane_traces.setdefault(lk, []).append((seq, len(picked)))
            if len(picked) == 1:
                self.stats.single_executions += 1
            else:
                self.stats.batch_executions += 1
            return lk, first_q, picked
        return None

    def _worker(self) -> None:
        while True:
            with self._lock:
                work = None
                while not self._shutdown:
                    if self._n_pending:
                        work = self._pick_locked()
                        if work is not None:
                            break
                    self._work_cv.wait(timeout=0.05)
                if self._shutdown:
                    return
            lane_key, query_name, picked = work

            t0 = time.perf_counter()
            try:
                if len(picked) == 1:
                    out = [self.service.execute(query_name, picked[0].params)]
                else:
                    out = self.service.execute_batch(
                        query_name, [e.params for e in picked]
                    )
                err = None
            except BaseException as e:  # noqa: BLE001 — propagate via fetch
                out, err = None, e
            if err is None:
                # Failed calls (often fast-failing) would corrupt a learned
                # cost model — only successful durations are evidence.  The
                # observation goes to the model that made the decision: the
                # lane's own under a policy, the global strategy otherwise.
                self._observe(lane_key, len(picked), time.perf_counter() - t0)

            with self._lock:
                for i, entry in enumerate(picked):
                    rk = self._req_key(entry.query_name, entry.params)
                    if rk is not None and self._inflight_by_req.get(rk) is entry:
                        del self._inflight_by_req[rk]
                    if err is None and rk is not None and self._cache_size:
                        self._cache_put_locked(rk, out[i])
                    # Fan the result out to every coalesced handle; straggler
                    # duplicates may already be resolved — first result wins.
                    for key in entry.keys:
                        if key in self._results or key in self._errors:
                            continue
                        if err is not None:
                            self._errors[key] = err
                            self._projections.pop(key, None)
                        else:
                            self._deliver_locked(
                                key, out[i], self._projections.pop(key, None)
                            )
                        self.stats.completed += 1
                        self._inflight_params.pop(key, None)
                        self._release_outstanding_locked(key)
                self._done_cv.notify_all()
