"""LoopContextTable — Rule A's ``Table t`` (§3.2) and §5.1's blocking queue.

Two modes:

* ``blocking=False`` — the basic Rule A context table: an ordered store the
  producer fills completely before the consumer iterates (``for each r in t
  order by t.key``).
* ``blocking=True`` — the §5.1 overlap variant: a bounded blocking
  producer/consumer queue.  The producer thread ``put``s records; the
  consumer iterates as records arrive; ``close()`` marks the end.  A bounded
  ``maxsize`` implements the paper's §8 memory-overhead mitigation (the
  producer backs off while results are consumed and memory freed).
"""
from __future__ import annotations

import queue as _queue
import threading
from typing import Any, Iterator, Optional

__all__ = ["LoopContextTable"]

_CLOSED = object()


class LoopContextTable:
    def __init__(self, blocking: bool = False, maxsize: Optional[int] = None):
        self.blocking = blocking
        if blocking:
            self._q: _queue.Queue = _queue.Queue(maxsize=maxsize or 0)
        else:
            self._items: list[Any] = []
        self._closed = False
        self._key = 0
        self._lock = threading.Lock()

    # -- producer side --------------------------------------------------------
    def put(self, record: Any) -> int:
        """Append a record; returns its loop key (``r.key = loopkey++``)."""
        with self._lock:
            if self._closed and not self.blocking:
                raise RuntimeError("LoopContextTable is closed")
            key = self._key
            self._key += 1
        if self.blocking:
            self._q.put((key, record))
        else:
            self._items.append((key, record))
        return key

    def close(self) -> None:
        self._closed = True
        if self.blocking:
            self._q.put(_CLOSED)

    def __len__(self) -> int:
        with self._lock:
            return self._key

    # -- consumer side --------------------------------------------------------
    def __iter__(self) -> Iterator[Any]:
        """Yield records in key order (``order by t.key``)."""
        if self.blocking:
            while True:
                item = self._q.get()
                if item is _CLOSED:
                    return
                _key, record = item
                yield record
        else:
            if not self._closed:
                raise RuntimeError(
                    "non-blocking LoopContextTable iterated before close(); "
                    "the basic Rule A consumer must start after the producer"
                )
            for _key, record in sorted(self._items, key=lambda kr: kr[0]):
                yield record

    def delete(self) -> None:
        """``delete t;`` — free the table (Rule A's last statement)."""
        if self.blocking:
            try:
                while True:
                    self._q.get_nowait()
            except _queue.Empty:
                pass
        else:
            self._items.clear()
