"""The ``async_query`` tagging primitive and QuerySpec registry.

This is the device-level analogue of the paper's ``executeQuery`` call: a
*query* is a parameterized, per-iteration data access (embedding gather,
KV fetch, remote parameter fetch, ...) that the loop-fission transformation
(Rule A, :mod:`repro.core.fission`) can pull out of a ``lax.scan`` and
execute in *batched* (set-oriented) form.

A model tags such an access by calling :func:`async_query` with a registered
:class:`QuerySpec`.  Untransformed programs behave exactly as if the query
were executed inline (the primitive's impl/lowering simply call
``spec.execute``), so tagging is semantically a no-op — precisely like the
paper's blocking ``executeQuery`` before transformation.  The fission pass
recognizes the primitive inside a scanned loop body, checks the Rule A
preconditions on the jaxpr data-dependence graph, and replaces the N
per-iteration executions with one call to ``spec.execute_batch``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import tree_util
from jax.extend import core as jex_core
from jax.interpreters import ad, batching, mlir

__all__ = [
    "QuerySpec",
    "register_query",
    "get_query_spec",
    "async_query",
    "async_query_p",
    "table_gather_spec",
    "sharded_param_fetch_spec",
]


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """Describes one batchable query type.

    Attributes:
      name: unique registry key.
      execute: the single-request (blocking) form, ``execute(*args)``.
        Must be a pure JAX function of its array arguments.
      execute_batch: the set-oriented form.  Receives every argument with a
        leading *batch* (loop-iteration) axis and must return the result
        with the same leading axis.  ``None`` falls back to
        ``jax.vmap(execute)`` — correct but without set-oriented savings.
      batch_axis_size_hint: optional static hint used by cost models.
    """

    name: str
    execute: Callable
    execute_batch: Optional[Callable] = None
    batch_axis_size_hint: Optional[int] = None

    def batched(self) -> Callable:
        if self.execute_batch is not None:
            return partial(self.execute_batch, batched=None)
        return jax.vmap(self.execute)


_REGISTRY: dict[str, QuerySpec] = {}


def register_query(spec: QuerySpec) -> QuerySpec:
    """Idempotently register ``spec`` under ``spec.name``."""
    existing = _REGISTRY.get(spec.name)
    if existing is not None and existing is not spec:
        # Re-registration with an identical definition is allowed (module
        # reloads in tests); silently replace.
        pass
    _REGISTRY[spec.name] = spec
    return spec


def get_query_spec(name: str) -> QuerySpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"No QuerySpec registered under {name!r}; call register_query first."
        ) from None


# ---------------------------------------------------------------------------
# The primitive.
#
# ``async_query_p`` is a real JAX primitive so that (a) it shows up as a
# single recognizable equation in the jaxpr (the analogue of the paper's
# query-execution *statement*), and (b) untransformed programs still trace,
# differentiate, vmap and lower correctly.
# ---------------------------------------------------------------------------

async_query_p = jex_core.Primitive("async_query")
async_query_p.multiple_results = True


def async_query(spec: QuerySpec | str, *args):
    """Tag a query execution point (paper: ``v = executeQuery(q)``).

    Semantically identical to ``spec.execute(*args)``.  Inside a loop that is
    later fissioned (Rule A) the execution is replaced by a single
    set-oriented ``spec.execute_batch`` call.
    """
    if isinstance(spec, QuerySpec):
        register_query(spec)
        name = spec.name
    else:
        name = spec
        spec = get_query_spec(name)
    flat_args, in_tree = tree_util.tree_flatten(args)
    out = async_query_p.bind(*flat_args, name=name, in_tree=in_tree)
    _, out_tree = _out_trees(spec, args)
    return tree_util.tree_unflatten(out_tree, out)


def _out_trees(spec: QuerySpec, args):
    """Abstractly evaluate ``spec.execute`` to get the output pytree."""
    shapes = jax.eval_shape(spec.execute, *args)
    flat, tree = tree_util.tree_flatten(shapes)
    return flat, tree


def _abstract_eval(*in_avals, name, in_tree):
    spec = get_query_spec(name)
    args = tree_util.tree_unflatten(
        in_tree, [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in in_avals]
    )
    out_shapes = jax.eval_shape(spec.execute, *args)
    flat, _ = tree_util.tree_flatten(out_shapes)
    return [jax.core.ShapedArray(s.shape, s.dtype) for s in flat]


async_query_p.def_abstract_eval(_abstract_eval)


def _run_execute(name, in_tree, *flat_args):
    spec = get_query_spec(name)
    args = tree_util.tree_unflatten(in_tree, list(flat_args))
    out = spec.execute(*args)
    flat, _ = tree_util.tree_flatten(out)
    return flat


def _impl(*flat_args, name, in_tree):
    return _run_execute(name, in_tree, *flat_args)


async_query_p.def_impl(_impl)

mlir.register_lowering(
    async_query_p,
    mlir.lower_fun(_impl, multiple_results=True),
)


def _jvp_rule(primals, tangents, *, name, in_tree):
    import numpy as np
    from jax import dtypes as _dtypes

    fn = partial(_run_execute, name, in_tree)

    def _zero_tan(p, t):
        if not isinstance(t, ad.Zero):
            return t
        aval = jax.core.get_aval(p)
        if jnp.issubdtype(aval.dtype, jnp.inexact):
            return jnp.zeros(aval.shape, aval.dtype)
        return np.zeros(aval.shape, _dtypes.float0)  # int/bool primals

    tangents = [_zero_tan(p, t) for p, t in zip(primals, tangents)]
    return jax.jvp(fn, tuple(primals), tuple(tangents))


ad.primitive_jvps[async_query_p] = _jvp_rule


def _batch_rule(batched_args, batch_dims, *, name, in_tree):
    spec = get_query_spec(name)
    # Move every batched arg's batch axis to the front; broadcast the rest.
    size = None
    for a, d in zip(batched_args, batch_dims):
        if d is not batching.not_mapped:
            size = a.shape[d]
            break
    assert size is not None
    moved = []
    for a, d in zip(batched_args, batch_dims):
        if d is batching.not_mapped:
            moved.append(jnp.broadcast_to(a, (size,) + a.shape))
        else:
            moved.append(jnp.moveaxis(a, d, 0))
    args = tree_util.tree_unflatten(in_tree, moved)
    out = spec.batched()(*args)
    flat, _ = tree_util.tree_flatten(out)
    return flat, [0] * len(flat)


batching.primitive_batchers[async_query_p] = _batch_rule


# ---------------------------------------------------------------------------
# Built-in query specs
# ---------------------------------------------------------------------------


def _table_gather(table, ids):
    """Single query: select rows of ``table`` by integer key(s)."""
    return jnp.take(table, ids, axis=0)


def _table_gather_batch(table, ids, *, batched=None):
    """Set-oriented form: ONE gather over all iterations' keys.

    Fission's calling convention: loop-invariant arguments (the table)
    arrive *unstacked*, varying arguments (the ids) arrive with a leading
    loop axis; ``batched`` is the per-leaf mask.  The whole batch becomes a
    single flat gather — the device analogue of the paper's rewritten
    set-oriented query: on TPU, one large DMA-friendly gather instead of N
    scalar-driven small ones inside a sequential scan.
    """
    if batched is not None and batched[0]:
        # Degenerate case: a varying table (one per iteration); vmap it.
        return jax.vmap(_table_gather)(table, ids)
    flat = ids.reshape(-1)
    rows = jnp.take(table, flat, axis=0)
    return rows.reshape(ids.shape + table.shape[1:])


table_gather_spec = register_query(
    QuerySpec(
        name="table_gather",
        execute=_table_gather,
        execute_batch=_table_gather_batch,
    )
)


def _sharded_param_fetch(param_shard, _token):
    """Single query: fetch one (sharded) parameter — stands for the remote
    parameter/KV fetch; the batched form coalesces N fetches into one."""
    return param_shard


def _sharded_param_fetch_batch(param_shard, _tokens, *, batched=None):
    return param_shard


sharded_param_fetch_spec = register_query(
    QuerySpec(
        name="sharded_param_fetch",
        execute=_sharded_param_fetch,
        execute_batch=_sharded_param_fetch_batch,
    )
)
