"""Device-level Rule A: fission of ``lax.scan`` loops at ``async_query`` calls.

``fission_scan(f, init, xs)`` is a drop-in replacement for
``jax.lax.scan(f, init, xs)``.  If the body contains :func:`async_query`
equations, the loop is split — exactly the paper's Rule A, transposed to the
SSA world of jaxprs:

    original:   scan over N iterations, each issuing one small query
    rewritten:  producer scan  (ss1: everything the query's inputs need;
                                stacks query arguments + split variables
                                into the *loop context table* = scan ys)
                one batched query execution (``spec.execute_batch`` — the
                                set-oriented form: ONE gather / ONE collective
                                / ONE device dispatch instead of N)
                consumer scan  (ss2: everything dependent on query results)

Correspondences with the paper, and what SSA buys us:

* **Split variables / loop context table** (Rule A items 1–3): any value the
  producer computes that the consumer needs is emitted as a stacked scan
  output.  The capture/restore pair is just def/use of an SSA value — no
  conditional-null handling needed.
* **Anti/output dependencies**: cannot occur inside a jaxpr (pure SSA), so
  the paper's relaxation of [1]'s preconditions (allowing LC anti/output
  deps to cross) is automatic here.
* **Statement reordering** ([4]): jaxpr equations are scheduled by data
  dependence only, so the partition {not-downstream-of-query} /
  {downstream} *is* the reordered program; Example 4/5's reordering needs no
  separate pass.
* **Precondition (a)**: a carry position produced on the consumer side and
  read on the producer side is a loop-carried flow dependence across the
  split → :class:`FissionPreconditionError` (the query result feeds later
  submissions; asynchrony is impossible, as in the paper).
* **Precondition (b)** (external deps): jaxprs are pure; equations carrying
  JAX *effects* (io_callback etc.) are rejected conservatively.
* **Rule B**: on SPMD hardware, predication is native.  Conditional queries
  are expressed with masks (``jnp.where`` on arguments, select on results);
  ``lax.cond`` around a query does not appear inside vectorized loop bodies.
* **Nested loops** (§3.4): an inner fissioned scan is a plain sequence of
  equations in the outer body; applying :func:`fission_scan` bottom-up gives
  the nested-table construction.
* **Multiple queries** (§3.2 "any number ... by repeatedly applying"): the
  consumer side is itself fissioned recursively.

Why this is the TPU-native adaptation (not a port): the paper's cost model —
per-request round trips and random IO amortized by set-oriented execution —
maps to per-iteration DMA descriptors and scalar-driven gathers amortized by
one large gather/collective.  XLA will *not* do this rewrite itself: it never
splits a ``scan`` carrying a gather into a hoisted batched gather plus a
consumer scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
from jax import lax, tree_util
from jax.extend import core as jex_core

from repro.core.ddg import FissionPreconditionError, ScanBodyDDG
from repro.core.query import async_query_p, get_query_spec

__all__ = [
    "fission_scan",
    "scan_with_queries",
    "FissionPreconditionError",
    "FissionReport",
    "count_queries",
]


@dataclasses.dataclass
class FissionReport:
    """What happened — for the applicability table and tests."""

    n_queries_found: int = 0
    n_queries_batched: int = 0
    batched_specs: list = dataclasses.field(default_factory=list)
    failures: list = dataclasses.field(default_factory=list)


def _is_literal(v) -> bool:
    return isinstance(v, jex_core.Literal)


def _first_slice(x):
    if hasattr(x, "shape"):
        return jax.ShapeDtypeStruct(x.shape[1:], x.dtype) if isinstance(
            x, jax.ShapeDtypeStruct
        ) else x[0]
    return x


def count_queries(f: Callable, init, xs) -> int:
    x0 = tree_util.tree_map(_first_slice, xs)
    closed = jax.make_jaxpr(f)(init, x0)
    return sum(1 for e in closed.jaxpr.eqns if e.primitive is async_query_p)


def fission_scan(
    f: Callable,
    init,
    xs,
    length: Optional[int] = None,
    *,
    report: Optional[FissionReport] = None,
    _depth: int = 0,
):
    """``lax.scan`` with Rule A applied at every ``async_query`` call.

    Falls back to plain ``lax.scan`` when the body has no queries.  Raises
    :class:`FissionPreconditionError` when a query lies on a true-dependence
    cycle (its submission needs a previous iteration's result).
    """
    if _depth > 8:
        raise RecursionError("fission_scan: too many chained queries")

    # ---- trace the body ------------------------------------------------
    x0 = tree_util.tree_map(_first_slice, xs)
    closed = jax.make_jaxpr(f)(init, x0)
    jaxpr, consts = closed.jaxpr, closed.consts
    out_shapes = jax.eval_shape(f, init, x0)
    (carry_shapes, y_shapes) = out_shapes
    _, out_tree = tree_util.tree_flatten(out_shapes)

    flat_init, carry_tree = tree_util.tree_flatten(init)
    flat_xs, xs_tree = tree_util.tree_flatten(xs)
    n_carry = len(flat_init)

    q_idxs = [i for i, e in enumerate(jaxpr.eqns) if e.primitive is async_query_p]
    if not q_idxs:
        return lax.scan(f, init, xs, length=length)
    if report is not None and _depth == 0:
        report.n_queries_found = _count_queries_jaxpr(jaxpr)

    # Effects are external state — precondition (b), conservative.
    for e in jaxpr.eqns:
        if e.effects:
            raise FissionPreconditionError(
                f"effectful equation {e.primitive.name} in loop body: external "
                f"anti/output dependence may cross the split (Rule A "
                f"precondition (b)); fission refused."
            )

    ddg = ScanBodyDDG(jaxpr, n_carry)
    qi = q_idxs[0]
    # Split at the FIRST query.  Everything downstream of it is ``ss2``; any
    # *later* query (even if independent) also moves to the consumer side so
    # the repeated application of Rule A (§3.2) batches it in turn.
    consumer_eqns: set[int] = set()
    for j in q_idxs:
        consumer_eqns |= ddg.downstream(j)

    # Statement reordering, SSA style ([4]'s reordering algorithm): an
    # equation that reads the previous-iteration value of a *consumer*-side
    # carry (e.g. an accumulator update chain) must itself move to the
    # consumer side — unless the query's own inputs flow through it, in
    # which case the query sits on a true-dependence cycle and Rule A is
    # inapplicable.  Iterate to a fixed point (the consumer set only grows).
    must_stay_producer = ddg.upstream_of_vars(ddg.eqn_reads(qi)) | {qi}
    while True:
        producer_pos, consumer_pos = ddg.classify_carry(consumer_eqns)
        consumer_carry_in_vars = {ddg.carry_in[j] for j in consumer_pos}
        moved = False
        for i in range(len(jaxpr.eqns)):
            if i in consumer_eqns:
                continue
            if ddg.eqn_reads(i) & consumer_carry_in_vars:
                if i in must_stay_producer:
                    raise FissionPreconditionError(
                        "query inputs depend (across iterations) on values "
                        "produced by the query's own consumers — true-"
                        "dependence cycle; Rule A inapplicable (paper §4.1)."
                    )
                consumer_eqns |= ddg.downstream(i)
                moved = True
        if not moved:
            break
    producer_eqns = [i for i in range(len(jaxpr.eqns)) if i not in consumer_eqns]
    ddg.check_split(qi, consumer_eqns, consumer_pos)

    q_eqn = jaxpr.eqns[qi]
    spec = get_query_spec(q_eqn.params["name"])

    # ---- variable classification ---------------------------------------
    const_env = dict(zip(jaxpr.constvars, consts))
    carry_in_vars = list(jaxpr.invars[:n_carry])
    x_vars = list(jaxpr.invars[n_carry:])
    carry_out_vars = list(jaxpr.outvars[:n_carry])
    y_out_vars = list(jaxpr.outvars[n_carry:])
    x_var_pos = {v: i for i, v in enumerate(x_vars)}
    carry_in_pos = {v: j for j, v in enumerate(carry_in_vars)}

    consumer_eqn_list = [i for i in sorted(consumer_eqns) if i != qi]
    consumer_reads = ddg.side_reads(consumer_eqn_list)
    q_outvars = [v for v in q_eqn.outvars]

    def _side_of_var(v) -> str:
        """Where is var v available? 'const' | 'x' | 'pcarry' | 'ccarry' |
        'prod' | 'cons' | 'query'."""
        if v in const_env:
            return "const"
        if v in x_var_pos:
            return "x"
        if v in carry_in_pos:
            return "ccarry" if carry_in_pos[v] in consumer_pos else "pcarry"
        d = ddg.def_site.get(v)
        if d == qi:
            return "query"
        if d in consumer_eqns:
            return "cons"
        return "prod"

    # Context table: values the consumer needs from the producer side.
    ctx_vars: list = []
    seen_ctx = set()

    def _need_ctx(v):
        if v in seen_ctx or _is_literal(v):
            return
        side = _side_of_var(v)
        if side in ("prod", "pcarry"):
            seen_ctx.add(v)
            ctx_vars.append(v)

    for v in sorted(consumer_reads, key=lambda v: str(v)):
        _need_ctx(v)

    # y outputs: which side emits each?
    consumer_y_pos: list[int] = []
    producer_y_pos: list[int] = []
    for k, v in enumerate(y_out_vars):
        side = "prod" if _is_literal(v) else _side_of_var(v)
        if side in ("cons", "query", "ccarry"):
            consumer_y_pos.append(k)
        else:
            producer_y_pos.append(k)

    # x components the consumer reads directly (pass original xs through —
    # no double stacking).
    consumer_x_pos = sorted(
        {x_var_pos[v] for v in consumer_reads if v in x_var_pos}
        | {
            x_var_pos[v]
            for v in y_out_vars
            if v in x_var_pos and y_out_vars.index(v) in consumer_y_pos
        }
    )

    # Query arguments: stacked (varying) vs invariant.
    q_arg_plan: list[tuple[str, Any]] = []  # (kind, payload)
    for v in q_eqn.invars:
        if _is_literal(v):
            q_arg_plan.append(("lit", v.val))
            continue
        side = _side_of_var(v)
        if side == "const":
            q_arg_plan.append(("const", const_env[v]))
        elif side == "x":
            q_arg_plan.append(("xs", x_var_pos[v]))
        elif side in ("prod", "pcarry"):
            if v not in seen_ctx:
                seen_ctx.add(v)
                ctx_vars.append(v)
            q_arg_plan.append(("ctx", v))
        else:  # 'cons'/'query'/'ccarry' → cycle; check_split already raised
            raise FissionPreconditionError(
                "query argument produced on the consumer side"
            )

    ctx_index = {v: i for i, v in enumerate(ctx_vars)}

    # ---- evaluation helper ----------------------------------------------
    def _eval_eqns(eqn_idxs: Sequence[int], env: dict) -> None:
        def read(v):
            if _is_literal(v):
                return v.val
            return env[v]

        for i in eqn_idxs:
            eqn = jaxpr.eqns[i]
            subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
            ans = eqn.primitive.bind(
                *subfuns, *(read(v) for v in eqn.invars), **bind_params
            )
            outs = ans if eqn.primitive.multiple_results else [ans]
            for ov, val in zip(eqn.outvars, outs):
                env[ov] = val

    def _read_out(env, v):
        if _is_literal(v):
            return v.val
        return env[v]

    producer_pos_list = sorted(producer_pos)
    consumer_pos_list = sorted(consumer_pos)

    # ---- producer scan ----------------------------------------------------
    def producer_body(carry_p, x_flat):
        env = dict(const_env)
        for idx, j in enumerate(producer_pos_list):
            env[carry_in_vars[j]] = carry_p[idx]
        for i, v in enumerate(x_vars):
            env[v] = x_flat[i]
        _eval_eqns(producer_eqns, env)
        new_carry = tuple(_read_out(env, carry_out_vars[j]) for j in producer_pos_list)
        ctx = tuple(env[v] for v in ctx_vars)
        ys_p = tuple(_read_out(env, y_out_vars[k]) for k in producer_y_pos)
        return new_carry, (ctx, ys_p)

    carry_p_init = tuple(flat_init[j] for j in producer_pos_list)
    xs_flat_tuple = tuple(flat_xs)
    carry_p_final, (ctx_stacked, ys_p_stacked) = lax.scan(
        producer_body, carry_p_init, xs_flat_tuple, length=length
    )

    # ---- ONE batched query execution (the set-oriented form) --------------
    flat_args = []
    batched_mask = []
    for kind, payload in q_arg_plan:
        if kind in ("lit", "const"):
            flat_args.append(payload)
            batched_mask.append(False)
        elif kind == "xs":
            flat_args.append(flat_xs[payload])
            batched_mask.append(True)
        else:  # ctx
            flat_args.append(ctx_stacked[ctx_index[payload]])
            batched_mask.append(True)
    args = tree_util.tree_unflatten(q_eqn.params["in_tree"], flat_args)
    mask_tree = tree_util.tree_unflatten(q_eqn.params["in_tree"], batched_mask)
    if spec.execute_batch is not None:
        out = spec.execute_batch(*args, batched=tree_util.tree_leaves(mask_tree))
    else:
        in_axes = tree_util.tree_map(lambda b: 0 if b else None, mask_tree)
        out = jax.vmap(spec.execute, in_axes=tuple(in_axes))(*args)
    q_res_flat, _ = tree_util.tree_flatten(out)
    if report is not None:
        report.n_queries_batched += 1
        report.batched_specs.append(spec.name)

    # ---- consumer scan -----------------------------------------------------
    consumer_xs = (
        tuple(q_res_flat),
        tuple(ctx_stacked[ctx_index[v]] for v in ctx_vars),
        tuple(flat_xs[i] for i in consumer_x_pos),
    )
    carry_c_init = tuple(flat_init[j] for j in consumer_pos_list)

    def consumer_body(carry_c, per_iter):
        qres, ctx_slice, x_slice = per_iter
        env = dict(const_env)
        for idx, j in enumerate(consumer_pos_list):
            env[carry_in_vars[j]] = carry_c[idx]
        for v, val in zip(ctx_vars, ctx_slice):
            env[v] = val
        for i, xi in zip(consumer_x_pos, x_slice):
            env[x_vars[i]] = xi
        for ov, val in zip(q_outvars, qres):
            env[ov] = val
        _eval_eqns(consumer_eqn_list, env)
        new_carry = tuple(_read_out(env, carry_out_vars[j]) for j in consumer_pos_list)
        ys_c = tuple(_read_out(env, y_out_vars[k]) for k in consumer_y_pos)
        return new_carry, ys_c

    # Recurse if more queries remain on the consumer side (§3.2: repeated
    # application).
    remaining = [i for i in consumer_eqn_list if jaxpr.eqns[i].primitive is async_query_p]
    if remaining:
        carry_c_final, ys_c_stacked = fission_scan(
            consumer_body,
            carry_c_init,
            consumer_xs,
            report=report,
            _depth=_depth + 1,
        )
    else:
        carry_c_final, ys_c_stacked = lax.scan(
            consumer_body, carry_c_init, consumer_xs, length=length
        )

    # ---- reassemble ---------------------------------------------------------
    flat_carry_final: list = [None] * n_carry
    for idx, j in enumerate(producer_pos_list):
        flat_carry_final[j] = carry_p_final[idx]
    for idx, j in enumerate(consumer_pos_list):
        flat_carry_final[j] = carry_c_final[idx]

    flat_ys: list = [None] * len(y_out_vars)
    for idx, k in enumerate(producer_y_pos):
        flat_ys[k] = ys_p_stacked[idx]
    for idx, k in enumerate(consumer_y_pos):
        flat_ys[k] = ys_c_stacked[idx]

    return tree_util.tree_unflatten(out_tree, flat_carry_final + flat_ys)


def _count_queries_jaxpr(jaxpr) -> int:
    n = 0
    for e in jaxpr.eqns:
        if e.primitive is async_query_p:
            n += 1
        for sub in jax.core.jaxprs_in_params(e.params) if hasattr(
            jax.core, "jaxprs_in_params"
        ) else []:
            n += _count_queries_jaxpr(sub)
    return n


def scan_with_queries(f: Callable, init, xs, *, fission: bool = True, length=None):
    """Config-switchable entry point: the *same* model code runs either the
    paper-faithful per-iteration form (``fission=False`` — the baseline) or
    the fissioned batched form (``fission=True``)."""
    if fission:
        return fission_scan(f, init, xs, length=length)
    return lax.scan(f, init, xs, length=length)
