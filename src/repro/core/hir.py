"""Host-level IR (HIR) — the paper's source-to-source transformer.

The paper (Ramachandra et al., "Program Transformations for Asynchronous and
Batched Query Submission") rewrites Java/JDBC programs.  Our host-level IR is
the language-neutral core of that tool: a tiny imperative language of
statements with explicit read/write sets, over which we implement

  * the **data dependence graph** (§3.1): flow / anti / output dependencies
    and their loop-carried variants, plus *external* dependencies through a
    shared service (the "database"),
  * **Rule B** (§3.3): control-dependence → flow-dependence conversion by
    predication (guard variables),
  * **statement reordering** ([4] §"Applicability"): dependence-preserving
    topological reordering that moves the query and its dependents apart so
    the Rule A precondition holds,
  * **Rule A** (§3.2): loop fission at a query statement into a *producer*
    loop (asynchronous ``submit``) and a *consumer* loop (blocking ``fetch``),
    communicating through a **loop context table**,
  * **nested-loop fission** (§3.4), and
  * the **applicability analysis** of §6.2 (Table 1).

Programs in this IR are *executable*: :class:`Interpreter` runs them against
a :class:`~repro.core.services.QueryService`, so every transformation can be
property-tested for semantic equivalence (transformed(program) ≡ program).

The IR deliberately mirrors the paper's presentation:

  ``v = executeQuery(q)``  →  :class:`Query` statement
  ``ss1; s; ss2``          →  :class:`Loop` body (list of statements)
  guard variables          →  ``Assign.guard`` (Rule B predication)

Expressions are Python callables over an environment dict; read/write sets
are declared explicitly (exactly the information SOOT/Jimple dataflow gives
the paper's tool).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Mapping, Optional, Sequence

__all__ = [
    "Stmt",
    "Assign",
    "Query",
    "If",
    "Loop",
    "Program",
    "DepKind",
    "DepEdge",
    "DataDependenceGraph",
    "build_ddg",
    "apply_rule_b",
    "reorder_for_fission",
    "FissionError",
    "apply_rule_a",
    "fission_loop",
    "transform_program",
    "analyze_applicability",
    "Interpreter",
]


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Stmt:
    """Base statement.  ``guard`` is the Rule B predication variable: when
    set, the statement only executes if ``env[guard]`` is truthy (negated if
    ``guard_negated``)."""

    guard: Optional[str] = dataclasses.field(default=None, kw_only=True)
    guard_negated: bool = dataclasses.field(default=False, kw_only=True)

    # --- dataflow interface -------------------------------------------------
    def reads(self) -> frozenset[str]:
        raise NotImplementedError

    def writes(self) -> frozenset[str]:
        raise NotImplementedError

    def external_reads(self) -> bool:
        """True if the statement reads external state (the database)."""
        return False

    def external_writes(self) -> bool:
        """True if the statement writes external state (the database)."""
        return False

    def _guard_reads(self) -> frozenset[str]:
        return frozenset([self.guard]) if self.guard else frozenset()

    def with_guard(self, guard: str, negated: bool = False) -> "Stmt":
        new = dataclasses.replace(self)
        if new.guard is not None:
            raise ValueError(
                "nested guards unsupported; apply Rule B innermost-first "
                "(the paper groups guards back in a readability pass)"
            )
        new.guard = guard
        new.guard_negated = negated
        return new


@dataclasses.dataclass
class Assign(Stmt):
    """``target = fn(*[env[v] for v in args])``.

    ``effect`` marks external writes (e.g. ``log``/``print``/DB update —
    §3.1 "External data dependencies"); such statements are modelled
    conservatively as writing the external resource named by ``effect``.
    """

    target: Optional[str] = None
    fn: Callable[..., Any] = None  # type: ignore[assignment]
    args: tuple[str, ...] = ()
    effect: Optional[str] = None

    def reads(self) -> frozenset[str]:
        return frozenset(self.args) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def external_writes(self) -> bool:
        return self.effect is not None

    def __repr__(self) -> str:  # readable transformed programs (§4.1 goal 1)
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        t = f"{self.target} = " if self.target else ""
        return f"{g}{t}{getattr(self.fn, '__name__', 'fn')}({', '.join(self.args)})"


@dataclasses.dataclass
class Query(Stmt):
    """``target = executeQuery(query_name, params...)`` — the blocking call.

    ``updates_db`` marks data-modifying statements (INSERT/UPDATE): they are
    external writes, any query is an external read (§3.1, §8 "update
    transactions" — conservative model).
    """

    target: Optional[str] = None
    query_name: str = ""
    params: tuple[str, ...] = ()
    updates_db: bool = False

    def reads(self) -> frozenset[str]:
        return frozenset(self.params) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def external_reads(self) -> bool:
        return True

    def external_writes(self) -> bool:
        return self.updates_db

    def __repr__(self) -> str:
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        return (
            f"{g}{self.target} = executeQuery({self.query_name!r}, "
            f"{', '.join(self.params)})"
        )


@dataclasses.dataclass
class _Submit(Stmt):
    """``handle = submitQuery(...)`` — produced by Rule A, non-blocking."""

    target: Optional[str] = None
    query_name: str = ""
    params: tuple[str, ...] = ()

    def reads(self) -> frozenset[str]:
        return frozenset(self.params) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def external_reads(self) -> bool:
        return True

    def __repr__(self) -> str:
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        return (
            f"{g}{self.target} = submitQuery({self.query_name!r}, "
            f"{', '.join(self.params)})"
        )


@dataclasses.dataclass
class _Fetch(Stmt):
    """``v = fetchResult(handle)`` — produced by Rule A, blocking."""

    target: Optional[str] = None
    handle: str = ""

    def reads(self) -> frozenset[str]:
        return frozenset([self.handle]) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def __repr__(self) -> str:
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        return f"{g}{self.target} = fetchResult({self.handle})"


@dataclasses.dataclass
class If(Stmt):
    """``if (pred_var) { then_body } else { else_body }`` (§3.3)."""

    pred: str = ""
    then_body: list[Stmt] = dataclasses.field(default_factory=list)
    else_body: list[Stmt] = dataclasses.field(default_factory=list)

    def reads(self) -> frozenset[str]:
        r = frozenset([self.pred]) | self._guard_reads()
        for s in itertools.chain(self.then_body, self.else_body):
            r |= s.reads()
        return r

    def writes(self) -> frozenset[str]:
        w: frozenset[str] = frozenset()
        for s in itertools.chain(self.then_body, self.else_body):
            w |= s.writes()
        return w

    def external_reads(self) -> bool:
        return any(
            s.external_reads() for s in itertools.chain(self.then_body, self.else_body)
        )

    def external_writes(self) -> bool:
        return any(
            s.external_writes()
            for s in itertools.chain(self.then_body, self.else_body)
        )

    def __repr__(self) -> str:
        return f"if ({self.pred}) {{ {len(self.then_body)} stmts }} else {{ {len(self.else_body)} stmts }}"


@dataclasses.dataclass
class Loop(Stmt):
    """``for item_var in env[iter_var]: body`` — the paper's generic loop.

    The paper presents Rule A for ``while`` loops; our executable form is the
    for-each loop (the paper's own second loop in Rule A's RHS is exactly
    this).  ``while`` loops whose predicate is updated by the body are
    expressible by reordering (Example 4/5) and covered in tests via an
    explicit counter idiom.
    """

    item_var: str = ""
    iter_var: str = ""
    body: list[Stmt] = dataclasses.field(default_factory=list)

    def reads(self) -> frozenset[str]:
        r = frozenset([self.iter_var]) | self._guard_reads()
        for s in self.body:
            r |= s.reads()
        return r - frozenset([self.item_var])

    def writes(self) -> frozenset[str]:
        w: frozenset[str] = frozenset()
        for s in self.body:
            w |= s.writes()
        return w

    def external_reads(self) -> bool:
        return any(s.external_reads() for s in self.body)

    def external_writes(self) -> bool:
        return any(s.external_writes() for s in self.body)

    def __repr__(self) -> str:
        return f"for {self.item_var} in {self.iter_var}: {{ {len(self.body)} stmts }}"


@dataclasses.dataclass
class _ProducerConsumer(Stmt):
    """Result of Rule A: producer loop + consumer loop over a context table.

    Executed by the interpreter either sequentially (basic Rule A) or with
    the producer in its own thread over a blocking queue (§5.1 overlap,
    ``overlap=True``).
    """

    producer: Loop = None  # type: ignore[assignment]
    consumer_body: list[Stmt] = dataclasses.field(default_factory=list)
    table_var: str = ""
    record_var: str = ""
    split_vars: tuple[str, ...] = ()
    overlap: bool = False

    def reads(self) -> frozenset[str]:
        r = self.producer.reads()
        for s in self.consumer_body:
            r |= s.reads()
        return r - frozenset(self.split_vars) - frozenset([self.table_var, self.record_var])

    def writes(self) -> frozenset[str]:
        w = self.producer.writes()
        for s in self.consumer_body:
            w |= s.writes()
        return w

    def external_reads(self) -> bool:
        return True

    def __repr__(self) -> str:
        mode = "overlap" if self.overlap else "two-phase"
        return (
            f"fissioned[{mode}](producer={self.producer!r}, "
            f"consumer={{ {len(self.consumer_body)} stmts }})"
        )


@dataclasses.dataclass
class Program:
    """A statement sequence + the set of input variables."""

    body: list[Stmt]
    inputs: tuple[str, ...] = ()

    def __repr__(self) -> str:
        return "\n".join(repr(s) for s in self.body)


# ---------------------------------------------------------------------------
# Data dependence graph (§3.1)
# ---------------------------------------------------------------------------


class DepKind(enum.Enum):
    FLOW = "FD"
    ANTI = "AD"
    OUTPUT = "OD"
    LOOP_FLOW = "LFD"
    LOOP_ANTI = "LAD"
    LOOP_OUTPUT = "LOD"
    EXT_FLOW = "xFD"
    EXT_ANTI = "xAD"
    EXT_OUTPUT = "xOD"
    EXT_LOOP_FLOW = "xLFD"
    EXT_LOOP_ANTI = "xLAD"
    EXT_LOOP_OUTPUT = "xLOD"

    @property
    def loop_carried(self) -> bool:
        return self in (
            DepKind.LOOP_FLOW,
            DepKind.LOOP_ANTI,
            DepKind.LOOP_OUTPUT,
            DepKind.EXT_LOOP_FLOW,
            DepKind.EXT_LOOP_ANTI,
            DepKind.EXT_LOOP_OUTPUT,
        )

    @property
    def external(self) -> bool:
        return self.value.startswith("x")

    @property
    def flow(self) -> bool:
        return self in (
            DepKind.FLOW,
            DepKind.LOOP_FLOW,
            DepKind.EXT_FLOW,
            DepKind.EXT_LOOP_FLOW,
        )


@dataclasses.dataclass(frozen=True)
class DepEdge:
    src: int  # statement index
    dst: int
    kind: DepKind
    var: str  # variable (or external resource) carrying the dependence

    def __repr__(self) -> str:
        return f"s{self.src} --{self.kind.value}[{self.var}]--> s{self.dst}"


@dataclasses.dataclass
class DataDependenceGraph:
    stmts: list[Stmt]
    edges: list[DepEdge]

    def edges_from(self, i: int) -> list[DepEdge]:
        return [e for e in self.edges if e.src == i]

    def edges_to(self, i: int) -> list[DepEdge]:
        return [e for e in self.edges if e.dst == i]

    def intra_edges(self) -> list[DepEdge]:
        return [e for e in self.edges if not e.kind.loop_carried]

    def loop_carried_edges(self) -> list[DepEdge]:
        return [e for e in self.edges if e.kind.loop_carried]


_EXT = "__db__"  # §3.1: model the whole database as one external variable


def build_ddg(body: Sequence[Stmt], loop_body: bool = True) -> DataDependenceGraph:
    """Build the DDG of a statement sequence (Fig. 1 of the paper).

    With ``loop_body=True`` the sequence is treated as the body of a loop and
    loop-carried edges are added: for every (write in s_a, read in s_b) pair
    with a ≥ b order in the *next* iteration, an ``LFD`` edge, etc.  External
    dependencies conservatively route through the single resource ``__db__``
    (every query reads it, every update/effect writes it).
    """
    stmts = list(body)
    edges: list[DepEdge] = []

    def rw(s: Stmt) -> tuple[frozenset[str], frozenset[str]]:
        r, w = s.reads(), s.writes()
        if s.external_reads():
            r = r | {_EXT}
        if s.external_writes():
            w = w | {_EXT}
        return r, w

    rws = [rw(s) for s in stmts]

    # Intra-iteration edges (forward control flow only).
    for a in range(len(stmts)):
        ra, wa = rws[a]
        for b in range(a + 1, len(stmts)):
            rb, wb = rws[b]
            for v in wa & rb:  # a writes, b reads  → flow
                kind = DepKind.EXT_FLOW if v == _EXT else DepKind.FLOW
                edges.append(DepEdge(a, b, kind, v))
            for v in ra & wb:  # a reads, b writes  → anti
                kind = DepKind.EXT_ANTI if v == _EXT else DepKind.ANTI
                edges.append(DepEdge(a, b, kind, v))
            for v in wa & wb:  # both write         → output
                kind = DepKind.EXT_OUTPUT if v == _EXT else DepKind.OUTPUT
                edges.append(DepEdge(a, b, kind, v))

    if loop_body:
        # Loop-carried edges: s_a in iteration t, s_b in iteration t+1, for
        # *all* (a, b) pairs including a >= b (that is what makes them
        # loop-carried).
        for a in range(len(stmts)):
            ra, wa = rws[a]
            for b in range(len(stmts)):
                rb, wb = rws[b]
                for v in wa & rb:
                    kind = DepKind.EXT_LOOP_FLOW if v == _EXT else DepKind.LOOP_FLOW
                    edges.append(DepEdge(a, b, kind, v))
                for v in ra & wb:
                    kind = DepKind.EXT_LOOP_ANTI if v == _EXT else DepKind.LOOP_ANTI
                    edges.append(DepEdge(a, b, kind, v))
                for v in wa & wb:
                    kind = (
                        DepKind.EXT_LOOP_OUTPUT if v == _EXT else DepKind.LOOP_OUTPUT
                    )
                    edges.append(DepEdge(a, b, kind, v))

    return DataDependenceGraph(stmts, edges)


# ---------------------------------------------------------------------------
# Rule B (§3.3): control → flow dependencies
# ---------------------------------------------------------------------------


def apply_rule_b(body: Sequence[Stmt]) -> list[Stmt]:
    """Flatten ``If`` statements into guarded statements (paper Rule B).

    ``if (p) {ss1} else {ss2}`` becomes ``cv = p; [cv] ss1; [!cv] ss2``.
    The predicate is already a variable in our IR, so no fresh assignment is
    needed unless the branch bodies might overwrite it — we always introduce
    the fresh ``cv`` for fidelity with the rule (and safety).
    """
    out: list[Stmt] = []
    fresh = _FreshNames(body)
    for s in body:
        if isinstance(s, If):
            inner_then = apply_rule_b(s.then_body)
            inner_else = apply_rule_b(s.else_body)
            cv = fresh("cv")
            # cv = p  (possibly itself guarded — nested Ifs come pre-flattened
            # by the recursive call, so s.guard is from an outer construct)
            cap = Assign(target=cv, fn=lambda p: bool(p), args=(s.pred,))
            if s.guard is not None:
                cap = cap.with_guard(s.guard, s.guard_negated)
            out.append(cap)
            for t in inner_then:
                out.append(_conjoin_guard(t, cv, False, fresh, out))
            for t in inner_else:
                out.append(_conjoin_guard(t, cv, True, fresh, out))
        else:
            out.append(s)
    return out


def _conjoin_guard(
    s: Stmt, cv: str, negated: bool, fresh: "_FreshNames", out: list[Stmt]
) -> Stmt:
    """Guard ``s`` with ``cv`` (negated as requested), conjoining any
    existing guard through a fresh boolean (guards are single variables)."""
    if s.guard is None:
        return s.with_guard(cv, negated)
    g_old, old_neg = s.guard, s.guard_negated
    conj = fresh("cv")

    def _and(a, b, _n1=old_neg, _n2=negated):
        va = (not a) if _n1 else bool(a)
        vb = (not b) if _n2 else bool(b)
        return va and vb

    _and.__name__ = "and"
    out.append(Assign(target=conj, fn=_and, args=(g_old, cv)))
    t = dataclasses.replace(s)
    t.guard = conj
    t.guard_negated = False
    return t


class _FreshNames:
    def __init__(self, body: Sequence[Stmt]):
        self._used = set()
        for s in body:
            self._used |= s.reads() | s.writes()
        self._n = 0

    def __call__(self, prefix: str) -> str:
        while True:
            name = f"{prefix}_{self._n}"
            self._n += 1
            if name not in self._used:
                self._used.add(name)
                return name


# ---------------------------------------------------------------------------
# Statement reordering ([4]) — enable Rule A when LC flow deps cross the split
# ---------------------------------------------------------------------------


class FissionError(ValueError):
    """Raised when the Rule A preconditions cannot be satisfied."""


def _find_query(body: Sequence[Stmt]) -> Optional[int]:
    for i, s in enumerate(body):
        if isinstance(s, Query):
            return i
    return None


def reorder_for_fission(body: Sequence[Stmt], qi: int) -> tuple[list[Stmt], int]:
    """Reorder loop-body statements so Rule A applies at the query ``qi``.

    The paper's sufficient condition ([4]): the query must not lie on a
    true-dependence (flow) cycle in the DDG.  We compute, over *flow* edges
    only (intra + loop-carried), the set of statements transitively required
    to produce the query's inputs (``pre``) and schedule them (in original
    order) before the query; all other statements go after it.  The schedule
    is then checked: it must respect every *intra-iteration* dependence
    (flow, anti and output); if not, fission is impossible by reordering.

    Returns the reordered body and the new query index.
    """
    ddg = build_ddg(body, loop_body=True)
    n = len(body)

    # Transitive predecessors of the query over flow edges (both intra and
    # loop-carried): these statements feed the query's parameters, possibly
    # through values carried around the loop, so they must stay on the
    # producer side.
    flow_preds: dict[int, set[int]] = {i: set() for i in range(n)}
    for e in ddg.edges:
        if e.kind.flow:
            flow_preds[e.dst].add(e.src)
    pre: set[int] = set()
    stack = [qi]
    while stack:
        cur = stack.pop()
        for p in flow_preds[cur]:
            if p != qi and p not in pre:
                pre.add(p)
                stack.append(p)
    if qi in pre or any(
        e.src == qi and e.dst == qi and e.kind.flow for e in ddg.edges
    ):
        raise FissionError(
            "query lies on a true-dependence cycle (its inputs depend on its "
            "own result); Rule A is inapplicable (paper §4.1)"
        )

    order = [i for i in range(n) if i in pre] + [qi] + [
        i for i in range(n) if i not in pre and i != qi
    ]

    # Validate: the new order must respect all intra-iteration dependencies.
    pos = {old: new for new, old in enumerate(order)}
    for e in ddg.intra_edges():
        if pos[e.src] > pos[e.dst]:
            raise FissionError(
                f"reordering would violate intra-iteration dependence {e!r}"
            )
    new_body = [body[i] for i in order]
    return new_body, pos[qi]


# ---------------------------------------------------------------------------
# Rule A (§3.2): loop fission
# ---------------------------------------------------------------------------


def _check_rule_a_preconditions(body: Sequence[Stmt], qi: int) -> None:
    """Rule A preconditions (the paper's relaxed form):

    (a) no loop-carried *flow* dependencies (external or otherwise) cross the
        split points before/after the query statement ``s``;
    (b) no loop-carried *external* anti or output dependencies cross them.

    "Crossing" means: the edge connects a statement in ``ss2`` (after s) to a
    statement in ``ss1 ∪ {s}`` (at or before s) in a later iteration —
    i.e. src ∈ after-side, dst ∈ before-side.  (Plain loop-carried anti /
    output deps on program variables are *allowed* to cross — that is the
    paper's improvement over [1]; the loop context table renames them away.)
    """
    ddg = build_ddg(body, loop_body=True)
    before = set(range(qi + 1))  # ss1 ∪ {s}
    after = set(range(qi + 1, len(body)))  # ss2

    for e in ddg.loop_carried_edges():
        crosses = e.src in after and e.dst in before
        if not crosses:
            continue
        if e.kind.flow:
            raise FissionError(
                f"loop-carried flow dependence crosses the split: {e!r} "
                f"(precondition (a) of Rule A)"
            )
        if e.kind.external:
            raise FissionError(
                f"loop-carried external {e.kind.value} dependence crosses the "
                f"split: {e!r} (precondition (b) of Rule A)"
            )


def _split_variables(body: Sequence[Stmt], qi: int) -> tuple[str, ...]:
    """SV of Rule A: variables with an LCAD or LCOD edge crossing the split
    boundary, i.e. read/written on the consumer side while (re)written on the
    producer side in a later iteration — they must be captured per-iteration
    in the loop context table.

    We compute them directly: any variable that the consumer side (ss2)
    reads, and that the producer side (ss1 ∪ s) writes, must be captured
    (the producer of a *later* iteration would otherwise clobber the value
    the consumer of an *earlier* iteration needs — exactly the LCAD case).
    Variables the consumer both writes before reading are still captured
    when a producer write may reach a consumer read (conditional writes —
    Rule A item 3 restores only non-null attributes; we capture
    conservatively and restore unconditionally, which is equivalent because
    capture happens after the producer's write of the same iteration).
    """
    before = list(body[: qi + 1])
    after = list(body[qi + 1 :])
    written_before: set[str] = set()
    for s in before:
        written_before |= s.writes()
        # Loop item var and guards of queries also flow through records.
        written_before |= {g for g in [s.guard] if g}
    read_after: set[str] = set()
    for s in after:
        read_after |= s.reads()
    return tuple(sorted((written_before & read_after)))


def apply_rule_a(
    loop: Loop,
    *,
    overlap: bool = False,
    reorder: bool = True,
) -> _ProducerConsumer:
    """Split ``loop`` at its first Query statement (paper Rule A).

    ``overlap=True`` produces the §5.1 variant (producer in its own thread,
    blocking-queue context table).  ``reorder=True`` first applies the
    statement-reordering algorithm when the preconditions fail.
    """
    body = apply_rule_b(loop.body)
    qi = _find_query(body)
    if qi is None:
        raise FissionError("loop contains no query execution statement")

    try:
        _check_rule_a_preconditions(body, qi)
    except FissionError:
        if not reorder:
            raise
        body, qi = reorder_for_fission(body, qi)
        _check_rule_a_preconditions(body, qi)

    q = body[qi]
    assert isinstance(q, Query)
    if q.updates_db:
        raise FissionError(
            "data-modifying query cannot be submitted asynchronously under "
            "the conservative external-dependence model (paper §8)"
        )

    fresh = _FreshNames(body)
    table_var = fresh("t")
    record_var = fresh("r")
    handle_attr = fresh("handle")
    sv = _split_variables(body, qi)

    # Producer body: ss1' = ss1 with capture of split variables, then
    # r.handle = submitQuery(q).
    producer_body: list[Stmt] = list(body[:qi])
    submit = _Submit(
        target=handle_attr,
        query_name=q.query_name,
        params=q.params,
    )
    if q.guard is not None:
        submit = submit.with_guard(q.guard, q.guard_negated)
    producer_body.append(submit)

    producer = Loop(
        item_var=loop.item_var,
        iter_var=loop.iter_var,
        body=producer_body,
    )

    # Consumer body: ss_r (restore) is handled by the interpreter (it binds
    # the record's captured variables into the environment); then
    # v = fetchResult(handle); ss2.
    fetch = _Fetch(target=q.target, handle=handle_attr)
    if q.guard is not None:
        fetch = fetch.with_guard(q.guard, q.guard_negated)
    consumer_body: list[Stmt] = [fetch] + list(body[qi + 1 :])

    split_vars = tuple(
        sorted(set(sv) | {loop.item_var} | ({q.guard} if q.guard else set()))
    )

    return _ProducerConsumer(
        producer=producer,
        consumer_body=consumer_body,
        table_var=table_var,
        record_var=record_var,
        split_vars=split_vars,
        overlap=overlap,
    )


def fission_loop(loop: Loop, **kw) -> Stmt:
    """Public alias of :func:`apply_rule_a`."""
    return apply_rule_a(loop, **kw)


def transform_program(
    prog: Program, *, overlap: bool = False, max_depth: int = 8
) -> Program:
    """Transform every fissionable loop in ``prog`` (nested loops §3.4:
    innermost-first, then the outer loop sees the fissioned inner statement
    as an opaque external-reading statement and may itself be fissioned when
    preconditions hold — matching the paper's nested-table construction
    conceptually, executed here via the runtime queue which is shared).
    Loops whose preconditions fail are left untouched (rule application can
    stop at any point — §3)."""

    def rewrite(stmts: list[Stmt], depth: int) -> list[Stmt]:
        out: list[Stmt] = []
        for s in stmts:
            if isinstance(s, Loop) and depth < max_depth:
                s = dataclasses.replace(s, body=rewrite(s.body, depth + 1))
                try:
                    out.append(apply_rule_a(s, overlap=overlap))
                    continue
                except FissionError:
                    pass
            if isinstance(s, If):
                s = dataclasses.replace(
                    s,
                    then_body=rewrite(s.then_body, depth),
                    else_body=rewrite(s.else_body, depth),
                )
            out.append(s)
        return out

    return Program(body=rewrite(list(prog.body), 0), inputs=prog.inputs)


# ---------------------------------------------------------------------------
# Applicability analysis (§6.2, Table 1)
# ---------------------------------------------------------------------------


def analyze_applicability(prog: Program) -> dict[str, Any]:
    """Count query-in-loop opportunities and how many Rule A (with Rule B +
    reordering) can transform — the paper's Table 1."""
    opportunities = 0
    transformed = 0
    failures: list[str] = []

    def visit(stmts: Sequence[Stmt]):
        nonlocal opportunities, transformed
        for s in stmts:
            if isinstance(s, Loop):
                flat = apply_rule_b(s.body)
                n_queries = sum(1 for t in flat if isinstance(t, Query))
                opportunities += n_queries
                probe = s
                for _ in range(n_queries):
                    try:
                        pc = apply_rule_a(probe)
                        transformed += 1
                        # Remaining queries live in the consumer; probe again.
                        probe = Loop(
                            item_var=pc.record_var,
                            iter_var=pc.table_var,
                            body=pc.consumer_body[1:],
                        )
                    except FissionError as e:
                        failures.append(str(e))
                        break
                visit(s.body)
            elif isinstance(s, If):
                visit(s.then_body)
                visit(s.else_body)

    visit(prog.body)
    pct = 100.0 * transformed / opportunities if opportunities else 100.0
    return {
        "opportunities": opportunities,
        "transformed": transformed,
        "applicability_pct": pct,
        "failures": failures,
    }


# ---------------------------------------------------------------------------
# Interpreter
# ---------------------------------------------------------------------------


class Interpreter:
    """Executes HIR programs against a query service.

    ``service`` must provide ``execute(query_name, params) -> result``.  For
    transformed programs it must additionally provide the asynchronous API
    ``submit(query_name, params) -> handle`` and ``fetch(handle) -> result``
    (see :class:`repro.core.runtime.AsyncQueryRuntime`).  The untransformed
    and transformed programs then execute observably identically — the
    property our tests check.
    """

    def __init__(self, service, outputs: Optional[Callable[[Any], None]] = None):
        self.service = service
        self.emitted: list[Any] = []  # ordered observable outputs (print/log)
        # Optional output sink: called with each (effect, value) pair as it
        # is emitted, alongside the `emitted` log — a streaming consumer
        # (print, logger, socket) sees outputs in program order without
        # waiting for run() to return.
        self.outputs = outputs

    # -- public --------------------------------------------------------------
    def run(self, prog: Program, inputs: Mapping[str, Any]) -> dict[str, Any]:
        env = dict(inputs)
        self._exec_block(prog.body, env)
        return env

    # -- internals -----------------------------------------------------------
    def _guard_ok(self, s: Stmt, env: dict) -> bool:
        if s.guard is None:
            return True
        v = bool(env[s.guard])
        return (not v) if s.guard_negated else v

    def _exec_block(self, stmts: Sequence[Stmt], env: dict) -> None:
        for s in stmts:
            self._exec(s, env)

    def _exec(self, s: Stmt, env: dict) -> None:
        if not self._guard_ok(s, env):
            return
        if isinstance(s, Assign):
            val = s.fn(*[env[a] for a in s.args])
            if s.effect is not None:
                self.emitted.append((s.effect, val))
                if self.outputs is not None:
                    self.outputs((s.effect, val))
            if s.target is not None:
                env[s.target] = val
        elif isinstance(s, Query):
            env[s.target] = self.service.execute(s.query_name, tuple(env[p] for p in s.params))
        elif isinstance(s, _Submit):
            env[s.target] = self.service.submit(s.query_name, tuple(env[p] for p in s.params))
        elif isinstance(s, _Fetch):
            env[s.target] = self.service.fetch(env[s.handle])
        elif isinstance(s, If):
            branch = s.then_body if bool(env[s.pred]) else s.else_body
            self._exec_block(branch, env)
        elif isinstance(s, Loop):
            for item in list(env[s.iter_var]):
                env[s.item_var] = item
                self._exec_block(s.body, env)
        elif isinstance(s, _ProducerConsumer):
            self._exec_fissioned(s, env)
        else:
            raise TypeError(f"unknown statement {type(s)}")

    def _exec_fissioned(self, s: _ProducerConsumer, env: dict) -> None:
        from repro.core.loop_context import LoopContextTable

        table = LoopContextTable(blocking=s.overlap)

        # In overlap mode (§5.1) the producer runs in its own thread over a
        # *snapshot* of the environment: by Rule A's preconditions there are
        # no dependences between producer and consumer other than through the
        # loop context table, so the snapshot is safe; it prevents the
        # low-level race of both threads mutating one dict entry (the paper's
        # Java tool gets this for free from per-iteration locals).
        penv = dict(env) if s.overlap else env

        # A producer exception must not strand the consumer: the table is
        # closed in a `finally` (the consumer's `for record in table:` would
        # otherwise block forever on the overlap path) and the exception is
        # captured and re-raised on the caller's thread after join — the
        # §5.1 thread must neither swallow errors nor hang the program.
        producer_error: list[BaseException] = []

        def produce():
            try:
                for item in list(penv[s.producer.iter_var]):
                    penv[s.producer.item_var] = item
                    self._exec_block(s.producer.body, penv)
                    record = {v: penv[v] for v in s.split_vars if v in penv}
                    # the submit handle:
                    for st in s.producer.body:
                        if isinstance(st, _Submit):
                            if self._guard_ok(st, penv):
                                record[st.target] = penv[st.target]
                            else:
                                record[st.target] = None
                    table.put(record)
            except BaseException as e:  # noqa: BLE001 — re-raised after join
                producer_error.append(e)
                return
            finally:
                table.close()
            # The producer loop has submitted everything: strategies that
            # wait for the full request set (PureBatch) may now fire.
            done_hook = getattr(self.service, "producer_done", None)
            if done_hook is not None:
                done_hook()

        if s.overlap:
            import threading

            th = threading.Thread(target=produce, name="hir-producer")
            th.start()
        else:
            produce()
            if producer_error:
                raise producer_error[0]

        for record in table:
            env.update(record)
            self._exec_block(s.consumer_body, env)

        if s.overlap:
            th.join()
            if producer_error:
                raise producer_error[0]
            # Merge back producer-only writes (vars the consumer neither
            # restores nor writes), preserving the original program's final
            # values: per body order, a consumer write supersedes the
            # producer's, otherwise the producer's final value stands.
            consumer_writes: set[str] = set()
            for st in s.consumer_body:
                consumer_writes |= st.writes()
            producer_writes = s.producer.writes() | {s.producer.item_var}
            for v in producer_writes - consumer_writes - set(s.split_vars):
                if v in penv:
                    env[v] = penv[v]
