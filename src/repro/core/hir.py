"""Host-level IR (HIR) — the paper's source-to-source transformer.

The paper (Ramachandra et al., "Program Transformations for Asynchronous and
Batched Query Submission") rewrites Java/JDBC programs.  Our host-level IR is
the language-neutral core of that tool: a tiny imperative language of
statements with explicit read/write sets, over which we implement

  * the **data dependence graph** (§3.1): flow / anti / output dependencies
    and their loop-carried variants, plus *external* dependencies through a
    shared service (the "database"),
  * **Rule B** (§3.3): control-dependence → flow-dependence conversion by
    predication (guard variables),
  * **statement reordering** ([4] §"Applicability"): dependence-preserving
    topological reordering that moves the query and its dependents apart so
    the Rule A precondition holds,
  * **Rule A** (§3.2): loop fission at a query statement into a *producer*
    loop (asynchronous ``submit``) and a *consumer* loop (blocking ``fetch``),
    communicating through a **loop context table**,
  * **nested-loop fission** (§3.4), and
  * the **applicability analysis** of §6.2 (Table 1).

Programs in this IR are *executable*: :class:`Interpreter` runs them against
a :class:`~repro.core.services.QueryService`, so every transformation can be
property-tested for semantic equivalence (transformed(program) ≡ program).

The IR deliberately mirrors the paper's presentation:

  ``v = executeQuery(q)``  →  :class:`Query` statement
  ``ss1; s; ss2``          →  :class:`Loop` body (list of statements)
  guard variables          →  ``Assign.guard`` (Rule B predication)

Expressions are Python callables over an environment dict; read/write sets
are declared explicitly (exactly the information SOOT/Jimple dataflow gives
the paper's tool).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Mapping, Optional, Sequence

__all__ = [
    "Stmt",
    "Assign",
    "Query",
    "If",
    "Loop",
    "Proc",
    "Call",
    "Program",
    "DepKind",
    "DepEdge",
    "DataDependenceGraph",
    "build_ddg",
    "apply_rule_b",
    "reorder_for_fission",
    "FissionError",
    "apply_rule_a",
    "fission_loop",
    "can_inline",
    "inline_call",
    "transform_program",
    "enumerate_fission_sites",
    "analyze_applicability",
    "collect_names",
    "Interpreter",
]


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Stmt:
    """Base statement.  ``guard`` is the Rule B predication variable: when
    set, the statement only executes if ``env[guard]`` is truthy (negated if
    ``guard_negated``)."""

    guard: Optional[str] = dataclasses.field(default=None, kw_only=True)
    guard_negated: bool = dataclasses.field(default=False, kw_only=True)

    # --- dataflow interface -------------------------------------------------
    def reads(self) -> frozenset[str]:
        raise NotImplementedError

    def writes(self) -> frozenset[str]:
        raise NotImplementedError

    def external_reads(self) -> bool:
        """True if the statement reads external state (the database)."""
        return False

    def external_writes(self) -> bool:
        """True if the statement writes external state (the database)."""
        return False

    def _guard_reads(self) -> frozenset[str]:
        return frozenset([self.guard]) if self.guard else frozenset()

    def with_guard(self, guard: str, negated: bool = False) -> "Stmt":
        new = dataclasses.replace(self)
        if new.guard is not None:
            raise ValueError(
                "nested guards unsupported; apply Rule B innermost-first "
                "(the paper groups guards back in a readability pass)"
            )
        new.guard = guard
        new.guard_negated = negated
        return new


@dataclasses.dataclass
class Assign(Stmt):
    """``target = fn(*[env[v] for v in args])``.

    ``effect`` marks external writes (e.g. ``log``/``print``/DB update —
    §3.1 "External data dependencies"); such statements are modelled
    conservatively as writing the external resource named by ``effect``.
    """

    target: Optional[str] = None
    fn: Callable[..., Any] = None  # type: ignore[assignment]
    args: tuple[str, ...] = ()
    effect: Optional[str] = None

    def reads(self) -> frozenset[str]:
        return frozenset(self.args) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def external_writes(self) -> bool:
        return self.effect is not None

    def __repr__(self) -> str:  # readable transformed programs (§4.1 goal 1)
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        t = f"{self.target} = " if self.target else ""
        return f"{g}{t}{getattr(self.fn, '__name__', 'fn')}({', '.join(self.args)})"


@dataclasses.dataclass
class Query(Stmt):
    """``target = executeQuery(query_name, params...)`` — the blocking call.

    ``updates_db`` marks data-modifying statements (INSERT/UPDATE): they are
    external writes, any query is an external read (§3.1, §8 "update
    transactions" — conservative model).
    """

    target: Optional[str] = None
    query_name: str = ""
    params: tuple[str, ...] = ()
    updates_db: bool = False

    def reads(self) -> frozenset[str]:
        return frozenset(self.params) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def external_reads(self) -> bool:
        return True

    def external_writes(self) -> bool:
        return self.updates_db

    def __repr__(self) -> str:
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        return (
            f"{g}{self.target} = executeQuery({self.query_name!r}, "
            f"{', '.join(self.params)})"
        )


@dataclasses.dataclass
class _Submit(Stmt):
    """``handle = submitQuery(...)`` — produced by Rule A, non-blocking."""

    target: Optional[str] = None
    query_name: str = ""
    params: tuple[str, ...] = ()

    def reads(self) -> frozenset[str]:
        return frozenset(self.params) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def external_reads(self) -> bool:
        return True

    def __repr__(self) -> str:
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        return (
            f"{g}{self.target} = submitQuery({self.query_name!r}, "
            f"{', '.join(self.params)})"
        )


@dataclasses.dataclass
class _Fetch(Stmt):
    """``v = fetchResult(handle)`` — produced by Rule A, blocking."""

    target: Optional[str] = None
    handle: str = ""

    def reads(self) -> frozenset[str]:
        return frozenset([self.handle]) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def __repr__(self) -> str:
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        return f"{g}{self.target} = fetchResult({self.handle})"


@dataclasses.dataclass
class If(Stmt):
    """``if (pred_var) { then_body } else { else_body }`` (§3.3)."""

    pred: str = ""
    then_body: list[Stmt] = dataclasses.field(default_factory=list)
    else_body: list[Stmt] = dataclasses.field(default_factory=list)

    def reads(self) -> frozenset[str]:
        r = frozenset([self.pred]) | self._guard_reads()
        for s in itertools.chain(self.then_body, self.else_body):
            r |= s.reads()
        return r

    def writes(self) -> frozenset[str]:
        w: frozenset[str] = frozenset()
        for s in itertools.chain(self.then_body, self.else_body):
            w |= s.writes()
        return w

    def external_reads(self) -> bool:
        return any(
            s.external_reads() for s in itertools.chain(self.then_body, self.else_body)
        )

    def external_writes(self) -> bool:
        return any(
            s.external_writes()
            for s in itertools.chain(self.then_body, self.else_body)
        )

    def __repr__(self) -> str:
        return f"if ({self.pred}) {{ {len(self.then_body)} stmts }} else {{ {len(self.else_body)} stmts }}"


@dataclasses.dataclass
class Loop(Stmt):
    """``for item_var in env[iter_var]: body`` — the paper's generic loop.

    The paper presents Rule A for ``while`` loops; our executable form is the
    for-each loop (the paper's own second loop in Rule A's RHS is exactly
    this).  ``while`` loops whose predicate is updated by the body are
    expressible by reordering (Example 4/5) and covered in tests via an
    explicit counter idiom.
    """

    item_var: str = ""
    iter_var: str = ""
    body: list[Stmt] = dataclasses.field(default_factory=list)

    def reads(self) -> frozenset[str]:
        r = frozenset([self.iter_var]) | self._guard_reads()
        for s in self.body:
            r |= s.reads()
        return r - frozenset([self.item_var])

    def writes(self) -> frozenset[str]:
        w: frozenset[str] = frozenset()
        for s in self.body:
            w |= s.writes()
        return w

    def external_reads(self) -> bool:
        return any(s.external_reads() for s in self.body)

    def external_writes(self) -> bool:
        return any(s.external_writes() for s in self.body)

    def __repr__(self) -> str:
        return f"for {self.item_var} in {self.iter_var}: {{ {len(self.body)} stmts }}"


@dataclasses.dataclass
class Proc:
    """A named procedure definition (Guravannavar thesis, ch. on procedure
    boundaries): formal parameters, a statement body executed in its OWN
    scope (callees cannot read caller variables — every body read must be a
    formal or a previously-written local), and an optional ``result`` local
    returned to the caller.

    ``Proc`` is a definition, not a statement: it only runs when a
    :class:`Call` names it.  Because callee scopes are isolated, a call's
    dataflow summary is exact — reads = args, writes = {target} — which is
    what lets :func:`inline_call` rename the body into the caller without
    changing any dependence.
    """

    name: str = "proc"
    formals: tuple[str, ...] = ()
    body: list[Stmt] = dataclasses.field(default_factory=list)
    result: Optional[str] = None

    def __repr__(self) -> str:
        return (
            f"proc {self.name}({', '.join(self.formals)}) "
            f"{{ {len(self.body)} stmts }} -> {self.result}"
        )


@dataclasses.dataclass
class Call(Stmt):
    """``target = proc(args...)`` — procedure invocation by direct reference.

    The callee runs in a fresh scope seeded only with ``formals`` bound to
    the caller's ``args`` values; on return, the callee's ``result`` local
    (if any) is assigned to ``target``.  External effects (queries, logs)
    inside the body happen against the shared service, so the call's
    external read/write summary is the body's.
    """

    target: Optional[str] = None
    proc: Proc = None  # type: ignore[assignment]
    args: tuple[str, ...] = ()

    def reads(self) -> frozenset[str]:
        return frozenset(self.args) | self._guard_reads()

    def writes(self) -> frozenset[str]:
        return frozenset([self.target]) if self.target else frozenset()

    def external_reads(self) -> bool:
        return any(s.external_reads() for s in self.proc.body)

    def external_writes(self) -> bool:
        return any(s.external_writes() for s in self.proc.body)

    def __repr__(self) -> str:
        g = f"[{'!' if self.guard_negated else ''}{self.guard}] " if self.guard else ""
        t = f"{self.target} = " if self.target else ""
        return f"{g}{t}{self.proc.name}({', '.join(self.args)})"


@dataclasses.dataclass
class _ProducerConsumer(Stmt):
    """Result of Rule A: producer loop + consumer loop over a context table.

    Executed by the interpreter either sequentially (basic Rule A) or with
    the producer in its own thread over a blocking queue (§5.1 overlap,
    ``overlap=True``).
    """

    producer: Loop = None  # type: ignore[assignment]
    consumer_body: list[Stmt] = dataclasses.field(default_factory=list)
    table_var: str = ""
    record_var: str = ""
    split_vars: tuple[str, ...] = ()
    overlap: bool = False

    def reads(self) -> frozenset[str]:
        r = self.producer.reads()
        for s in self.consumer_body:
            r |= s.reads()
        return r - frozenset(self.split_vars) - frozenset([self.table_var, self.record_var])

    def writes(self) -> frozenset[str]:
        w = self.producer.writes()
        for s in self.consumer_body:
            w |= s.writes()
        return w

    def external_reads(self) -> bool:
        return True

    def external_writes(self) -> bool:
        # A fissioned loop still performs whatever external writes (logs,
        # effects) its statements perform — an enclosing loop's dependence
        # analysis must keep seeing them or nested fission would reorder
        # emissions it may not.
        return self.producer.external_writes() or any(
            s.external_writes() for s in self.consumer_body
        )

    def __repr__(self) -> str:
        mode = "overlap" if self.overlap else "two-phase"
        return (
            f"fissioned[{mode}](producer={self.producer!r}, "
            f"consumer={{ {len(self.consumer_body)} stmts }})"
        )


@dataclasses.dataclass
class Program:
    """A statement sequence + the set of input variables."""

    body: list[Stmt]
    inputs: tuple[str, ...] = ()

    def __repr__(self) -> str:
        return "\n".join(repr(s) for s in self.body)


# ---------------------------------------------------------------------------
# Data dependence graph (§3.1)
# ---------------------------------------------------------------------------


class DepKind(enum.Enum):
    FLOW = "FD"
    ANTI = "AD"
    OUTPUT = "OD"
    LOOP_FLOW = "LFD"
    LOOP_ANTI = "LAD"
    LOOP_OUTPUT = "LOD"
    EXT_FLOW = "xFD"
    EXT_ANTI = "xAD"
    EXT_OUTPUT = "xOD"
    EXT_LOOP_FLOW = "xLFD"
    EXT_LOOP_ANTI = "xLAD"
    EXT_LOOP_OUTPUT = "xLOD"

    @property
    def loop_carried(self) -> bool:
        return self in (
            DepKind.LOOP_FLOW,
            DepKind.LOOP_ANTI,
            DepKind.LOOP_OUTPUT,
            DepKind.EXT_LOOP_FLOW,
            DepKind.EXT_LOOP_ANTI,
            DepKind.EXT_LOOP_OUTPUT,
        )

    @property
    def external(self) -> bool:
        return self.value.startswith("x")

    @property
    def flow(self) -> bool:
        return self in (
            DepKind.FLOW,
            DepKind.LOOP_FLOW,
            DepKind.EXT_FLOW,
            DepKind.EXT_LOOP_FLOW,
        )


@dataclasses.dataclass(frozen=True)
class DepEdge:
    src: int  # statement index
    dst: int
    kind: DepKind
    var: str  # variable (or external resource) carrying the dependence

    def __repr__(self) -> str:
        return f"s{self.src} --{self.kind.value}[{self.var}]--> s{self.dst}"


@dataclasses.dataclass
class DataDependenceGraph:
    stmts: list[Stmt]
    edges: list[DepEdge]

    def edges_from(self, i: int) -> list[DepEdge]:
        return [e for e in self.edges if e.src == i]

    def edges_to(self, i: int) -> list[DepEdge]:
        return [e for e in self.edges if e.dst == i]

    def intra_edges(self) -> list[DepEdge]:
        return [e for e in self.edges if not e.kind.loop_carried]

    def loop_carried_edges(self) -> list[DepEdge]:
        return [e for e in self.edges if e.kind.loop_carried]


_EXT = "__db__"  # §3.1: model the whole database as one external variable


def build_ddg(body: Sequence[Stmt], loop_body: bool = True) -> DataDependenceGraph:
    """Build the DDG of a statement sequence (Fig. 1 of the paper).

    With ``loop_body=True`` the sequence is treated as the body of a loop and
    loop-carried edges are added: for every (write in s_a, read in s_b) pair
    with a ≥ b order in the *next* iteration, an ``LFD`` edge, etc.  External
    dependencies conservatively route through the single resource ``__db__``
    (every query reads it, every update/effect writes it).
    """
    stmts = list(body)
    edges: list[DepEdge] = []

    def rw(s: Stmt) -> tuple[frozenset[str], frozenset[str]]:
        r, w = s.reads(), s.writes()
        if s.external_reads():
            r = r | {_EXT}
        if s.external_writes():
            w = w | {_EXT}
        return r, w

    rws = [rw(s) for s in stmts]

    # Intra-iteration edges (forward control flow only).
    for a in range(len(stmts)):
        ra, wa = rws[a]
        for b in range(a + 1, len(stmts)):
            rb, wb = rws[b]
            for v in wa & rb:  # a writes, b reads  → flow
                kind = DepKind.EXT_FLOW if v == _EXT else DepKind.FLOW
                edges.append(DepEdge(a, b, kind, v))
            for v in ra & wb:  # a reads, b writes  → anti
                kind = DepKind.EXT_ANTI if v == _EXT else DepKind.ANTI
                edges.append(DepEdge(a, b, kind, v))
            for v in wa & wb:  # both write         → output
                kind = DepKind.EXT_OUTPUT if v == _EXT else DepKind.OUTPUT
                edges.append(DepEdge(a, b, kind, v))

    if loop_body:
        # Loop-carried edges: s_a in iteration t, s_b in iteration t+1, for
        # *all* (a, b) pairs including a >= b (that is what makes them
        # loop-carried).
        for a in range(len(stmts)):
            ra, wa = rws[a]
            for b in range(len(stmts)):
                rb, wb = rws[b]
                for v in wa & rb:
                    kind = DepKind.EXT_LOOP_FLOW if v == _EXT else DepKind.LOOP_FLOW
                    edges.append(DepEdge(a, b, kind, v))
                for v in ra & wb:
                    kind = DepKind.EXT_LOOP_ANTI if v == _EXT else DepKind.LOOP_ANTI
                    edges.append(DepEdge(a, b, kind, v))
                for v in wa & wb:
                    kind = (
                        DepKind.EXT_LOOP_OUTPUT if v == _EXT else DepKind.LOOP_OUTPUT
                    )
                    edges.append(DepEdge(a, b, kind, v))

    return DataDependenceGraph(stmts, edges)


# ---------------------------------------------------------------------------
# Rule B (§3.3): control → flow dependencies
# ---------------------------------------------------------------------------


def apply_rule_b(
    body: Sequence[Stmt],
    *,
    reserved: Sequence[str] = (),
    _fresh: Optional["_FreshNames"] = None,
) -> list[Stmt]:
    """Flatten ``If`` statements into guarded statements (paper Rule B).

    ``if (p) {ss1} else {ss2}`` becomes ``cv = p; [cv] ss1; [!cv] ss2``.
    The predicate is already a variable in our IR, so no fresh assignment is
    needed unless the branch bodies might overwrite it — we always introduce
    the fresh ``cv`` for fidelity with the rule (and safety).

    ``reserved`` holds names that fresh guard variables must additionally
    avoid — callers transforming a whole program pass every name the
    program uses anywhere, so a generated ``cv_N`` can never collide with a
    user variable outside this body (see :class:`_FreshNames`).
    """
    out: list[Stmt] = []
    # One namer is shared across the whole recursion: If.reads()/writes()
    # aggregate their branch bodies, so the top-level namer already knows
    # every nested name, and sharing it keeps sibling/nested scopes from
    # reusing each other's generated guards.
    fresh = _fresh if _fresh is not None else _FreshNames(body, reserved=reserved)
    for s in body:
        if isinstance(s, If):
            inner_then = apply_rule_b(s.then_body, _fresh=fresh)
            inner_else = apply_rule_b(s.else_body, _fresh=fresh)
            cv = fresh("cv")
            # cv = p  (possibly itself guarded — nested Ifs come pre-flattened
            # by the recursive call, so s.guard is from an outer construct)
            cap = Assign(target=cv, fn=lambda p: bool(p), args=(s.pred,))
            if s.guard is not None:
                cap = cap.with_guard(s.guard, s.guard_negated)
            out.append(cap)
            for t in inner_then:
                out.append(_conjoin_guard(t, cv, False, fresh, out))
            for t in inner_else:
                out.append(_conjoin_guard(t, cv, True, fresh, out))
        else:
            out.append(s)
    return out


def _conjoin_guard(
    s: Stmt, cv: str, negated: bool, fresh: "_FreshNames", out: list[Stmt]
) -> Stmt:
    """Guard ``s`` with ``cv`` (negated as requested), conjoining any
    existing guard through a fresh boolean (guards are single variables)."""
    if s.guard is None:
        return s.with_guard(cv, negated)
    g_old, old_neg = s.guard, s.guard_negated
    conj = fresh("cv")

    def _and(a, b, _n1=old_neg, _n2=negated):
        va = (not a) if _n1 else bool(a)
        vb = (not b) if _n2 else bool(b)
        return va and vb

    _and.__name__ = "and"
    out.append(Assign(target=conj, fn=_and, args=(g_old, cv)))
    t = dataclasses.replace(s)
    t.guard = conj
    t.guard_negated = False
    return t


def collect_names(stmts: Sequence[Stmt]) -> set[str]:
    """Every variable name a statement sequence mentions anywhere: reads,
    writes, loop binders, guards — recursing into ``If``/``Loop`` bodies and
    into the bodies of procedures reachable through :class:`Call` (callee
    locals live in their own scope, but counting them keeps fresh names
    unique program-wide, which inlining relies on)."""
    names: set[str] = set()
    seen_procs: set[int] = set()

    def visit(seq: Sequence[Stmt]) -> None:
        for s in seq:
            names.update(s.reads() | s.writes())
            if s.guard:
                names.add(s.guard)
            if isinstance(s, If):
                visit(s.then_body)
                visit(s.else_body)
            elif isinstance(s, Loop):
                names.add(s.item_var)
                names.add(s.iter_var)
                visit(s.body)
            elif isinstance(s, _ProducerConsumer):
                names.update(s.split_vars)
                names.update((s.table_var, s.record_var))
                visit([s.producer])
                visit(s.consumer_body)
            elif isinstance(s, Call):
                names.update(s.args)
                if id(s.proc) not in seen_procs:
                    seen_procs.add(id(s.proc))
                    names.update(s.proc.formals)
                    visit(s.proc.body)

    visit(stmts)
    names.discard(None)  # unguarded / targetless statements
    return names


class _FreshNames:
    """Fresh-name allocator seeded with every name the given body mentions
    plus an explicit ``reserved`` set.

    The ``reserved`` parameter exists because seeding from one loop body is
    not enough: Rule A's generated names (``handle_N``, ``cv_N``, …) land in
    the shared environment at run time, so they must avoid collision with
    *program-wide* names, not just the body being fissioned — a program
    using ``handle_0`` outside the loop would otherwise be silently
    clobbered (a real miscompile the differential harness pinned down).
    Whole-program callers pass :func:`collect_names` of the full program.
    """

    def __init__(self, body: Sequence[Stmt], reserved: Sequence[str] = ()):
        self._used = set(reserved)
        self._used |= collect_names(body)
        self._n = 0

    def __call__(self, prefix: str) -> str:
        while True:
            name = f"{prefix}_{self._n}"
            self._n += 1
            if name not in self._used:
                self._used.add(name)
                return name


# ---------------------------------------------------------------------------
# Statement reordering ([4]) — enable Rule A when LC flow deps cross the split
# ---------------------------------------------------------------------------


class FissionError(ValueError):
    """Raised when the Rule A preconditions cannot be satisfied."""


def _find_query(body: Sequence[Stmt]) -> Optional[int]:
    for i, s in enumerate(body):
        if isinstance(s, Query):
            return i
    return None


def reorder_for_fission(body: Sequence[Stmt], qi: int) -> tuple[list[Stmt], int]:
    """Reorder loop-body statements so Rule A applies at the query ``qi``.

    The paper's sufficient condition ([4]): the query must not lie on a
    true-dependence (flow) cycle in the DDG.  We compute, over *flow* edges
    only (intra + loop-carried), the set of statements transitively required
    to produce the query's inputs (``pre``) and schedule them (in original
    order) before the query; all other statements go after it.  The schedule
    is then checked: it must respect every *intra-iteration* dependence
    (flow, anti and output); if not, fission is impossible by reordering.

    Returns the reordered body and the new query index.
    """
    ddg = build_ddg(body, loop_body=True)
    n = len(body)

    # Transitive predecessors of the query over flow edges (both intra and
    # loop-carried): these statements feed the query's parameters, possibly
    # through values carried around the loop, so they must stay on the
    # producer side.
    flow_preds: dict[int, set[int]] = {i: set() for i in range(n)}
    for e in ddg.edges:
        if e.kind.flow:
            flow_preds[e.dst].add(e.src)
    pre: set[int] = set()
    stack = [qi]
    while stack:
        cur = stack.pop()
        for p in flow_preds[cur]:
            if p != qi and p not in pre:
                pre.add(p)
                stack.append(p)
    if qi in pre or any(
        e.src == qi and e.dst == qi and e.kind.flow for e in ddg.edges
    ):
        raise FissionError(
            "query lies on a true-dependence cycle (its inputs depend on its "
            "own result); Rule A is inapplicable (paper §4.1)"
        )

    order = [i for i in range(n) if i in pre] + [qi] + [
        i for i in range(n) if i not in pre and i != qi
    ]

    # Validate: the new order must respect all intra-iteration dependencies.
    pos = {old: new for new, old in enumerate(order)}
    for e in ddg.intra_edges():
        if pos[e.src] > pos[e.dst]:
            raise FissionError(
                f"reordering would violate intra-iteration dependence {e!r}"
            )
    new_body = [body[i] for i in order]
    return new_body, pos[qi]


# ---------------------------------------------------------------------------
# Rule A (§3.2): loop fission
# ---------------------------------------------------------------------------


def _check_rule_a_preconditions(body: Sequence[Stmt], qi: int) -> None:
    """Rule A preconditions (the paper's relaxed form):

    (a) no loop-carried *flow* dependencies (external or otherwise) cross the
        split points before/after the query statement ``s``;
    (b) no loop-carried *external* anti or output dependencies cross them.

    "Crossing" means: the edge connects a statement in ``ss2`` (after s) to a
    statement in ``ss1 ∪ {s}`` (at or before s) in a later iteration —
    i.e. src ∈ after-side, dst ∈ before-side.  (Plain loop-carried anti /
    output deps on program variables are *allowed* to cross — that is the
    paper's improvement over [1]; the loop context table renames them away.)

    A third check guards the context-table capture itself:

    (c) a split variable written by the consumer side must have at least one
        *unconditional* producer-side write.  The table captures split
        variables unconditionally after each producer iteration; when every
        producer write of ``v`` is guarded and the guard is off for
        iteration ``i``, the captured value is whatever the producer phase
        last left in ``v`` — NOT the consumer's iteration ``i-1`` write that
        the synchronous program would observe.  (Found by the differential
        harness; see test_hir_rules.py's minimized regression.)
    """
    ddg = build_ddg(body, loop_body=True)
    before = set(range(qi + 1))  # ss1 ∪ {s}
    after = set(range(qi + 1, len(body)))  # ss2

    for e in ddg.loop_carried_edges():
        crosses = e.src in after and e.dst in before
        if not crosses:
            continue
        if e.kind.flow:
            raise FissionError(
                f"loop-carried flow dependence crosses the split: {e!r} "
                f"(precondition (a) of Rule A)"
            )
        if e.kind.external:
            raise FissionError(
                f"loop-carried external {e.kind.value} dependence crosses the "
                f"split: {e!r} (precondition (b) of Rule A)"
            )

    after_writes: set[str] = set()
    for i in after:
        after_writes |= body[i].writes()
    for v in sorted(set(_split_variables(body, qi)) & after_writes):
        writers = [body[i] for i in before if v in body[i].writes()]
        if writers and all(s.guard is not None for s in writers):
            raise FissionError(
                f"split variable {v!r} is written only conditionally by the "
                f"producer side but rewritten by the consumer side — the "
                f"unconditional context-table restore would clobber the "
                f"consumer's previous-iteration value (precondition (c))"
            )


def _split_variables(body: Sequence[Stmt], qi: int) -> tuple[str, ...]:
    """SV of Rule A: variables with an LCAD or LCOD edge crossing the split
    boundary, i.e. read/written on the consumer side while (re)written on the
    producer side in a later iteration — they must be captured per-iteration
    in the loop context table.

    We compute them directly: any variable that the consumer side (ss2)
    reads, and that the producer side (ss1 ∪ s) writes, must be captured
    (the producer of a *later* iteration would otherwise clobber the value
    the consumer of an *earlier* iteration needs — exactly the LCAD case).
    Variables the consumer both writes before reading are still captured
    when a producer write may reach a consumer read.  Capture happens after
    the producer's write of the same iteration and restore is unconditional,
    which is equivalent as long as precondition (c) of
    :func:`_check_rule_a_preconditions` holds (some producer-side write of
    the variable is unconditional whenever the consumer side rewrites it).

    The query statement itself is *excluded* from the producer-side write
    set: its target is written by the consumer's ``_Fetch``, never by the
    producer (the submit writes the handle), so capturing it would snapshot
    a stale pre-loop value — and the unconditional restore would clobber
    the loop-carried previous-iteration value the consumer relies on when
    the query is guarded and the guard is false (fuzz-found miscompile).
    The query's guard variable is added back by :func:`apply_rule_a`.
    """
    before = list(body[:qi])
    after = list(body[qi + 1 :])
    written_before: set[str] = set()
    for s in before:
        written_before |= s.writes()
        # Loop item var and guards of queries also flow through records.
        written_before |= {g for g in [s.guard] if g}
    read_after: set[str] = set()
    for s in after:
        read_after |= s.reads()
    return tuple(sorted((written_before & read_after)))


def apply_rule_a(
    loop: Loop,
    *,
    overlap: bool = False,
    reorder: bool = True,
    reserved: Sequence[str] = (),
) -> _ProducerConsumer:
    """Split ``loop`` at its first Query statement (paper Rule A).

    ``overlap=True`` produces the §5.1 variant (producer in its own thread,
    blocking-queue context table).  ``reorder=True`` first applies the
    statement-reordering algorithm when the preconditions fail.
    ``reserved`` names are kept out of the generated fresh variables
    (``handle_N``, ``cv_N``, …); whole-program callers pass every name the
    surrounding program uses so the handle variable cannot clobber a program
    variable outside this loop.
    """
    body = apply_rule_b(loop.body, reserved=reserved)
    qi = _find_query(body)
    if qi is None:
        raise FissionError("loop contains no query execution statement")

    try:
        _check_rule_a_preconditions(body, qi)
    except FissionError:
        if not reorder:
            raise
        body, qi = reorder_for_fission(body, qi)
        _check_rule_a_preconditions(body, qi)

    q = body[qi]
    assert isinstance(q, Query)
    if q.updates_db:
        raise FissionError(
            "data-modifying query cannot be submitted asynchronously under "
            "the conservative external-dependence model (paper §8)"
        )

    fresh = _FreshNames(body, reserved=reserved)
    table_var = fresh("t")
    record_var = fresh("r")
    handle_attr = fresh("handle")
    sv = _split_variables(body, qi)

    # Producer body: ss1' = ss1 with capture of split variables, then
    # r.handle = submitQuery(q).
    producer_body: list[Stmt] = list(body[:qi])
    submit = _Submit(
        target=handle_attr,
        query_name=q.query_name,
        params=q.params,
    )
    if q.guard is not None:
        submit = submit.with_guard(q.guard, q.guard_negated)
    producer_body.append(submit)

    producer = Loop(
        item_var=loop.item_var,
        iter_var=loop.iter_var,
        body=producer_body,
    )

    # Consumer body: ss_r (restore) is handled by the interpreter (it binds
    # the record's captured variables into the environment); then
    # v = fetchResult(handle); ss2.
    fetch = _Fetch(target=q.target, handle=handle_attr)
    if q.guard is not None:
        fetch = fetch.with_guard(q.guard, q.guard_negated)
    consumer_body: list[Stmt] = [fetch] + list(body[qi + 1 :])

    split_vars = tuple(
        sorted(set(sv) | {loop.item_var} | ({q.guard} if q.guard else set()))
    )

    return _ProducerConsumer(
        producer=producer,
        consumer_body=consumer_body,
        table_var=table_var,
        record_var=record_var,
        split_vars=split_vars,
        overlap=overlap,
    )


def fission_loop(loop: Loop, **kw) -> Stmt:
    """Public alias of :func:`apply_rule_a`."""
    return apply_rule_a(loop, **kw)


# ---------------------------------------------------------------------------
# Procedure inlining (Guravannavar thesis: inline-then-fission)
# ---------------------------------------------------------------------------


def _proc_has_query(proc: Proc, _seen: Optional[set[int]] = None) -> bool:
    """Whether the procedure (transitively) executes any query."""
    seen = _seen if _seen is not None else set()
    if id(proc) in seen:
        return False
    seen.add(id(proc))

    def visit(stmts: Sequence[Stmt]) -> bool:
        for s in stmts:
            if isinstance(s, (Query, _Submit)):
                return True
            if isinstance(s, If) and (visit(s.then_body) or visit(s.else_body)):
                return True
            if isinstance(s, Loop) and visit(s.body):
                return True
            if isinstance(s, Call) and _proc_has_query(s.proc, seen):
                return True
        return False

    return visit(proc.body)


def _proc_local_names(proc: Proc) -> set[str]:
    """Names bound inside the procedure's scope: formals, every write
    target, and loop binders — exactly the names :func:`inline_call` must
    rename to keep the inlined body out of the caller's namespace."""
    local = set(proc.formals)

    def visit(stmts: Sequence[Stmt]) -> None:
        for s in stmts:
            local.update(s.writes())
            if isinstance(s, If):
                visit(s.then_body)
                visit(s.else_body)
            elif isinstance(s, Loop):
                local.add(s.item_var)
                visit(s.body)

    visit(proc.body)
    return local


def can_inline(proc: Proc) -> tuple[bool, str]:
    """§6.2-style applicability check for inline-then-fission.

    Refuses (with a reason) when inlining would be unsound or undefined:

    * **recursion** — a procedure (transitively) calling itself cannot be
      inlined by substitution;
    * **free variables** — a body read that is neither a formal nor a
      procedure-local write has no value in the callee scope (the program
      is invalid; refusing keeps the transformer from "fixing" it by
      capturing caller state the synchronous semantics never read);
    * **undefined result** — ``result`` must be a formal or a body write.
    """
    # Recursion: can `proc` reach itself over the static call graph?
    def callees(p: Proc) -> list[Proc]:
        found: list[Proc] = []

        def walk(stmts: Sequence[Stmt]) -> None:
            for s in stmts:
                if isinstance(s, Call):
                    found.append(s.proc)
                elif isinstance(s, If):
                    walk(s.then_body)
                    walk(s.else_body)
                elif isinstance(s, Loop):
                    walk(s.body)

        walk(p.body)
        return found

    stack, seen = [proc], set()
    while stack:
        p = stack.pop()
        if id(p) in seen:
            continue
        seen.add(id(p))
        for callee in callees(p):
            if callee is proc:
                return False, (
                    f"procedure {proc.name!r} is (transitively) recursive"
                )
            stack.append(callee)

    local = _proc_local_names(proc)
    free: set[str] = set()
    for s in proc.body:
        free |= s.reads()
    free -= local
    if free:
        return False, (
            f"procedure {proc.name!r} reads undefined (free) variables "
            f"{sorted(free)} — callee scopes are isolated"
        )
    if proc.result is not None and proc.result not in local:
        return False, (
            f"procedure {proc.name!r} result {proc.result!r} is never bound"
        )
    return True, ""


def _rename_stmt(s: Stmt, ren: Mapping[str, str]) -> Stmt:
    """Alpha-rename one statement (recursively) under ``ren``; names not in
    the map — including ``Assign.effect`` resource names — pass through."""

    def r(name: Optional[str]) -> Optional[str]:
        return ren.get(name, name) if name is not None else None

    t = dataclasses.replace(s)
    t.guard = r(s.guard)
    if isinstance(t, Assign):
        t.target = r(t.target)
        t.args = tuple(r(a) for a in t.args)
    elif isinstance(t, (Query, _Submit)):
        t.target = r(t.target)
        t.params = tuple(r(p) for p in t.params)
    elif isinstance(t, _Fetch):
        t.target = r(t.target)
        t.handle = r(t.handle)
    elif isinstance(t, If):
        t.pred = r(t.pred)
        t.then_body = [_rename_stmt(b, ren) for b in s.then_body]
        t.else_body = [_rename_stmt(b, ren) for b in s.else_body]
    elif isinstance(t, Loop):
        t.item_var = r(t.item_var)
        t.iter_var = r(t.iter_var)
        t.body = [_rename_stmt(b, ren) for b in s.body]
    elif isinstance(t, Call):
        t.target = r(t.target)
        t.args = tuple(r(a) for a in t.args)
        # the callee's own scope is untouched: its locals are not ours
    else:
        raise TypeError(f"cannot rename statement {type(s)}")
    return t


def _identity(v: Any) -> Any:
    return v


def _negate(v: Any) -> bool:
    return not bool(v)


def inline_call(call: Call, fresh: _FreshNames) -> list[Stmt]:
    """Substitute a :class:`Call` with its procedure body (thesis
    inline-then-fission, step 1).

    Every callee-scope name is alpha-renamed to a fresh caller name
    (``<proc>_<var>_N``), formals become explicit copy assignments from the
    caller's argument variables, and ``target = result`` closes the call.  A
    guarded call wraps the whole expansion in an ``If`` on the (possibly
    freshly negated) guard so Rule B can later flatten it — callee
    statements keep their own inner guards, and nested guards are illegal.

    Callers must have verified :func:`can_inline` first.
    """
    proc = call.proc
    ren = {
        v: fresh(f"{proc.name}_{v}")
        for v in sorted(_proc_local_names(proc))
    }
    stmts: list[Stmt] = []
    for formal, arg in zip(proc.formals, call.args):
        stmts.append(
            Assign(target=ren[formal], fn=_identity, args=(arg,))
        )
    stmts.extend(_rename_stmt(s, ren) for s in proc.body)
    if call.target is not None and proc.result is not None:
        stmts.append(
            Assign(target=call.target, fn=_identity, args=(ren[proc.result],))
        )
    if call.guard is None:
        return stmts
    pred = call.guard
    out: list[Stmt] = []
    if call.guard_negated:
        pred = fresh("cv")
        out.append(Assign(target=pred, fn=_negate, args=(call.guard,)))
    out.append(If(pred=pred, then_body=stmts))
    return out


def transform_program(
    prog: Program,
    *,
    overlap: bool = False,
    max_depth: int = 8,
    sites: Optional[Sequence[int]] = None,
) -> Program:
    """Transform every fissionable loop in ``prog`` (nested loops §3.4:
    innermost-first, then the outer loop sees the fissioned inner statement
    as an opaque external-reading statement and may itself be fissioned when
    preconditions hold — matching the paper's nested-table construction
    conceptually, executed here via the runtime queue which is shared).
    Loops whose preconditions fail are left untouched (rule application can
    stop at any point — §3).

    Query-bearing :class:`Call` statements are inlined first (thesis
    inline-then-fission) when :func:`can_inline` approves, so Rule A/B and
    reordering apply across procedure boundaries; unsafe inlines (recursion,
    free variables) leave the call in place.

    ``sites`` optionally restricts Rule A to a subset of loop sites, named
    by their preorder index over the post-inline traversal (the numbering
    :func:`enumerate_fission_sites` reports) — the handle the synthesis
    search in :mod:`repro.core.equivalence` uses to enumerate *which*
    queries to asynchronize.
    """
    return _transform(prog, overlap=overlap, max_depth=max_depth, sites=sites)


def _transform(
    prog: Program,
    *,
    overlap: bool = False,
    max_depth: int = 8,
    sites: Optional[Sequence[int]] = None,
    report: Optional[list] = None,
) -> Program:
    """Shared engine behind :func:`transform_program` and
    :func:`enumerate_fission_sites`: one deterministic traversal that
    numbers loop sites in preorder (post-inline), optionally restricted to
    ``sites``, optionally appending ``(site, fissioned, reason)`` triples
    to ``report``."""
    fresh = _FreshNames(prog.body, reserved=prog.inputs)
    allowed = None if sites is None else set(sites)
    counter = itertools.count()

    def rewrite(stmts: list[Stmt], depth: int) -> list[Stmt]:
        out: list[Stmt] = []
        for s in stmts:
            if (
                isinstance(s, Call)
                and depth < max_depth
                and _proc_has_query(s.proc)
                and can_inline(s.proc)[0]
            ):
                out.extend(rewrite(inline_call(s, fresh), depth + 1))
                continue
            if isinstance(s, Loop) and depth < max_depth:
                site = next(counter)
                s = dataclasses.replace(s, body=rewrite(s.body, depth + 1))
                if allowed is None or site in allowed:
                    try:
                        out.append(
                            apply_rule_a(
                                s, overlap=overlap,
                                reserved=frozenset(fresh._used),
                            )
                        )
                        if report is not None:
                            report.append((site, True, ""))
                        continue
                    except FissionError as e:
                        if report is not None:
                            report.append((site, False, str(e)))
            if isinstance(s, If):
                s = dataclasses.replace(
                    s,
                    then_body=rewrite(s.then_body, depth),
                    else_body=rewrite(s.else_body, depth),
                )
            out.append(s)
        return out

    return Program(body=rewrite(list(prog.body), 0), inputs=prog.inputs)


def enumerate_fission_sites(
    prog: Program, *, overlap: bool = False, max_depth: int = 8
) -> list[tuple[int, bool, str]]:
    """Attempt Rule A at every loop site of the (inlined) program; return
    ``(site_index, fissioned, reason)`` per site in the same deterministic
    preorder numbering ``transform_program(sites=...)`` accepts.  The
    synthesis-lite search enumerates subsets of the fissioned sites and
    re-checks equivalence per candidate."""
    report: list[tuple[int, bool, str]] = []
    _transform(prog, overlap=overlap, max_depth=max_depth, report=report)
    return report


# ---------------------------------------------------------------------------
# Applicability analysis (§6.2, Table 1)
# ---------------------------------------------------------------------------


def analyze_applicability(prog: Program) -> dict[str, Any]:
    """Count query-in-loop opportunities and how many Rule A (with Rule B +
    reordering + procedure inlining) can transform — the paper's Table 1.

    The analysis runs over the *inlined* program so opportunities inside
    procedures called from loops are visited exactly as
    :func:`transform_program` would see them; query-bearing calls whose
    inline is refused (recursion, free variables) are reported in
    ``failures`` and their internal opportunities are not counted — the
    transformer will not enter them either, so the counts and the rewrite
    agree."""
    opportunities = 0
    transformed = 0
    failures: list[str] = []

    fresh = _FreshNames(prog.body, reserved=prog.inputs)

    def inline_visible(stmts: Sequence[Stmt], depth: int = 0) -> list[Stmt]:
        out: list[Stmt] = []
        for s in stmts:
            if (
                isinstance(s, Call)
                and depth < 8
                and _proc_has_query(s.proc)
            ):
                ok, reason = can_inline(s.proc)
                if ok:
                    out.extend(inline_call(s, fresh))
                    continue
                failures.append(f"inline refused: {reason}")
            out.append(s)
        return out

    def visit(stmts: Sequence[Stmt], depth: int = 0):
        nonlocal opportunities, transformed
        for s in inline_visible(stmts, depth):
            if isinstance(s, Loop):
                body = inline_visible(s.body, depth + 1)
                s = dataclasses.replace(s, body=body)
                flat = apply_rule_b(body)
                n_queries = sum(1 for t in flat if isinstance(t, Query))
                opportunities += n_queries
                probe = s
                for _ in range(n_queries):
                    try:
                        pc = apply_rule_a(probe)
                        transformed += 1
                        # Remaining queries live in the consumer; probe again.
                        probe = Loop(
                            item_var=pc.record_var,
                            iter_var=pc.table_var,
                            body=pc.consumer_body[1:],
                        )
                    except FissionError as e:
                        failures.append(str(e))
                        break
                visit(body, depth + 1)
            elif isinstance(s, If):
                visit(s.then_body, depth)
                visit(s.else_body, depth)

    visit(prog.body)
    pct = 100.0 * transformed / opportunities if opportunities else 100.0
    return {
        "opportunities": opportunities,
        "transformed": transformed,
        "applicability_pct": pct,
        "failures": failures,
    }


# ---------------------------------------------------------------------------
# Interpreter
# ---------------------------------------------------------------------------


class Interpreter:
    """Executes HIR programs against a query service.

    ``service`` must provide ``execute(query_name, params) -> result``.  For
    transformed programs it must additionally provide the asynchronous API
    ``submit(query_name, params) -> handle`` and ``fetch(handle) -> result``
    (see :class:`repro.core.runtime.AsyncQueryRuntime`).  The untransformed
    and transformed programs then execute observably identically — the
    property our tests check.
    """

    def __init__(self, service, outputs: Optional[Callable[[Any], None]] = None):
        self.service = service
        self.emitted: list[Any] = []  # ordered observable outputs (print/log)
        # Optional output sink: called with each (effect, value) pair as it
        # is emitted, alongside the `emitted` log — a streaming consumer
        # (print, logger, socket) sees outputs in program order without
        # waiting for run() to return.
        self.outputs = outputs

    # -- public --------------------------------------------------------------
    def run(self, prog: Program, inputs: Mapping[str, Any]) -> dict[str, Any]:
        env = dict(inputs)
        self._exec_block(prog.body, env)
        return env

    # -- internals -----------------------------------------------------------
    def _guard_ok(self, s: Stmt, env: dict) -> bool:
        if s.guard is None:
            return True
        v = bool(env[s.guard])
        return (not v) if s.guard_negated else v

    def _exec_block(self, stmts: Sequence[Stmt], env: dict) -> None:
        for s in stmts:
            self._exec(s, env)

    def _exec(self, s: Stmt, env: dict) -> None:
        if not self._guard_ok(s, env):
            return
        if isinstance(s, Assign):
            val = s.fn(*[env[a] for a in s.args])
            if s.effect is not None:
                self.emitted.append((s.effect, val))
                if self.outputs is not None:
                    self.outputs((s.effect, val))
            if s.target is not None:
                env[s.target] = val
        elif isinstance(s, Query):
            env[s.target] = self.service.execute(s.query_name, tuple(env[p] for p in s.params))
        elif isinstance(s, _Submit):
            env[s.target] = self.service.submit(s.query_name, tuple(env[p] for p in s.params))
        elif isinstance(s, _Fetch):
            env[s.target] = self.service.fetch(env[s.handle])
        elif isinstance(s, If):
            branch = s.then_body if bool(env[s.pred]) else s.else_body
            self._exec_block(branch, env)
        elif isinstance(s, Loop):
            for item in list(env[s.iter_var]):
                env[s.item_var] = item
                self._exec_block(s.body, env)
        elif isinstance(s, Call):
            # Callee scopes are isolated: the local environment holds ONLY
            # the formals (bound to the caller's argument values); a body
            # read of anything else is a KeyError in the callee, same as in
            # the inlined form where the free variable was never assigned.
            local = {
                f: env[a] for f, a in zip(s.proc.formals, s.args)
            }
            self._exec_block(s.proc.body, local)
            if s.target is not None:
                env[s.target] = (
                    local[s.proc.result] if s.proc.result is not None else None
                )
        elif isinstance(s, _ProducerConsumer):
            self._exec_fissioned(s, env)
        else:
            raise TypeError(f"unknown statement {type(s)}")

    def _exec_fissioned(self, s: _ProducerConsumer, env: dict) -> None:
        from repro.core.loop_context import LoopContextTable

        table = LoopContextTable(blocking=s.overlap)

        # In overlap mode (§5.1) the producer runs in its own thread over a
        # *snapshot* of the environment: by Rule A's preconditions there are
        # no dependences between producer and consumer other than through the
        # loop context table, so the snapshot is safe; it prevents the
        # low-level race of both threads mutating one dict entry (the paper's
        # Java tool gets this for free from per-iteration locals).
        penv = dict(env) if s.overlap else env

        # A producer exception must not strand the consumer: the table is
        # closed in a `finally` (the consumer's `for record in table:` would
        # otherwise block forever on the overlap path) and the exception is
        # captured and re-raised on the caller's thread after join — the
        # §5.1 thread must neither swallow errors nor hang the program.
        producer_error: list[BaseException] = []

        def produce():
            try:
                for item in list(penv[s.producer.iter_var]):
                    penv[s.producer.item_var] = item
                    self._exec_block(s.producer.body, penv)
                    record = {v: penv[v] for v in s.split_vars if v in penv}
                    # the submit handle:
                    for st in s.producer.body:
                        if isinstance(st, _Submit):
                            if self._guard_ok(st, penv):
                                record[st.target] = penv[st.target]
                            else:
                                record[st.target] = None
                    table.put(record)
            except BaseException as e:  # noqa: BLE001 — re-raised after join
                producer_error.append(e)
                return
            finally:
                table.close()
            # The producer loop has submitted everything: strategies that
            # wait for the full request set (PureBatch) may now fire.
            done_hook = getattr(self.service, "producer_done", None)
            if done_hook is not None:
                done_hook()

        if s.overlap:
            import threading

            th = threading.Thread(target=produce, name="hir-producer")
            th.start()
        else:
            produce()
            if producer_error:
                raise producer_error[0]

        for record in table:
            env.update(record)
            self._exec_block(s.consumer_body, env)

        if s.overlap:
            th.join()
            if producer_error:
                raise producer_error[0]
            # Merge back producer-only writes (vars the consumer neither
            # restores nor writes), preserving the original program's final
            # values: per body order, a consumer write supersedes the
            # producer's, otherwise the producer's final value stands.
            consumer_writes: set[str] = set()
            for st in s.consumer_body:
                consumer_writes |= st.writes()
            producer_writes = s.producer.writes() | {s.producer.item_var}
            for v in producer_writes - consumer_writes - set(s.split_vars):
                if v in penv:
                    env[v] = penv[v]
