"""AsyncQueryRuntime — the paper's runtime asynchronous-submission framework
(§4.2, Fig. 3) with asynchronous batching (§5.2), generalized to a
**sharded, multi-lane** runtime for heterogeneous multi-tenant traffic.

The paper's runtime (and this module's first incarnation) keeps ONE FIFO
queue and batches "requests that share a query template, split at the first
boundary".  That is exact for the paper's setting — a single transformed
loop submits one template with varying params — but it head-of-line blocks
the moment two templates interleave: a strict A,B,A,B arrival order makes
every batch degenerate to size 1.  At production scale concurrent users
issue many templates at once, and SharedDB-style shared execution says the
win comes from batching *across* concurrent queries.  So:

  * **Lanes.**  Pending requests are sharded into one lane per query
    template (``query_name``).  Each lane batches independently, so mixed
    traffic batches per-template instead of serializing.  ``sharded=False``
    restores the paper's single-queue behaviour (one lane, batches split at
    template boundaries) for A/B comparison — see
    ``benchmarks/bench_lanes.py``.
  * **In-flight deduplication.**  Identical ``(query_name, params)``
    submissions coalesce onto one pending/in-flight service call whose
    result fans out to every attached handle (SharedDB-style sharing);
    ``stats.deduped`` counts coalesced submissions.  Pure queries only —
    disable with ``dedup=False`` for effectful services.
  * **Result cache.**  Opt-in LRU (``result_cache_size``) serving repeat
    submissions of already-completed requests without a service call
    (``stats.cache_hits``), with TTL expiry (``result_cache_ttl``) and an
    explicit :meth:`invalidate` hook for write-through services.
  * **Adaptive feedback.**  Every service call's ``(batch_size, duration)``
    is reported to ``strategy.observe`` so cost-learning strategies
    (:class:`~repro.core.strategies.AdaptiveCost`) can fit the service's
    fixed-vs-per-item cost model online.
  * **Per-lane policy** (``policy=``): a
    :class:`~repro.core.lane_policy.LanePolicy` replaces the one global
    strategy with per-lane instances, the one global ``max_pending`` with
    per-tenant / per-lane quotas (``submit(..., tenant=...)``), picks lanes
    by weighted fair queueing, and canonicalizes projection-only template
    variants onto one shared lane (explicitly via ``policy.share`` or
    auto-detected from ``policy.describe`` metadata).

**Lock-sharded hot path.**  Asynchronous submission only wins when
submission itself is cheap (the paper's whole premise), so since the
lock-sharding refactor NO global lock exists on the submit/fetch/worker
path.  Synchronization is sharded to match the sharded data:

  * each **lane** has its own lock guarding only its pending deque;
  * the **dedup registries** (queued/in-flight request identity) are
    striped across ``n_stripes`` locks keyed by request hash;
  * **handle state** (results, errors, pending metadata) is striped the
    same way, each stripe with its own condition variable — a delivery
    wakes only fetchers hashed to that stripe, not every blocked thread;
  * workers block on a :class:`~repro.core.concurrency.ReadyLanes` queue
    of lanes that have pending work (weighted-fair pop under a policy)
    instead of polling a global CV and scanning idle lanes;
  * **quota waits** sleep on per-tenant / per-lane
    :class:`~repro.core.concurrency.QuotaGate` condition variables and are
    woken by the release that frees a slot — no fixed-interval polling
    anywhere in the quota path;
  * batch deliveries are fanned out per stripe after the service call,
    outside any lane lock;
  * stats counters are :class:`~repro.core.concurrency.ShardedCounter`
    stripes, so producers do not convoy on bookkeeping.

Lock-ordering rules live in ROADMAP.md ("Locking model"); the frozen
global-lock PR 2 implementation survives as
:class:`~repro.core.runtime_baseline.GlobalLockRuntime` for the Part 5
contention benchmark's A/B.

The paper-facing API is unchanged:

  * ``submit(query_name, params) -> handle``  (non-blocking ``submitQuery``)
  * ``fetch(handle)`` blocks on the result cache keyed by loop context
  * a thread pool of ``n_threads`` workers ("connections") drains lanes,
    executing a take of 1 individually and k>1 as one set-oriented
    ``service.execute_batch`` (the runtime query rewrite), splitting the
    result set back per request.

Production extras carried over:

  * **straggler mitigation**: ``fetch`` past ``straggler_timeout``
    re-submits the request so another lane/connection retries; first
    result wins, duplicates are dropped idempotently.  The deadline is
    recomputed against the handle's own (canonical) lane after each
    resubmit, measured from when the duplicate is actually enqueued.
  * **bounded queue** (§8 memory overheads): ``submit`` blocks when more
    than ``max_pending`` requests are outstanding (producer back-off).
  * **batch-size traces**, also per lane (``stats.lane_traces``) for
    Fig. 10-style analysis of each template's ramp.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Optional

from repro.core.concurrency import QuotaGate, ReadyLanes, ShardedCounter
from repro.core.lane_policy import LanePolicy
from repro.core.resilience import (DeadlineExceeded, FailureDomain,
                                   Resilience, ServiceCardinalityError)
from repro.core.services import QueryService
from repro.core.strategies import BatchingStrategy, PureAsync

__all__ = ["Handle", "AsyncQueryRuntime", "RuntimeStats"]

_SINGLE_LANE = "__single__"  # lane key in sharded=False compatibility mode


@dataclasses.dataclass(frozen=True)
class Handle:
    """Loop-context key for one submitted request (paper: ``ctx``)."""

    key: int
    query_name: str

    def __repr__(self) -> str:
        return f"<handle #{self.key} {self.query_name}>"


class RuntimeStats:
    """Runtime counters, striped across locks so the hot path never convoys
    on bookkeeping.  Fields compare/convert like numbers
    (:class:`~repro.core.concurrency.ShardedCounter`); ``snapshot`` returns
    plain JSON-safe values.  Trace lists rely on the GIL's atomic
    ``list.append``; per-lane trace lists are only appended under that
    lane's own lock."""

    _COUNTERS = (
        "submitted",
        "completed",
        "single_executions",
        "batch_executions",
        "resubmissions",
        "deduped",      # submissions coalesced onto a pending/in-flight call
        "cache_hits",   # submissions served from the completed-result LRU
        "cache_expired",  # LRU entries dropped because their TTL lapsed
        "shared",       # submissions rerouted onto a canonical lane (projection)
        "quota_waits",  # submissions that blocked on a quota / back-pressure bound
        # failure domain (resilience=Resilience(...)):
        "failures",     # service calls that raised (before any retry verdict)
        "retries",      # re-executions after a retryable failure
        "fissions",     # failed batches split to isolate failing params
        "breaker_trips",      # circuit breakers tripped closed -> open
        "shed_submissions",   # requests executed on the breaker's shed path
        "deadline_expired",   # handles resolved with DeadlineExceeded at fetch
    )

    def __init__(self):
        for name in self._COUNTERS:
            setattr(self, name, ShardedCounter())
        self.batch_trace: list = []  # (seq, size)
        # per-lane (seq, size) traces; lane key == query template (or __single__)
        self.lane_traces: dict = {}

    def snapshot(self) -> dict:
        """Plain JSON-safe copy of every counter and trace."""
        d = {name: int(getattr(self, name)) for name in self._COUNTERS}
        # dict()/list() copies are single C-level ops (no GIL release), so
        # snapshotting while workers insert new lanes cannot hit
        # "dictionary changed size during iteration".
        d["batch_trace"] = list(self.batch_trace)
        d["lane_traces"] = {k: list(v) for k, v in dict(self.lane_traces).items()}
        d["batch_sizes"] = [s for _, s in d["batch_trace"] if s > 1]
        d["mean_batch_size"] = self.mean_batch_size
        return d

    @property
    def mean_batch_size(self) -> float:
        """Mean take size over every execution (singles included)."""
        trace = self.batch_trace
        if not trace:
            return 0.0
        return sum(s for _, s in trace) / len(trace)


class _Entry:
    """One service call's worth of work: a params tuple plus every handle
    key whose submission coalesced onto it (dedup fan-out).  ``keys`` is
    mutated/snapshotted only under the request's req-stripe lock (or never
    shared, for unhashable params)."""

    __slots__ = ("keys", "query_name", "params")

    def __init__(self, key: int, query_name: str, params: tuple):
        self.keys = [key]
        self.query_name = query_name
        self.params = params


class _Lane:
    """One query template's pending deque behind its own lock."""

    __slots__ = ("key", "lock", "entries", "dead", "parked")

    def __init__(self, key: str):
        self.key = key
        self.lock = threading.Lock()
        self.entries: deque[_Entry] = deque()
        self.dead = False  # set (under lock) when GC'd out of the registry
        # parked: a worker consulted the strategy and was told to wait
        # (decide() <= 0 with work queued) — the next submit must re-queue
        # the lane so the strategy is re-asked with the larger backlog.
        self.parked = False


class _HandleStripe:
    """One stripe of handle-keyed state: results/errors plus pending
    metadata, with a condition variable that only this stripe's fetchers
    sleep on."""

    __slots__ = ("lock", "cv", "results", "errors", "pending")

    def __init__(self):
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)
        self.results: dict[int, Any] = {}
        self.errors: dict[int, BaseException] = {}
        self.pending: dict[int, _Pending] = {}


class _Pending:
    """Per-handle metadata while unresolved: where it runs, how to project
    its result, which quota slots to release on delivery, and the absolute
    monotonic deadline (``None`` = no deadline) after which ``fetch``
    resolves the handle with :class:`DeadlineExceeded`."""

    __slots__ = ("lane_query", "params", "projector", "slots", "deadline")

    def __init__(self, lane_query, params, projector, slots, deadline=None):
        self.lane_query = lane_query
        self.params = params
        self.projector = projector
        self.slots = slots
        self.deadline = deadline


class _ReqStripe:
    """One stripe of request-identity state (dedup registries)."""

    __slots__ = ("lock", "queued", "inflight")

    def __init__(self):
        self.lock = threading.Lock()
        self.queued: dict[tuple, _Entry] = {}
        self.inflight: dict[tuple, _Entry] = {}


class _ResultCache:
    """Sharded LRU + TTL result cache.  ``n_stripes=1`` (the default)
    preserves exact global LRU order; more stripes trade LRU exactness for
    lock spread (each stripe keeps its own LRU over ~size/n entries)."""

    def __init__(self, size: int, ttl: Optional[float], n_stripes: int = 1):
        n_stripes = max(1, min(n_stripes, size))
        self._locks = [threading.Lock() for _ in range(n_stripes)]
        self._maps: list[OrderedDict] = [OrderedDict() for _ in range(n_stripes)]
        self._cap = -(-size // n_stripes)  # ceil: total capacity >= size
        self._ttl = ttl

    def _idx(self, req: tuple) -> int:
        return hash(req) % len(self._maps)

    def get(self, req: tuple) -> tuple:
        """``(value, fresh, n_expired)`` — expires TTL'd entries on read."""
        i = self._idx(req)
        with self._locks[i]:
            m = self._maps[i]
            hit = m.get(req)
            if hit is None:
                return None, False, 0
            value, deadline = hit
            if deadline is not None and time.monotonic() >= deadline:
                del m[req]
                return None, False, 1
            m.move_to_end(req)
            return value, True, 0

    def put(self, req: tuple, value: Any) -> None:
        """Insert/refresh one entry (evicting LRU past stripe capacity)."""
        deadline = (time.monotonic() + self._ttl
                    if self._ttl is not None else None)
        i = self._idx(req)
        with self._locks[i]:
            m = self._maps[i]
            m[req] = (value, deadline)
            m.move_to_end(req)
            while len(m) > self._cap:
                m.popitem(last=False)

    def invalidate(self, query_name: Optional[str],
                   params: Optional[tuple], req_key_fn) -> int:
        """Drop everything / one template's entries / one entry; returns
        the number of entries removed."""
        if query_name is None:
            n = 0
            for lock, m in zip(self._locks, self._maps):
                with lock:
                    n += len(m)
                    m.clear()
            return n
        if params is not None:
            rk = req_key_fn(query_name, params)
            if rk is None:
                return 0
            i = self._idx(rk)
            with self._locks[i]:
                if rk in self._maps[i]:
                    del self._maps[i][rk]
                    return 1
            return 0
        n = 0
        for lock, m in zip(self._locks, self._maps):
            with lock:
                victims = [k for k in m if k[0] == query_name]
                for k in victims:
                    del m[k]
                n += len(victims)
        return n


class AsyncQueryRuntime:
    """The runtime library of §4.2 + §5.2, sharded into per-template lanes
    with lock-sharded synchronization (see module docstring).

    May be used directly (``submit``/``fetch``) or as the service behind the
    HIR :class:`~repro.core.hir.Interpreter` for transformed programs.
    """

    def __init__(
        self,
        service: QueryService,
        n_threads: int = 10,
        strategy: Optional[BatchingStrategy] = None,
        max_pending: Optional[int] = None,
        straggler_timeout: Optional[float] = None,
        sharded: bool = True,
        dedup: bool = True,
        result_cache_size: int = 0,
        result_cache_ttl: Optional[float] = None,
        policy: Optional[LanePolicy] = None,
        n_stripes: int = 16,
        result_cache_stripes: int = 1,
        resilience: Optional[Resilience] = None,
    ):
        if policy is not None and strategy is not None:
            raise ValueError(
                "pass either a global `strategy` or a per-lane `policy`, not both"
            )
        if n_stripes < 1:
            raise ValueError("n_stripes must be >= 1")
        self.service = service
        self.policy = policy
        self.strategy = strategy or PureAsync()
        self.strategy.reset()
        self.n_threads = n_threads
        self.max_pending = max_pending
        self.straggler_timeout = straggler_timeout
        self.sharded = sharded
        self.dedup = dedup

        # lane registry: lane key -> _Lane; lookups are lock-free dict reads,
        # creation/GC go through _lanes_lock (GC also takes the lane's lock).
        self._lanes: dict[str, _Lane] = {}
        self._lanes_lock = threading.Lock()
        self._ready = ReadyLanes()

        # striped handle/request state (power-of-two mask for cheap hashing)
        n_stripes = 1 << (n_stripes - 1).bit_length()
        self._stripe_mask = n_stripes - 1
        self._stripes = [_HandleStripe() for _ in range(n_stripes)]
        self._req_stripes = [_ReqStripe() for _ in range(n_stripes)]

        self._cache = (
            _ResultCache(result_cache_size, result_cache_ttl,
                         result_cache_stripes)
            if result_cache_size else None
        )

        # admission gates: created on demand per tenant / lane, plus one
        # global gate when max_pending bounds total outstanding requests.
        self._gates_lock = threading.Lock()
        self._tenant_gates: dict[str, QuotaGate] = {}
        self._lane_gates: dict[str, QuotaGate] = {}
        self._global_gate = QuotaGate() if max_pending is not None else None

        self._key_seq = itertools.count()   # handle keys (atomic under GIL)
        self._exec_seq = itertools.count()  # execution sequence for traces
        self._producer_done = False
        self._shutdown = False
        self._drain_cv = threading.Condition()
        self._drain_waiters = 0
        self.stats = RuntimeStats()

        # Failure domain (None = legacy semantics: no retries, a failed
        # batch delivers its one exception to every waiter).  With a
        # Resilience config the worker path retries with backoff under a
        # per-lane budget, fissions failed batches to isolate failing
        # params, sheds breaker-open lanes to direct synchronous
        # execution, and fetch enforces per-request deadlines.
        self.resilience = resilience
        self._fd = (
            FailureDomain(resilience,
                          on_trip=lambda: self.stats.breaker_trips.add())
            if resilience is not None else None
        )

        self._threads = [
            threading.Thread(target=self._worker, name=f"aqr-worker-{i}", daemon=True)
            for i in range(n_threads)
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------ API
    def submit(self, query_name: str, params: tuple,
               tenant: Optional[str] = None,
               deadline: Optional[float] = None) -> Handle:
        """Non-blocking query submission (``submitQuery``).  Blocks only at an
        admission bound: the global ``max_pending`` (§8 producer back-off), or
        — with a :class:`LanePolicy` — this tenant's / this lane's quota.
        Blocked submissions sleep on that bound's own condition variable and
        are woken by the release that frees a slot (never by a timer).

        With a policy, templates registered via ``policy.share`` (or
        auto-detected from ``policy.describe`` metadata) are canonicalized
        onto their shared lane here; the submission's own projection is
        applied at result fan-out.

        ``deadline`` (seconds, relative; default the resilience config's
        ``deadline``) bounds how long this handle's ``fetch`` waits: past
        it the handle resolves with a typed
        :class:`~repro.core.resilience.DeadlineExceeded` at its fetch
        point — the exception-semantics-preserving way to time out.
        """
        policy = self.policy
        if policy is None:
            lane_query, projector = query_name, None
        elif self.sharded:
            # One policy-lock acquisition per submit: resolve the shared
            # routing AND note the submission on the canonical lane in a
            # single critical section (the lane key IS the canonical query
            # when sharded).  The note lands before the quota wait below —
            # a blocked submission still warms its lane's temperature.
            lane_query, projector = policy.resolve_submit(query_name)
        else:
            # Single-queue compatibility mode: the lane key is not the
            # query name, so the fold doesn't apply — note the one lane.
            lane_query, projector = policy.resolve(query_name)
            policy.note_submit(_SINGLE_LANE)
        lk = self._lane_key(lane_query)

        slots = self._acquire_slots(lk, tenant)  # may block; raises on shutdown

        key = next(self._key_seq)
        handle = Handle(key, query_name)
        self.stats.submitted.add()
        self._producer_done = False
        if projector is not None:
            self.stats.shared.add()

        req = self._req_key(lane_query, params)
        stripe = self._handle_stripe(key)

        # 1) completed-result cache (SharedDB-style reuse across time)
        if req is not None and self._cache is not None:
            value, fresh, expired = self._cache.get(req)
            if expired:
                self.stats.cache_expired.add(expired)
            if fresh:
                self._deliver_cached(stripe, key, value, projector, slots)
                return handle

        # Register pending metadata BEFORE the key can become discoverable
        # through an entry, so a racing delivery always finds the projector
        # and the quota slots to release.
        eff = deadline
        if eff is None and self.resilience is not None:
            eff = self.resilience.deadline
        meta = _Pending(lane_query, params, projector, slots,
                        time.monotonic() + eff if eff is not None else None)
        with stripe.lock:
            stripe.pending[key] = meta

        # 2) in-flight/pending dedup (sharing across concurrent users)
        if req is not None and self.dedup:
            rs = self._req_stripe(req)
            value = None
            with rs.lock:
                live = rs.queued.get(req) or rs.inflight.get(req)
                if live is not None:
                    live.keys.append(key)
                    self.stats.deduped.add()
                    return handle
                # Re-probe the cache under the registry lock: _complete
                # caches BEFORE it unregisters, so an identical request
                # that just completed (after the optimistic probe above
                # missed) is guaranteed visible here — no gap in which a
                # twin re-executes.  Cache locks are leaves; ordering
                # req-stripe → cache is one-way.
                if self._cache is not None:
                    value, fresh, expired = self._cache.get(req)
                else:
                    fresh, expired = False, 0
                if not fresh:
                    entry = _Entry(key, lane_query, params)
                    # registered before the lane append: a worker cannot
                    # pick (and complete) the entry until it is in the
                    # lane, so the registry can never outlive a completed
                    # entry.
                    rs.queued[req] = entry
            if expired:
                self.stats.cache_expired.add(expired)
            if fresh:
                self._deliver_cached(stripe, key, value, projector, slots)
                return handle
        else:
            entry = _Entry(key, lane_query, params)

        # 3) enqueue on this template's lane
        self._append_entry(lk, entry)
        return handle

    def producer_done(self) -> None:
        """Signal that no more requests are coming (enables PureBatch and
        lets adaptive strategies drain the tail)."""
        self._producer_done = True
        # Wake parked lanes: a strategy that answered "wait" is re-asked now.
        self._ready.push_all(
            lk for lk, lane in list(self._lanes.items()) if lane.entries
        )

    def fetch(self, handle: Optional[Handle]) -> Any:
        """Blocking result fetch (``fetchResult`` / ``getResultSet(ctx)``).
        ``None`` handles (guarded-away submissions, Rule B) return ``None``.
        Waits only on the handle's own stripe CV — a delivery wakes this
        stripe's fetchers, not every blocked thread in the process.
        """
        if handle is None:
            return None
        key = handle.key
        stripe = self._handle_stripe(key)
        deadline = (
            time.monotonic() + self.straggler_timeout
            if self.straggler_timeout is not None
            else None
        )
        t_start = time.monotonic()
        with stripe.lock:
            meta = stripe.pending.get(key)
            req_deadline = meta.deadline if meta is not None else None
        while True:
            with stripe.lock:
                if key in stripe.errors:
                    raise stripe.errors[key]
                if key in stripe.results:
                    return stripe.results[key]
                if self._shutdown:
                    raise RuntimeError("runtime is shut down")
                now = time.monotonic()
                if req_deadline is not None and now >= req_deadline:
                    # Resolve the handle with a typed error AT ITS FETCH
                    # POINT (the paper's exception-semantics discipline
                    # applied to timeouts).  First resolver wins: pop the
                    # pending meta so a late worker delivery becomes an
                    # idempotent no-op and slots are released exactly once.
                    meta = stripe.pending.pop(key, None)
                    if meta is None:
                        continue  # delivery raced us; loop re-checks
                    err = DeadlineExceeded(handle.query_name, req_deadline,
                                           now - t_start)
                    stripe.errors[key] = err
                    stripe.cv.notify_all()
                else:
                    timeout = None
                    if deadline is not None:
                        timeout = deadline - now
                    if req_deadline is not None:
                        rem = req_deadline - now
                        timeout = rem if timeout is None else min(timeout, rem)
                    if timeout is None:
                        stripe.cv.wait()
                        continue
                    if timeout > 0:
                        stripe.cv.wait(timeout=timeout)
                        continue
                    if req_deadline is not None and deadline is not None \
                            and req_deadline <= deadline:
                        continue  # deadline branch handles it next pass
                    err = None
            if err is not None:
                # Deadline fired: release admission slots and account the
                # handle as completed (errored) outside the stripe lock.
                if meta.slots is not None:
                    self._release_slots(meta.slots)
                self.stats.deadline_expired.add()
                self.stats.completed.add()
                self._notify_drain()
                raise err
            # Straggler: re-enqueue OUTSIDE the stripe lock so the duplicate
            # goes through the normal lane path, then restart the clock
            # against the handle's own (canonical) lane from the moment the
            # duplicate is actually queued — not from when the timeout fired.
            self._resubmit(handle)
            deadline = time.monotonic() + self.straggler_timeout

    def execute(self, query_name: str, params: tuple) -> Any:
        """Synchronous single-query escape hatch (the HIR interpreter's
        untransformed path): delegates straight to the service, bypassing
        lanes, dedup and the cache."""
        return self.service.execute(query_name, params)

    def drain(self) -> None:
        """Block until every submitted request has a result."""
        self.producer_done()
        with self._drain_cv:
            self._drain_waiters += 1
            try:
                while int(self.stats.completed) < int(self.stats.submitted):
                    # Completions signal this CV whenever a drainer is
                    # registered; the timeout is a crash-safety net, not the
                    # wakeup mechanism.
                    self._drain_cv.wait(timeout=0.5)
            finally:
                self._drain_waiters -= 1

    def shutdown(self) -> None:
        """Stop the worker pool and wake every blocked fetcher / submitter /
        drainer (they observe the shutdown flag and raise).  Pending work is
        abandoned; call :meth:`drain` first for a clean stop."""
        self._shutdown = True
        self._ready.close()
        with self._gates_lock:
            gates = list(self._tenant_gates.values())
            gates += list(self._lane_gates.values())
        if self._global_gate is not None:
            gates.append(self._global_gate)
        for g in gates:
            g.notify_all()
        for stripe in self._stripes:
            with stripe.lock:
                stripe.cv.notify_all()
        with self._drain_cv:
            self._drain_cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.drain()
        self.shutdown()
        return False

    # ------------------------------------------------------------ internals
    def _req_key(self, query_name: str, params: tuple) -> Optional[tuple]:
        """Request identity for dedup/caching; None if params unhashable."""
        try:
            hash(params)
        except TypeError:
            return None
        return (query_name, params)

    def _lane_key(self, query_name: str) -> str:
        return query_name if self.sharded else _SINGLE_LANE

    def _handle_stripe(self, key: int) -> _HandleStripe:
        return self._stripes[key & self._stripe_mask]

    def _req_stripe(self, req: tuple) -> _ReqStripe:
        return self._req_stripes[hash(req) & self._stripe_mask]

    # ------------------------------------------------------------ cache API
    def invalidate(self, query_name: Optional[str] = None,
                   params: Optional[tuple] = None) -> int:
        """Explicit result-cache invalidation hook (the complement of TTL
        expiry, for services whose writes are visible to the caller).

        ``invalidate()`` drops everything; ``invalidate(q)`` drops every
        cached result of template ``q``; ``invalidate(q, params)`` drops one
        entry.  Shared (projection) variants resolve to their canonical
        template first.  Returns the number of entries dropped.
        """
        if self._cache is None:
            return 0
        if query_name is not None and self.policy is not None:
            query_name = self.policy.resolve(query_name)[0]
        return self._cache.invalidate(query_name, params, self._req_key)

    # ------------------------------------------------------- quota plumbing
    _GATE_SWEEP_AT = 1024  # registry size that triggers an idle-gate sweep

    def _gate(self, registry: dict, key: str) -> QuotaGate:
        gate = registry.get(key)
        if gate is None:
            with self._gates_lock:
                gate = registry.get(key)
                if gate is None:
                    if len(registry) >= self._GATE_SWEEP_AT:
                        # High-cardinality churn (per-entity lanes, one-shot
                        # tenants) must not grow the registries without
                        # bound: drop idle gates, amortized over creations.
                        for k, g in list(registry.items()):
                            if g.try_gc():
                                del registry[k]
                    gate = registry[key] = QuotaGate()
        return gate

    def _acquire_slots(self, lane_key: str, tenant: Optional[str]) -> tuple:
        """Reserve one slot at every admission bound that applies, blocking
        on the *full* bound's own CV.  Returns the gates holding a slot (to
        release at delivery).  To stay deadlock-free across bounds, slots
        already held are given back before sleeping, then the whole set is
        re-acquired — a blocked whale never pins a lane slot it cannot use.

        Registry-backed gates are re-validated after each acquire: a gate
        swept out of its registry between lookup and acquire no longer
        bounds anything, so the slot is given back and the live gate is
        re-resolved.
        """
        policy = self.policy
        if policy is not None:
            tq = policy.tenant_quota(tenant)
            lq = policy.lane_quota
        else:
            tq = lq = None
        if self._global_gate is None and tq is None and lq is None:
            if self._shutdown:
                raise RuntimeError("runtime is shut down")
            return ()

        acquired: list = []
        blocked = False
        try:
            while True:
                if self._shutdown:
                    raise RuntimeError("runtime is shut down")
                # (gate, limit, registry, key); registry None = never swept
                need: list = []
                if self._global_gate is not None:
                    need.append((self._global_gate, self.max_pending,
                                 None, None))
                if tq is not None:
                    need.append((self._gate(self._tenant_gates, tenant), tq,
                                 self._tenant_gates, tenant))
                if lq is not None:
                    need.append((self._gate(self._lane_gates, lane_key), lq,
                                 self._lane_gates, lane_key))
                full = None
                stale = False
                for gate, limit, registry, key in need:
                    if not gate.try_acquire(limit):
                        full = (gate, limit)
                        break
                    if registry is not None and registry.get(key) is not gate:
                        gate.release()  # swept while we acquired: re-resolve
                        stale = True
                        break
                    acquired.append(gate)
                if full is None and not stale:
                    slots = tuple(acquired)
                    acquired = []
                    return slots
                for g in acquired:
                    g.release()
                acquired = []
                if stale:
                    continue
                if not blocked:
                    blocked = True
                    self.stats.quota_waits.add()
                gate, limit = full
                gate.wait_below(limit, lambda: self._shutdown)
        finally:
            for g in acquired:  # only on exception paths
                g.release()

    def _release_slots(self, slots: tuple) -> None:
        for g in slots:
            g.release()

    # ------------------------------------------------------- lane plumbing
    def _append_entry(self, lane_key: str, entry: _Entry,
                      skip_if=None) -> bool:
        """Append under the lane lock and schedule the lane if needed.

        The ready push happens only on the empty→nonempty transition (or
        when the lane is parked): a nonempty lane is already covered — by
        its pending ready entry, or by the worker that left it nonempty
        and re-pushes it after releasing the lane lock.  This keeps the
        shared ready queue off the per-submission hot path once lanes are
        flowing.

        ``skip_if(lane)`` (checked under the lane lock) aborts the append
        — the straggler path uses it to avoid piling up duplicates of a
        handle that is already queued again.  Returns whether the entry
        was appended.
        """
        while True:
            lane = self._lanes.get(lane_key)
            if lane is None:
                with self._lanes_lock:
                    lane = self._lanes.get(lane_key)
                    if lane is None:
                        lane = self._lanes[lane_key] = _Lane(lane_key)
                        self.stats.lane_traces.setdefault(lane_key, [])
            with lane.lock:
                if lane.dead:
                    continue  # lost a race with GC: re-resolve the registry
                if skip_if is not None and skip_if(lane):
                    return False
                wake = not lane.entries or lane.parked
                lane.parked = False
                lane.entries.append(entry)
                break
        if wake:
            self._ready.push(lane_key)
        return True

    def _maybe_gc_lane(self, lane_key: str, lane: _Lane) -> None:
        """GC drained lanes so high-cardinality template churn doesn't grow
        the registry (traces keep the history).  ``dead`` closes the race
        with a submitter holding a stale reference: it re-resolves."""
        with self._lanes_lock:
            with lane.lock:
                if not lane.entries and self._lanes.get(lane_key) is lane:
                    lane.dead = True
                    del self._lanes[lane_key]

    def _resubmit(self, handle: Handle) -> bool:
        """Duplicate a straggler onto its own lane (dedup bypassed on
        purpose: the point is a racing duplicate call)."""
        key = handle.key
        stripe = self._handle_stripe(key)
        with stripe.lock:
            if key in stripe.results or key in stripe.errors:
                return False  # resolved while we were timing out
            meta = stripe.pending.get(key)
            if meta is None:
                return False
            lane_query, params = meta.lane_query, meta.params
        lk = self._lane_key(lane_query)
        appended = self._append_entry(
            lk, _Entry(key, lane_query, params),
            # already queued again (an earlier timeout's duplicate): skip
            skip_if=lambda lane: any(key in e.keys for e in lane.entries),
        )
        if appended:
            self.stats.resubmissions.add()
        return appended

    # ------------------------------------------------------- worker internals
    def _take(self, lane_key: str) -> Optional[tuple]:
        """Pop a batch from one ready lane under ITS lock only.  Returns
        ``(query_name, entries)`` or None (stale pop / strategy says wait —
        the next submit or ``producer_done`` re-queues the lane)."""
        lane = self._lanes.get(lane_key)
        if lane is None:
            return None
        first_q: Optional[str] = None
        picked: list[_Entry] = []
        with lane.lock:
            if not lane.dead and lane.entries:
                strategy = (self.policy.strategy_for(lane_key)
                            if self.policy is not None else self.strategy)
                take = strategy.decide(len(lane.entries), self._producer_done)
                if take <= 0:
                    # Strategy says wait.  Park: the next submit (or
                    # producer_done) re-queues the lane so the strategy is
                    # re-asked with the larger backlog.
                    lane.parked = True
                    return None
                lane.parked = False
                take = min(take, len(lane.entries))
                # Batches must share a query template.  Sharded lanes are
                # homogeneous by construction; the single-queue compatibility
                # mode splits at the first boundary (the paper's behaviour).
                first_q = lane.entries[0].query_name
                while lane.entries and len(picked) < take:
                    if lane.entries[0].query_name != first_q:
                        break
                    entry = lane.entries.popleft()
                    rk = self._req_key(entry.query_name, entry.params)
                    if rk is not None and self.dedup:
                        rs = self._req_stripe(rk)
                        with rs.lock:
                            if rs.queued.get(rk) is entry:
                                del rs.queued[rk]
                            if rk not in rs.inflight:
                                rs.inflight[rk] = entry
                    picked.append(entry)
                if self.policy is not None and self.policy.lane_weights:
                    self.policy.charge(lane_key, len(picked))
                seq = next(self._exec_seq)
                self.stats.batch_trace.append((seq, len(picked)))
                self.stats.lane_traces.setdefault(lane_key, []).append(
                    (seq, len(picked)))
                if len(picked) == 1:
                    self.stats.single_executions.add()
                else:
                    self.stats.batch_executions.add()
            more = bool(lane.entries) and not lane.dead
        if more:
            # Leftover backlog: stay scheduled so another worker (or this
            # one, next round) keeps draining the lane.
            self._ready.push(lane_key)
        elif not lane.dead:
            # A submit racing this GC re-resolves the registry and pushes
            # the lane ready itself, so no pick is ever stranded.
            self._maybe_gc_lane(lane_key, lane)
        if picked:
            return first_q, picked
        return None

    def _observe(self, lane_key: str, batch_size: int, duration: float) -> None:
        """Route service-call feedback to the deciding model: the lane's own
        (policy mode) or the global strategy."""
        if self.policy is not None:
            self.policy.observe(lane_key, batch_size, duration)
        else:
            self.strategy.observe(batch_size, duration)

    def _observe_failure(self, lane_key: str, duration: float) -> None:
        """Route a failed-call observation to the deciding cost model (it
        feeds the adaptive threshold's failure penalty, not the service-time
        estimate — failed calls are often fast-failing and would corrupt
        the latter)."""
        if self.policy is not None:
            self.policy.observe_failure(lane_key, duration)
        else:
            self.strategy.observe_failure(duration)

    # ------------------------------------------------- resilient execution
    def _execute_once(self, query_name: str, picked: list) -> list:
        """One service call for the picked entries; normalizes the batch /
        single split and validates result cardinality (a service returning
        the wrong number of rows is a non-retryable contract violation —
        guessing an alignment would deliver values to the wrong handles)."""
        if len(picked) == 1:
            out = [self.service.execute(query_name, picked[0].params)]
        else:
            out = self.service.execute_batch(
                query_name, [e.params for e in picked]
            )
            out = list(out)
        if len(out) != len(picked):
            raise ServiceCardinalityError(query_name, len(picked), len(out))
        return out

    def _call_with_retry(self, lane_key: str, query_name: str, picked: list,
                         breaker) -> tuple:
        """Execute with bounded retry + exponential backoff + deterministic
        jitter, spending the lane's retry budget (earned back by successes,
        so a persistent failure can't turn into a retry storm).  Returns
        ``(out, None)`` on success, ``(None, last_exception)`` on final
        failure.  Success/failure is reported to the breaker and to the
        cost model's failure penalty."""
        fd = self._fd
        policy = fd.retry
        budget = fd.budget(lane_key)
        last: Optional[BaseException] = None
        for attempt in range(max(1, policy.max_attempts)):
            if attempt > 0:
                self.stats.retries.add()
                policy.sleep_backoff(attempt, lane_key)
            t0 = time.perf_counter()
            try:
                out = self._execute_once(query_name, picked)
            except BaseException as e:  # noqa: BLE001 — propagate via fetch
                last = e
                self.stats.failures.add()
                self._observe_failure(lane_key, time.perf_counter() - t0)
                if breaker is not None:
                    breaker.record_failure()
                if not policy.is_retryable(e):
                    break
                # The budget caps retry *amplification*: re-executing an
                # n-entry batch multiplies load n-fold, so batch retries
                # spend tokens.  A single entry's bounded retries can't
                # amplify beyond max_attempts and must stay available even
                # with a dry budget — otherwise a first-attempt transient
                # leaks to a fetcher that fault-free semantics say succeeds.
                if len(picked) > 1 and not budget.try_spend():
                    break
                continue
            self._observe(lane_key, len(picked), time.perf_counter() - t0)
            budget.earn()
            if breaker is not None:
                breaker.record_success()
            return out, None
        return None, last

    def _execute_shed(self, lane_key: str, query_name: str,
                      picked: list) -> tuple:
        """Tripped-breaker degraded mode: per-entry direct synchronous
        execution (no batching) so each request still resolves — with its
        own value or its own error — while the lane's batch path cools
        down.  Transient faults are still retried per entry (bounded by
        ``max_attempts``, exempt from the budget: single-entry retries
        can't amplify into a storm, and exception semantics must survive
        degradation), and successes earn the budget back so the bucket is
        refilled by the time the breaker closes.  No breaker feedback is
        recorded: shed traffic must not hold the breaker open — the
        half-open probes decide recovery."""
        fd = self._fd
        policy = fd.retry
        budget = fd.budget(lane_key)
        self.stats.shed_submissions.add(len(picked))
        out: list = []
        errs: list = []
        any_err = False
        for entry in picked:
            err: Optional[BaseException] = None
            value = None
            for attempt in range(max(1, policy.max_attempts)):
                if attempt > 0:
                    self.stats.retries.add()
                    policy.sleep_backoff(attempt, (lane_key, "shed"))
                try:
                    value, err = self.service.execute(
                        query_name, entry.params), None
                    budget.earn()
                    break
                except BaseException as e:  # noqa: BLE001 — own delivery
                    err = e
                    self.stats.failures.add()
                    if not policy.is_retryable(e):
                        break
            out.append(value)
            errs.append(err)
            any_err = any_err or err is not None
        return out, (errs if any_err else None)

    def _execute_resilient(self, lane_key: str, query_name: str,
                           picked: list) -> tuple:
        """Execute one picked batch under the failure domain: breaker-gated,
        retried with backoff, and — on final batch failure — fission-split
        so each param's own exception reaches exactly its own handles while
        innocent co-batched params still get values.  Returns ``(out,
        errs)`` in :meth:`_complete`'s per-entry format.  Without a
        resilience config this is the legacy one-shot path."""
        fd = self._fd
        if fd is None:
            t0 = time.perf_counter()
            try:
                out = self._execute_once(query_name, picked)
            except BaseException as e:  # noqa: BLE001 — propagate via fetch
                return None, [e] * len(picked)
            self._observe(lane_key, len(picked), time.perf_counter() - t0)
            return out, None
        breaker = fd.breaker(lane_key)
        if breaker is not None and breaker.allow() == "shed":
            return self._execute_shed(lane_key, query_name, picked)
        out, exc = self._call_with_retry(lane_key, query_name, picked, breaker)
        if exc is None:
            return out, None
        if len(picked) == 1 or not fd.config.fission:
            return None, [exc] * len(picked)
        # Batch fission-retry: binary split and recurse.  Each half re-enters
        # the resilient path (re-checking the breaker — repeated failures
        # during fission can trip it and degrade the rest to shed mode), so
        # a single poisoned param is isolated at batch-size 1, where its own
        # exception is delivered to exactly its own handles.
        self.stats.fissions.add()
        mid = len(picked) // 2
        out_lo, errs_lo = self._execute_resilient(
            lane_key, query_name, picked[:mid])
        out_hi, errs_hi = self._execute_resilient(
            lane_key, query_name, picked[mid:])
        if errs_lo is None and errs_hi is None:
            return (out_lo or []) + (out_hi or []), None
        out = ((out_lo if out_lo is not None else [None] * mid)
               + (out_hi if out_hi is not None else [None] * (len(picked) - mid)))
        errs = ((errs_lo if errs_lo is not None else [None] * mid)
                + (errs_hi if errs_hi is not None
                   else [None] * (len(picked) - mid)))
        return out, errs

    def _deliver_into(self, stripe: _HandleStripe, key: int, value: Any,
                      projector) -> None:
        """Resolve one handle (stripe lock held), applying its projection."""
        if projector is None:
            stripe.results[key] = value
            return
        try:
            stripe.results[key] = projector(value)
        except BaseException as e:  # noqa: BLE001 — surface via fetch
            stripe.errors[key] = e

    def _deliver_cached(self, stripe: _HandleStripe, key: int, value: Any,
                        projector, slots: tuple) -> None:
        """Resolve a submission from the result cache: deliver + wake the
        stripe, give back the admission slots, count the completion.  Any
        pending metadata registered for the key is discarded — the handle
        resolves here, not through a service call."""
        with stripe.lock:
            stripe.pending.pop(key, None)
            self._deliver_into(stripe, key, value, projector)
            stripe.cv.notify_all()
        self._release_slots(slots)
        self.stats.cache_hits.add()
        self.stats.completed.add()
        self._notify_drain()

    def _complete(self, picked: list, out, errs) -> None:
        """Fan one service call's results out to every attached handle —
        per handle stripe, outside any lane lock.  ``errs`` is ``None``
        (all succeeded) or a list aligned with ``picked`` holding each
        entry's own exception (``None`` for entries that succeeded) — an
        error reaches exactly the handles attached to ITS entry, and every
        dedup'd waiter of an entry gets that entry's outcome exactly once.
        Straggler duplicates (and deadline-expired handles) may already be
        resolved: first result wins, idempotently.  The stripe CV is
        signalled in a ``finally`` so no fault between delivery and wakeup
        can strand a fetcher."""
        per_stripe: dict[int, list] = {}
        for i, entry in enumerate(picked):
            err = errs[i] if errs is not None else None
            value = out[i] if err is None and out is not None else None
            rk = self._req_key(entry.query_name, entry.params)
            if err is None and rk is not None and self._cache is not None:
                # Cache before unregistering from the dedup registry: paired
                # with submit's cache re-probe under the req-stripe lock, a
                # racing identical submission sees either the live entry or
                # the cached value — never a gap that re-executes.  A cache
                # fault must not poison delivery (the result still reaches
                # its waiters; only reuse is lost).
                try:
                    self._cache.put(rk, value)
                except BaseException:  # noqa: BLE001 — best-effort reuse
                    pass
            if rk is not None and self.dedup:
                rs = self._req_stripe(rk)
                with rs.lock:
                    if rs.inflight.get(rk) is entry:
                        del rs.inflight[rk]
                    keys = list(entry.keys)  # snapshot closes the attach race
            else:
                keys = list(entry.keys)
            for key in keys:
                per_stripe.setdefault(key & self._stripe_mask, []).append(
                    (key, value, err))
        released: list = []
        n_done = 0
        for idx, items in per_stripe.items():
            stripe = self._stripes[idx]
            with stripe.lock:
                try:
                    for key, value, err in items:
                        if key in stripe.results or key in stripe.errors:
                            continue  # straggler duplicate: first result won
                        meta = stripe.pending.pop(key, None)
                        projector = (meta.projector
                                     if meta is not None else None)
                        if err is not None:
                            stripe.errors[key] = err
                        else:
                            self._deliver_into(stripe, key, value, projector)
                        n_done += 1
                        if meta is not None:
                            released.append(meta)
                finally:
                    stripe.cv.notify_all()
        for meta in released:
            self._release_slots(meta.slots)
        if n_done:
            self.stats.completed.add(n_done)
            self._notify_drain()

    def _notify_drain(self) -> None:
        if self._drain_waiters:
            with self._drain_cv:
                self._drain_cv.notify_all()

    # consecutive takes a worker may spend on one lane before it must go
    # back to the ready queue: bounds how long any other ready lane can
    # wait behind sticky workers (liveness), while still amortizing the
    # ready-queue round trip over bursts on a busy lane.
    _STICKY_TAKES = 8

    def _worker(self) -> None:
        lane_key = None  # sticky lane: drain it (boundedly) before re-pop
        sticky_left = 0
        while True:
            if self._shutdown:
                return  # abandon pending work, as the global-lock loop did
            if lane_key is None:
                # Weighted-fair selection costs a policy-lock + O(n) scan
                # per pick, and with uniform weights FIFO pop + tail
                # re-push IS fair round-robin — so consult the policy's
                # weights afresh each pop (weights may be set at any time)
                # and select only when some lane is actually weighted.
                policy = self.policy
                select = (policy.lane_min
                          if policy is not None and policy.lane_weights
                          else None)
                lane_key = self._ready.pop(select=select)
                if lane_key is None:
                    return  # queue closed: shutdown
                sticky_left = self._STICKY_TAKES
            work = self._take(lane_key)
            if work is None:
                # Lane dry (or parked): go back to the ready queue.
                lane_key = None
                continue
            query_name, picked = work

            out, errs = self._execute_resilient(lane_key, query_name, picked)
            try:
                self._complete(picked, out, errs)
            except BaseException as e:  # noqa: BLE001 — never strand fetchers
                # A fault in fan-out itself (e.g. a poisoned cache or dedup
                # registry) must still resolve every attached handle — an
                # exception mid-_complete would otherwise strand fetchers on
                # an unsignalled CV forever.  Deliveries are idempotent, so
                # re-completing the already-resolved prefix is a no-op.
                self._complete(picked, None, [e] * len(picked))
            # Sticky: keep draining this lane while it has work — the next
            # _take re-checks under the lane lock, so no ready-queue round
            # trip (lock + wakeup) is paid per batch on a busy lane.  The
            # stick is BOUNDED: after _STICKY_TAKES batches the worker
            # rotates through the ready queue (the lane was re-pushed by
            # _take if it kept a backlog), so ready lanes can never starve
            # behind stuck-in-a-groove workers.
            sticky_left -= 1
            if sticky_left <= 0:
                lane_key = None
