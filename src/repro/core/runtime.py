"""AsyncQueryRuntime — the paper's runtime asynchronous-submission framework
(§4.2, Fig. 3) with asynchronous batching (§5.2).

Layout mirrors the paper exactly:

  * ``submit(query_name, params) -> handle``  (non-blocking ``submitQuery`` /
    ``stmt.addBatch(ctx)``): enqueue the request keyed by a monotonically
    increasing loop-context key.
  * a **thread pool** of ``n_threads`` workers, each holding its own
    "connection" to the service (the paper: one JDBC connection per thread),
    monitors the queue.  A free worker asks the :class:`BatchingStrategy`
    how many pending requests to take:

        1  → execute individually (pure asynchronous submission)
        k>1→ rewrite as one set-oriented request: ``service.execute_batch``
             (the paper's runtime query rewrite), then split the result set.

  * results land in a **cache** keyed by the loop context
    (``stmt.getResultSet(ctx)`` ≡ ``fetch(handle)``), which blocks until the
    corresponding request completes.

Extras needed at production scale (system brief):

  * **straggler mitigation**: an optional per-request timeout after which a
    waiting ``fetch`` *re-submits* the request to the queue so another worker
    (connection/serving lane) retries; first result wins, duplicates are
    dropped idempotently.  This is the natural generalization of the paper's
    thread-pool model to lossy clusters.
  * **bounded queue** (§8 memory overheads): ``submit`` blocks when more
    than ``max_pending`` requests are outstanding, implementing producer
    back-off.
  * **batch-size trace** for Fig. 10-style analysis.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Optional, Sequence

from repro.core.services import QueryService
from repro.core.strategies import BatchingStrategy, PureAsync

__all__ = ["Handle", "AsyncQueryRuntime", "RuntimeStats"]


@dataclasses.dataclass(frozen=True)
class Handle:
    """Loop-context key for one submitted request (paper: ``ctx``)."""

    key: int
    query_name: str

    def __repr__(self) -> str:
        return f"<handle #{self.key} {self.query_name}>"


@dataclasses.dataclass
class RuntimeStats:
    submitted: int = 0
    completed: int = 0
    single_executions: int = 0
    batch_executions: int = 0
    resubmissions: int = 0
    batch_trace: list = dataclasses.field(default_factory=list)  # (seq, size)

    def snapshot(self) -> dict:
        d = dataclasses.asdict(self)
        d["batch_sizes"] = [s for _, s in self.batch_trace if s > 1]
        return d


class _Pending:
    __slots__ = ("handle", "params", "inflight")

    def __init__(self, handle: Handle, params: tuple):
        self.handle = handle
        self.params = params
        self.inflight = 0


class AsyncQueryRuntime:
    """The runtime library of §4.2 + §5.2.

    May be used directly (``submit``/``fetch``) or as the service behind the
    HIR :class:`~repro.core.hir.Interpreter` for transformed programs.
    """

    def __init__(
        self,
        service: QueryService,
        n_threads: int = 10,
        strategy: Optional[BatchingStrategy] = None,
        max_pending: Optional[int] = None,
        straggler_timeout: Optional[float] = None,
    ):
        self.service = service
        self.strategy = strategy or PureAsync()
        self.strategy.reset()
        self.n_threads = n_threads
        self.max_pending = max_pending
        self.straggler_timeout = straggler_timeout

        self._queue: deque[_Pending] = deque()
        self._results: dict[int, Any] = {}
        self._errors: dict[int, BaseException] = {}
        self._lock = threading.Lock()
        self._work_cv = threading.Condition(self._lock)  # queue state changed
        self._done_cv = threading.Condition(self._lock)  # a result arrived
        self._next_key = 0
        self._producer_done = False
        self._shutdown = False
        self._inflight_params: dict[int, tuple] = {}
        self.stats = RuntimeStats()

        self._threads = [
            threading.Thread(target=self._worker, name=f"aqr-worker-{i}", daemon=True)
            for i in range(n_threads)
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------ API
    def submit(self, query_name: str, params: tuple) -> Handle:
        """Non-blocking query submission (``submitQuery``).  Blocks only when
        the bounded queue is full (§8 producer back-off)."""
        with self._lock:
            while (
                self.max_pending is not None
                and len(self._queue) >= self.max_pending
                and not self._shutdown
            ):
                self._done_cv.wait(timeout=0.1)
            if self._shutdown:
                raise RuntimeError("runtime is shut down")
            handle = Handle(self._next_key, query_name)
            self._next_key += 1
            self._queue.append(_Pending(handle, params))
            self.stats.submitted += 1
            self._producer_done = False
            self._work_cv.notify()
        return handle

    def producer_done(self) -> None:
        """Signal that no more requests are coming (enables PureBatch and
        lets adaptive strategies drain the tail)."""
        with self._lock:
            self._producer_done = True
            self._work_cv.notify_all()

    def fetch(self, handle: Optional[Handle]) -> Any:
        """Blocking result fetch (``fetchResult`` / ``getResultSet(ctx)``).
        ``None`` handles (guarded-away submissions, Rule B) return ``None``.
        """
        if handle is None:
            return None
        deadline = (
            time.monotonic() + self.straggler_timeout
            if self.straggler_timeout is not None
            else None
        )
        with self._lock:
            while handle.key not in self._results and handle.key not in self._errors:
                timeout = None
                if deadline is not None:
                    timeout = max(0.0, deadline - time.monotonic())
                    if timeout == 0.0:
                        # Straggler: re-enqueue so another lane retries.
                        self._resubmit_locked(handle)
                        deadline = time.monotonic() + self.straggler_timeout
                        timeout = self.straggler_timeout
                self._done_cv.wait(timeout=timeout)
            if handle.key in self._errors:
                raise self._errors[handle.key]
            return self._results[handle.key]

    # The HIR interpreter's synchronous path delegates to the service.
    def execute(self, query_name: str, params: tuple) -> Any:
        return self.service.execute(query_name, params)

    def drain(self) -> None:
        """Block until every submitted request has a result."""
        self.producer_done()
        with self._lock:
            while self.stats.completed < self.stats.submitted:
                self._done_cv.wait(timeout=0.1)

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            self._work_cv.notify_all()
            self._done_cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.drain()
        self.shutdown()
        return False

    # ------------------------------------------------------------ internals
    def _resubmit_locked(self, handle: Handle) -> None:
        for p in self._queue:
            if p.handle.key == handle.key:
                return  # already pending again
        # Need original params: look in the inflight registry.
        params = self._inflight_params.get(handle.key)
        if params is None:
            return
        self._queue.append(_Pending(handle, params))
        self.stats.resubmissions += 1
        self._work_cv.notify()

    def _worker(self) -> None:
        while True:
            with self._lock:
                take = 0
                while not self._shutdown:
                    n = len(self._queue)
                    take = self.strategy.decide(n, self._producer_done) if n else 0
                    if take > 0:
                        break
                    self._work_cv.wait(timeout=0.05)
                if self._shutdown:
                    return
                take = min(take, len(self._queue))
                # Requests in one batch must share a query template; split at
                # the first boundary (the paper: same query, varying params).
                first_q = self._queue[0].handle.query_name
                picked: list[_Pending] = []
                while self._queue and len(picked) < take:
                    if self._queue[0].handle.query_name != first_q:
                        break
                    p = self._queue.popleft()
                    p.inflight += 1
                    self._inflight_params[p.handle.key] = p.params
                    picked.append(p)
                seq = self.stats.single_executions + self.stats.batch_executions
                self.stats.batch_trace.append((seq, len(picked)))
                if len(picked) == 1:
                    self.stats.single_executions += 1
                else:
                    self.stats.batch_executions += 1

            try:
                if len(picked) == 1:
                    out = [self.service.execute(first_q, picked[0].params)]
                else:
                    out = self.service.execute_batch(
                        first_q, [p.params for p in picked]
                    )
                err = None
            except BaseException as e:  # noqa: BLE001 — propagate via fetch
                out, err = None, e

            with self._lock:
                for i, p in enumerate(picked):
                    if p.handle.key in self._results or p.handle.key in self._errors:
                        continue  # straggler duplicate: first result won
                    if err is not None:
                        self._errors[p.handle.key] = err
                    else:
                        self._results[p.handle.key] = out[i]
                    self.stats.completed += 1
                    self._inflight_params.pop(p.handle.key, None)
                self._done_cv.notify_all()
