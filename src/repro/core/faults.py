"""Deterministic fault injection: ChaosService / ChaosEngine.

Chaos wrappers plug UNDER the runtime (any
:class:`~repro.core.services.QueryService`) and under the serving engine
(:class:`~repro.serving.engine.InferenceEngine` or any duck-typed
stand-in) and inject failures from a **seeded schedule** — every decision
is a pure hash of ``(seed, decision kind, identity)``
(:func:`~repro.core.resilience.hash_unit`), never global RNG state, so a
chaos run replays bit-identically regardless of thread interleaving and a
CI failure reproduces locally from the seed alone.

Three fault kinds, mirroring what production services actually do:

* **poisoned params** (``fail_rate``): a deterministic subset of
  ``(query_name, params)`` identities *always* fails with
  :class:`InjectedParamError` — the "genuinely failing request" whose
  exception must reach exactly its own fetch point.  A batch containing
  any poisoned param raises :class:`InjectedBatchFault` (statement-level
  poisoning, like a DB driver failing the whole multi-row statement) —
  the runtime's fission-retry splits the batch to isolate the culprits.
* **transient faults** (``transient_rate``): a subset of identities fails
  its first ``transient_repeats`` attempts with :class:`InjectedFault`
  and then succeeds — what retry/backoff exists to absorb.
* **latency spikes** (``latency_rate``/``latency``): a seeded fraction of
  calls sleeps before executing — what deadlines and stragglers absorb.

:class:`ChaosEngine` additionally injects serving-side faults: a seeded
fraction of decode ticks raises :class:`~repro.core.resilience.LaneError`
for a deterministic victim lane (the crash-recovery/quarantine path), and
a seeded fraction of prefill dispatches raises :class:`InjectedFault`
(the spec-thread crash / admission-retry path).

``REPRO_CHAOS_SEED`` is the CI knob: :func:`chaos_seed` reads it so the
chaos job can run the same suites under two different schedules.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Optional

from repro.core.resilience import LaneError, NonRetryableError, hash_unit

__all__ = [
    "ChaosEngine",
    "ChaosPlan",
    "ChaosService",
    "InjectedBatchFault",
    "InjectedFault",
    "InjectedParamError",
    "chaos_seed",
]


def chaos_seed(default: int = 0) -> int:
    """The chaos schedule seed: ``REPRO_CHAOS_SEED`` env, else ``default``."""
    return int(os.environ.get("REPRO_CHAOS_SEED", default))


class InjectedFault(RuntimeError):
    """A transient injected failure (succeeds on retry)."""


class InjectedParamError(NonRetryableError, RuntimeError):
    """A deterministically-failing param's own exception.

    Carries the identity it was injected for, so tests can assert each
    failed request raised exactly *its* exception and no one else's."""

    def __init__(self, query_name: str, params):
        super().__init__(f"injected failure for {query_name!r} {params!r}")
        self.query_name = query_name
        self.params = params


class InjectedBatchFault(RuntimeError):
    """A batch-level failure: >= 1 param in the batch is poisoned.

    Statement-level poisoning (the whole multi-param call fails); the
    runtime's fission-retry isolates which params are actually bad."""

    def __init__(self, query_name: str, n_bad: int, n_total: int):
        super().__init__(
            f"injected batch failure for {query_name!r}: "
            f"{n_bad}/{n_total} params poisoned")
        self.query_name = query_name
        self.n_bad = n_bad
        self.n_total = n_total


class ChaosPlan:
    """One seeded fault schedule, shared by service and engine wrappers.

    Stateless decisions (:meth:`poisoned`, latency draws) are pure
    hashes; the only state is the per-identity attempt counter behind
    transient faults (fail the first k attempts, then succeed), which is
    keyed by request identity — not call order — so concurrent retries
    still converge on the same schedule."""

    def __init__(self, seed: int = 0, fail_rate: float = 0.0,
                 transient_rate: float = 0.0, transient_repeats: int = 2,
                 latency_rate: float = 0.0, latency: float = 0.001,
                 decode_fault_rate: float = 0.0,
                 prefill_fault_rate: float = 0.0):
        for name in ("fail_rate", "transient_rate", "latency_rate",
                     "decode_fault_rate", "prefill_fault_rate"):
            v = locals()[name]
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        self.seed = seed
        self.fail_rate = fail_rate
        self.transient_rate = transient_rate
        self.transient_repeats = transient_repeats
        self.latency_rate = latency_rate
        self.latency = latency
        self.decode_fault_rate = decode_fault_rate
        self.prefill_fault_rate = prefill_fault_rate
        self._lock = threading.Lock()
        self._attempts: dict = {}

    # ------------------------------------------------------- service faults
    def poisoned(self, query_name: str, params) -> bool:
        """Whether this identity ALWAYS fails (deterministic in the seed)."""
        return hash_unit(self.seed, "poison", query_name,
                         params) < self.fail_rate

    def fault_for(self, query_name: str, params) -> Optional[BaseException]:
        """The exception (if any) attempt-N of this identity should raise."""
        if self.poisoned(query_name, params):
            return InjectedParamError(query_name, params)
        if hash_unit(self.seed, "transient", query_name,
                     params) < self.transient_rate:
            key = (query_name, params)
            with self._lock:
                n = self._attempts[key] = self._attempts.get(key, 0) + 1
            if n <= self.transient_repeats:
                return InjectedFault(
                    f"transient fault #{n} for {query_name!r} {params!r}")
        return None

    def latency_for(self, kind: str, index: int) -> float:
        """Injected sleep for call ``index`` of ``kind`` (0.0 = none)."""
        if hash_unit(self.seed, "latency", kind, index) < self.latency_rate:
            return self.latency
        return 0.0

    # -------------------------------------------------------- engine faults
    def decode_fault(self, tick: int) -> bool:
        """Whether decode tick ``tick`` should crash one lane."""
        return hash_unit(self.seed, "decode", tick) < self.decode_fault_rate

    def pick(self, kind: str, index: int, n: int) -> int:
        """Deterministic victim choice among ``n`` candidates."""
        return int(hash_unit(self.seed, "pick", kind, index) * n) % n


class ChaosService:
    """A :class:`~repro.core.services.QueryService` wrapper injecting the
    plan's faults ahead of the inner service.

    Poisoned params raise their own :class:`InjectedParamError` on the
    single-execute path; a batch containing any poisoned or
    currently-transient param raises (the param's own error for a 1-param
    batch, :class:`InjectedBatchFault` otherwise) so the runtime's
    fission-retry has something to isolate.  Injection counters are on
    the wrapper (``injected_single`` / ``injected_batch`` /
    ``injected_sleeps``); everything else proxies to the inner service.
    """

    def __init__(self, inner, plan: ChaosPlan):
        self.inner = inner
        self.plan = plan
        self.injected_single = 0
        self.injected_batch = 0
        self.injected_sleeps = 0
        self._calls = 0
        self._lock = threading.Lock()

    def _tick(self, kind: str) -> None:
        with self._lock:
            self._calls += 1
            n = self._calls
        dt = self.plan.latency_for(kind, n)
        if dt > 0.0:
            self.injected_sleeps += 1
            time.sleep(dt)

    def execute(self, query_name: str, params) -> object:
        """Single execution, behind the plan's faults for this identity."""
        self._tick("single")
        err = self.plan.fault_for(query_name, params)
        if err is not None:
            self.injected_single += 1
            raise err
        return self.inner.execute(query_name, params)

    def execute_batch(self, query_name: str, params_list) -> list:
        """Batched execution; any faulty member poisons the whole call."""
        self._tick("batch")
        errs = [self.plan.fault_for(query_name, p) for p in params_list]
        bad = [e for e in errs if e is not None]
        if bad:
            self.injected_batch += 1
            if len(params_list) == 1:
                raise bad[0]
            raise InjectedBatchFault(query_name, len(bad), len(params_list))
        return self.inner.execute_batch(query_name, params_list)

    def __getattr__(self, name):
        return getattr(self.inner, name)


# attributes ChaosEngine keeps on itself; everything else proxies inward
_CHAOS_ENGINE_SELF = frozenset(
    {"_engine", "plan", "injected_decode_faults", "injected_prefill_faults",
     "_decode_calls", "_prefill_calls"})


class ChaosEngine:
    """A serving-engine proxy injecting decode/prefill faults.

    A seeded fraction of :meth:`decode_tick` calls raises
    :class:`~repro.core.resilience.LaneError` for a deterministically
    chosen *active* lane BEFORE the device step runs (no token is
    half-emitted), exercising the scheduler's quarantine + KV-salvage +
    requeue recovery.  A seeded fraction of prefill dispatches (and
    ``admit``) raises :class:`InjectedFault`, exercising the spec-crash
    abort and the admission retry path.  All other attribute access —
    reads AND writes (e.g. the scheduler installing ``on_lane_evicted``)
    — proxies to the wrapped engine, so the wrapper is drop-in for any
    engine the scheduler accepts."""

    def __init__(self, engine, plan: ChaosPlan):
        object.__setattr__(self, "_engine", engine)
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "injected_decode_faults", 0)
        object.__setattr__(self, "injected_prefill_faults", 0)
        object.__setattr__(self, "_decode_calls", 0)
        object.__setattr__(self, "_prefill_calls", 0)

    def __getattr__(self, name):
        return getattr(self._engine, name)

    def __setattr__(self, name, value):
        if name in _CHAOS_ENGINE_SELF:
            object.__setattr__(self, name, value)
        else:
            setattr(self._engine, name, value)

    def _active_lanes(self) -> list:
        act = getattr(self._engine, "active", None)
        if act is None:
            return []
        # Engines expose occupancy either as a boolean vector indexed by
        # lane (the JAX engine) or as a set of active lane ids (sim
        # engines) — accept both so the wrapper stays drop-in.
        if isinstance(act, (set, frozenset)):
            return sorted(int(lane) for lane in act)
        try:
            return [int(i) for i, on in enumerate(act) if on]
        except TypeError:
            return []

    def _template_of(self, lane: int) -> Optional[str]:
        # best effort: engines don't track templates per lane; the
        # scheduler resolves the request from its own running table.
        return None

    def decode_tick(self):
        """One decode step — or an injected single-lane crash."""
        self._decode_calls += 1
        n = self._decode_calls
        if self.plan.decode_fault(n):
            lanes = self._active_lanes()
            if lanes:
                victim = lanes[self.plan.pick("victim", n, len(lanes))]
                self.injected_decode_faults += 1
                raise LaneError(victim, self._template_of(victim),
                                reason=f"injected decode fault (tick {n})")
        dt = self.plan.latency_for("decode", n)
        if dt > 0.0:
            time.sleep(dt)
        return self._engine.decode_tick()

    def _prefill_fault(self, template) -> None:
        self._prefill_calls += 1
        n = self._prefill_calls
        if hash_unit(self.plan.seed, "prefill",
                     n) < self.plan.prefill_fault_rate:
            self.injected_prefill_faults += 1
            raise InjectedFault(
                f"injected prefill fault #{n} ({template!r})")

    def admit(self, requests, template=None):
        """Synchronous admission, behind the plan's prefill faults."""
        self._prefill_fault(template)
        return self._engine.admit(requests, template=template)

    def prefill_dispatch(self, requests, template=None, chunk=None):
        """Split-path dispatch, behind the plan's prefill faults."""
        self._prefill_fault(template)
        if chunk is None:
            return self._engine.prefill_dispatch(requests, template=template)
        return self._engine.prefill_dispatch(requests, template=template,
                                             chunk=chunk)
