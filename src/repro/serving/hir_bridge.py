"""HIR → serving bridge: run transformed query programs on the scheduler.

The transformation layer rewrites application programs so their queries
arrive in cohorts instead of one-at-a-time; the serving layer's
:class:`~repro.serving.scheduler.ContinuousBatchingScheduler` is what turns
a cohort into one shared decode stream.  This module closes the loop:

* :class:`TraceSimEngine` — a deterministic latency-model engine (same
  admission surface the scheduler binds elsewhere) whose every token is a
  pure function of ``(template, prompt, position)``, so "bit-identical
  outputs" is a meaningful assertion rather than a tautology;
* :class:`SchedulerQueryService` — a
  :class:`~repro.core.services.QueryService`-shaped facade that maps each
  HIR query to one generation request.  ``execute`` drives the scheduler
  for a single request (the synchronous tax: one full drive per query);
  ``execute_batch`` submits the whole cohort and drains once (the
  transformed win: prefill amortized per template, decode ticks shared
  across lanes).  ``stats.round_trips`` counts *scheduler drives*, the
  serving analogue of the paper's round-trip count.

``benchmarks/bench_lanes.py`` Part 10 runs the app-shaped traces from
:mod:`repro.core.app_traces` through this bridge, synchronous oracle vs.
``transform_program`` output, and gates the tokens/s ratio and the
round-trip ratio in CI.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence

import numpy as np

from repro.serving.engine import KVPartition
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.core.strategies import OneOrAll

__all__ = ["TraceSimEngine", "SchedulerQueryService"]

_TOK_MOD = 50021


def _prompt_for(query_name: str, params: Sequence) -> np.ndarray:
    """Deterministic prompt encoding of one HIR query."""
    vals = [len(params)] + [int(p) % _TOK_MOD for p in params]
    return np.asarray(vals, dtype=np.int32)


def _tok(template: str, prompt: np.ndarray, i: int) -> int:
    """Token ``i`` of a request: pure function of identity and position."""
    base = int(np.sum(prompt.astype(np.int64) * 31)) % _TOK_MOD
    off = sum(ord(c) for c in template)
    return (base * 7 + off * 13 + i * 101) % _TOK_MOD


class _Staged:
    """Staged prefill (mirrors the sim engines' staged shape)."""

    __slots__ = ("template", "requests")

    def __init__(self, template, requests):
        self.template = template
        self.requests = list(requests)


class TraceSimEngine:
    """Latency-model serving engine with deterministic token emission.

    Costs follow the two-resource model of the other sim engines: a
    per-template prefill profile ``(fixed_s, per_item_s)`` paid per
    dispatch, and a decode tick costing ``decode_base + n_active *
    decode_per_lane`` — so batched admission amortizes the fixed prefill
    cost AND shares decode ticks, which is exactly the advantage the
    transformed program is supposed to harvest.  Unlike those engines,
    every emitted token is :func:`_tok` of the request's identity, so two
    runs that claim the same outputs must have generated the same tokens.
    """

    def __init__(self, n_lanes: int = 8,
                 profiles: Optional[dict] = None,
                 default_profile: tuple = (8e-4, 1e-4),
                 decode_base: float = 1.2e-3,
                 decode_per_lane: float = 5e-5,
                 sleep=None):
        import time

        self.partition = KVPartition(n_lanes)
        self.profiles = dict(profiles or {})
        self.default_profile = default_profile
        self.decode_base = decode_base
        self.decode_per_lane = decode_per_lane
        self.active: dict[int, Request] = {}  # lane -> request
        self.prefill_time = 0.0
        self.decode_steps = 0
        self._sleep = sleep if sleep is not None else time.sleep

    @property
    def kv(self):
        """The KVView the scheduler binds."""
        return self.partition

    @property
    def n_free(self):
        """Free decode lanes."""
        return self.partition.n_free

    def n_free_for(self, template):
        """Lanes ``template`` may draw."""
        return self.partition.n_free_for(template)

    def prefill_dispatch(self, requests, template=None):
        """Pay the profile's prefill cost and stage the cohort."""
        fixed, per = self.profiles.get(template, self.default_profile)
        dt = fixed + per * len(requests)
        self.prefill_time += dt
        self._sleep(dt)
        return _Staged(template, requests)

    def commit_prefill(self, staged, n=None):
        """Bind staged requests to lanes; prefill emits token 0
        deterministically (the sim engines emit a literal 0 here)."""
        reqs = staged.requests if n is None else staged.requests[:n]
        for r in reqs:
            lane = self.partition.alloc(staged.template)
            r.lane = lane
            r.generated.append(_tok(r.template, r.prompt, 0))
            self.active[lane] = r
        return (len(staged.requests), 8)

    def admit(self, requests, template=None):
        """Synchronous admission: dispatch + commit inline."""
        return self.commit_prefill(self.prefill_dispatch(requests, template))

    def decode_tick(self):
        """One decode step over every active lane: each lane's next token
        is a pure function of its request, never of co-batched lanes."""
        if not self.active:
            return {}
        self._sleep(self.decode_base + self.decode_per_lane * len(self.active))
        self.decode_steps += 1
        return {lane: _tok(r.template, r.prompt, len(r.generated))
                for lane, r in self.active.items()}

    def retire(self, lane):
        """Release a lane back to its pool."""
        self.active.pop(lane, None)
        self.partition.release(lane)


class _DriveStats:
    """Counters the equivalence/bench layers read off the service."""

    def __init__(self):
        self.round_trips = 0       # scheduler drives
        self.single_drives = 0
        self.batch_drives = 0
        self.requests = 0
        self.tokens = 0

    def __int__(self):
        return self.round_trips


class SchedulerQueryService:
    """QueryService facade over a :class:`ContinuousBatchingScheduler`.

    One *drive* = submit a cohort, ``producer_done()``, ``run_until_
    drained()``.  ``execute`` pays a whole drive for one request —
    faithfully modelling what a synchronous program does to a serving
    stack — while ``execute_batch`` amortizes a single drive across the
    cohort.  Results are the request's full generated-token tuple, so
    bit-identity of observables means bit-identity of generations.

    The engine persists across drives (lanes fully drain between them);
    each drive gets a fresh scheduler so no cross-drive queue state leaks.
    A lock serializes drives — the async runtime's workers may race
    single consumer-side executes against a producer batch.
    """

    def __init__(self, engine: Optional[TraceSimEngine] = None,
                 max_new_tokens: int = 4,
                 strategy_factory=OneOrAll):
        self.engine = engine if engine is not None else TraceSimEngine()
        self.max_new_tokens = max_new_tokens
        self.strategy_factory = strategy_factory
        self.stats = _DriveStats()
        self._rid = 0
        self._lock = threading.Lock()

    def _drive(self, query_name: str, params_list: Sequence) -> list:
        with self._lock:
            reqs = []
            for params in params_list:
                self._rid += 1
                reqs.append(Request(
                    rid=self._rid,
                    prompt=_prompt_for(query_name, params),
                    max_new_tokens=self.max_new_tokens,
                    template=query_name,
                ))
            sched = ContinuousBatchingScheduler(
                self.engine, strategy=self.strategy_factory())
            for r in reqs:
                sched.submit(r)
            sched.producer_done()
            sched.run_until_drained()
            self.stats.round_trips += 1
            self.stats.requests += len(reqs)
            self.stats.tokens += sum(len(r.generated) for r in reqs)
            return [tuple(r.generated) for r in reqs]

    def execute(self, query_name: str, params):
        """One query, one full scheduler drive (the synchronous tax)."""
        self.stats.single_drives += 1
        return self._drive(query_name, [params])[0]

    def execute_batch(self, query_name: str, params_list):
        """A cohort of queries in one shared drive (the transformed win)."""
        self.stats.batch_drives += 1
        return self._drive(query_name, list(params_list))
