"""Continuous batching scheduler — the paper's §5.2 as ML serving.

The decode loop has a *true-dependence cycle* (token t+1 needs token t), so
device-level Rule A cannot fission it — exactly the paper's inapplicable
case (§4.1).  The paper's answer is runtime **asynchronous batching**: keep
requests flowing through a queue and let free capacity decide, adaptively,
between latency (serve one now) and throughput (batch many).  Continuous
batching in LLM serving is that same decision made per engine tick, and the
paper's strategies transfer verbatim:

  admission per tick = strategy.decide(queue_length, producer_done)

  * PureAsync        → admit one request at a time (latency-optimal ttft
                       for the head of the queue, poor throughput)
  * OneOrAll         → admit everything waiting
  * LowerThreshold   → admit all only when the backlog exceeds bt (batch
                       setup — a prefill dispatch — costs ~3 decode ticks)
  * GrowingUpper     → cap admissions at a doubling threshold: small early
                       batches protect time-to-first-token, large late
                       batches protect throughput (Fig. 10's ramp)
  * AdaptiveCost     → learns prefill fixed-vs-per-item cost from observed
                       admit() durations and batches when it pays

Like the sharded :class:`~repro.core.runtime.AsyncQueryRuntime`, pending
requests are held in one lane per :attr:`Request.template`: each admission
batch is drawn from a single template's lane (homogeneous prompts bucket
tighter in the padded prefill), and mixed traffic classes stop head-of-line
blocking each other.  The strategy is consulted per lane; admission
round-robins over lanes while engine slots remain free.

With a :class:`~repro.core.lane_policy.LanePolicy` (``policy=``), each
template lane is asked its OWN strategy (hot templates learn a per-lane
AdaptiveCost model, cold ones stay pure-async), lanes are visited in
weighted-fair order instead of round-robin, and both prefill (admit) and
decode-tick durations feed back into that lane's cost model.  Admission
also passes the template to :meth:`InferenceEngine.admit`, which pins one
compiled prefill shape per template and — with ``kv_shares`` — bounds the
batch by that template's reserved + shared KV lanes
(:meth:`InferenceEngine.n_free_for`), so a burst on one template cannot
evict or starve the others' cache residency.

Admission consumes the same :class:`~repro.core.concurrency.ReadyLanes`
structure the lock-sharded runtime's workers drain: lanes with queued
requests sit in a duplicate-suppressed ready queue, each tick pops lanes
(weighted-fair under a policy, FIFO/round-robin otherwise) only while
engine slots remain free, and lanes with leftover backlog are re-queued —
a tick never scans lanes that have nothing to admit.

**Speculative prefill overlap** (``overlap=True``) — the paper's core
claim, applied to the tick loop itself: results should already be fetched
by the time they are consumed, so the *next* batch's prefill should be in
flight while the *current* decode tick runs, not after it.  Each tick
becomes a two-stage pipeline:

  commit(staged) → admit → speculate(dispatch next lane's prefill)
                                      ∥ decode tick t
  commit at tick t+1's boundary ──────┘

The scheduler peeks (without popping — :meth:`ReadyLanes.peek`) the next
ready lane, sizes a batch against the lanes that are free now *plus* the
lanes decode is about to retire (the speculation), and dispatches its
padded prefill on a separate thread through
:meth:`InferenceEngine.prefill_dispatch` while :meth:`decode_tick` runs.
At the next tick boundary the staged KV is committed into lanes
(:meth:`InferenceEngine.commit_prefill`).  If the bet missed — the lanes
it counted on were never freed, or freed into another template's
reservation — the uncommitted requests go back to the head of their queue
and the wasted prefill time feeds the lane's own cost model via
``observe_abort``, so chronically-missing lanes speculate less.

**Depth-k speculation** (``spec_depth=k``, default 1) generalizes the
single staged bet to a bounded pipeline: up to ``k`` dispatched-but-
uncommitted prefills ride in flight at once, each sized against the free
lanes MINUS the capacity already promised to older staged bets (older
bets claim their lanes first; a younger bet may only count lanes the
older ones cannot take).  Bets settle oldest-first at every tick
boundary; when an older bet misses, younger bets survive only while
their template's own reserved lanes still cover them — an uncovered
younger bet aborts immediately (its lane's ``observe_abort`` is charged
with the bet's pipeline depth) rather than wasting further boundaries.
Depth pays off when prefill capacity is separate from decode (the
disaggregated shape): ``k`` prefills progress concurrently under one
decode stream, submitting well AHEAD of the consumption point exactly as
the paper's §5.1 thread does for queries.

**Chunked prefill** (``chunk_tokens=n``) keeps one huge prompt from
stalling the pipeline: a prompt wider than ``n`` is dispatched alone and
processed as resumable chunks (:meth:`InferenceEngine.prefill_resume`) —
one chunk per tick boundary rides the speculation thread under that
tick's decode, and the bet commits when the last chunk lands.  Younger
bets queue behind it (commits stay oldest-first) but decode never stops.

**Host KV spill** (engine ``kv_spill=HostSpillPool(...)``): a straggler
force-retire stages the lane's KV to host memory instead of dropping it
(``stats.kv_spilled``); when the request is re-admitted, admission
restores the KV into a fresh lane and generation RESUMES
(``stats.kv_restored``) — no re-prefill, no token restart.  Requests
with staged KV are kept out of speculative prefill batches (the restore
path is strictly cheaper).

The scheduler records the per-tick admission trace (= Fig. 10 batch sizes,
also split per lane) and per-request ttft/latency (= Fig. 11
time-to-k-th-response).

Straggler mitigation: a lane whose request exceeds ``lane_timeout`` decode
ticks is force-retired and the request re-queued (re-submission, as in the
runtime's fetch-timeout path).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Optional

from repro.core.concurrency import ReadyLanes
from repro.core.lane_policy import LanePolicy
from repro.core.resilience import (
    FailureDomain,
    LaneError,
    LaneFailedError,
    Resilience,
)
from repro.core.strategies import BatchingStrategy, PureAsync
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request

__all__ = ["ContinuousBatchingScheduler"]


@dataclasses.dataclass
class SchedulerStats:
    """Per-scheduler counters and traces (one instance per scheduler)."""

    admission_trace: list = dataclasses.field(default_factory=list)  # (tick, n)
    # per-template (tick, n) admission traces (runtime lane analogue)
    lane_admissions: dict = dataclasses.field(default_factory=dict)
    decode_ticks: int = 0
    completed: int = 0
    requeued: int = 0
    # speculative-prefill pipeline (overlap=True)
    spec_dispatched: int = 0  # requests whose prefill was dispatched early
    spec_committed: int = 0   # of those, committed into KV lanes
    spec_aborted: int = 0     # of those, re-queued (the bet missed)
    spec_chunks: int = 0      # chunked-prefill resume steps processed
    # host KV spill (engine kv_spill=HostSpillPool)
    kv_spilled: int = 0       # evicted lanes whose KV was staged to host
    kv_restored: int = 0      # re-admissions served by a restore (no prefill)
    # prefix-granular KV sharing (engine prefix_share=True): admissions
    # that aliased a resident page-aligned prompt prefix instead of
    # recomputing it (mirrored from the engine's own counter each tick)
    prefix_hits: int = 0
    # failure domain (resilience=Resilience(...))
    quarantined: int = 0      # lanes held out after a device-step crash
    decode_retries: int = 0   # decode ticks re-run after a transient fault
    prefill_retries: int = 0  # admit() calls re-run after a transient fault
    spec_crashes: int = 0     # spec-thread dispatches that raised (aborted)
    breaker_trips: int = 0    # per-template circuit-breaker trips


class _SpecTask:
    """One in-flight speculative prefill (one bet of the depth-k pipeline).

    The dispatch runs on its own daemon thread so the host-side padding +
    device dispatch overlaps the main thread's decode tick; the main
    thread settles bets at tick boundaries, oldest-first.  At most
    ``spec_depth`` tasks are in flight, so a plain thread per dispatch
    costs nothing worth pooling.  A chunked task (``chunk`` set and an
    oversized prompt) is re-armed by :meth:`advance` once per boundary
    until every chunk has been folded in; ``duration`` accumulates across
    chunks so the cost model sees the bet's full dispatch time."""

    __slots__ = ("template", "batch", "chunk", "staged", "duration", "error",
                 "age", "_thread")

    def __init__(self, engine, template: str, batch: list,
                 chunk: Optional[int] = None):
        self.template = template
        self.batch = batch
        self.chunk = chunk
        self.staged = None
        self.duration = 0.0
        self.error: Optional[BaseException] = None
        self.age = 0  # tick boundaries this bet has been in flight
        self._spawn(engine)

    def _spawn(self, engine) -> None:
        self._thread = threading.Thread(
            target=self._run, args=(engine,), daemon=True,
            name="cbs-spec-prefill",
        )
        self._thread.start()

    def _run(self, engine) -> None:
        t0 = time.perf_counter()
        try:
            if self.staged is None:
                if self.chunk is None:
                    self.staged = engine.prefill_dispatch(
                        self.batch, template=self.template)
                else:
                    self.staged = engine.prefill_dispatch(
                        self.batch, template=self.template, chunk=self.chunk)
            else:
                engine.prefill_resume(self.staged)
        except BaseException as e:  # noqa: BLE001 — surfaced at commit
            self.error = e
        self.duration += time.perf_counter() - t0

    @property
    def finished(self) -> bool:
        """Whether the current dispatch/resume thread has returned (a
        non-blocking check — younger bets are only committed when they
        have already finished, never waited on)."""
        return not self._thread.is_alive()

    @property
    def complete(self) -> bool:
        """Whether the staged prefill is commit-eligible: dispatched, and
        (for a chunked bet) every chunk folded in.  Engines without chunk
        support stage complete results in one dispatch."""
        return (self.staged is not None
                and getattr(self.staged, "complete", True))

    def advance(self, engine) -> None:
        """Re-arm a chunked task: fold the next chunk on a fresh spec
        thread (it overlaps the decode tick now starting)."""
        self._spawn(engine)

    def join(self) -> None:
        """Block until the dispatch thread has finished (commit boundary)."""
        self._thread.join()


class ContinuousBatchingScheduler:
    """Per-template admission + one batched decode step per tick.

    Parameters
    ----------
    engine:
        The lane-holding engine.  Any object with the
        :class:`InferenceEngine` admission/decode surface works; the
        ``overlap=True`` pipeline additionally needs the split dispatch
        path (``prefill_dispatch`` / ``commit_prefill`` / ``n_free_for``).
    strategy / policy:
        One global :class:`BatchingStrategy`, or a per-lane
        :class:`LanePolicy` (mutually exclusive).
    lane_timeout:
        Decode ticks before a running request is force-retired and
        re-queued (straggler mitigation); ``None`` disables.  With an
        engine spill pool the retired lane's KV is staged to host memory
        and the re-queued request resumes on re-admission.
    overlap:
        Enable the speculative prefill/decode pipeline (module docstring).
    spec_depth:
        Maximum staged speculative prefills in flight (default 1 — the
        single-bet pipeline).  Values above 1 need ``overlap=True`` and
        pay off when prefill hardware is separate from decode.
    chunk_tokens:
        Split any prompt wider than this into resumable prefill chunks
        (one per tick boundary) so a single huge prompt overlaps decode
        instead of stalling the commit boundary.  Needs ``overlap=True``
        and an engine with ``prefill_resume``; ``None`` disables.
    resilience:
        A :class:`~repro.core.resilience.Resilience` config enabling the
        failure domain: transient admit/decode faults are retried with
        backoff, a device-step :class:`~repro.core.resilience.LaneError`
        quarantines the crashed lane (KV salvaged via the spill pool when
        one exists) and re-queues its request at the head, a spec-thread
        crash aborts that bet cleanly instead of wedging the pipeline,
        and a per-template circuit breaker sheds chronically-failing
        lanes' speculation.  A template whose submissions fail
        ``lane_fail_threshold`` times consecutively raises a typed
        :class:`~repro.core.resilience.LaneFailedError` naming the
        template and last exception.  ``None`` (default) keeps the
        legacy fail-fast behavior: any engine exception propagates.
    """

    def __init__(
        self,
        engine: InferenceEngine,
        strategy: Optional[BatchingStrategy] = None,
        lane_timeout: Optional[int] = None,
        policy: Optional[LanePolicy] = None,
        overlap: bool = False,
        spec_depth: int = 1,
        chunk_tokens: Optional[int] = None,
        resilience: Optional[Resilience] = None,
    ):
        if policy is not None and strategy is not None:
            raise ValueError(
                "pass either a global `strategy` or a per-lane `policy`, not both"
            )
        self.engine = engine
        self.policy = policy
        self.strategy = strategy or PureAsync()
        self.strategy.reset()
        self.overlap = overlap
        if overlap and not hasattr(engine, "prefill_dispatch"):
            raise ValueError(
                "overlap=True needs an engine with the split dispatch path "
                "(prefill_dispatch/commit_prefill/n_free_for)"
            )
        if spec_depth < 1:
            raise ValueError("spec_depth must be >= 1")
        if spec_depth > 1 and not overlap:
            raise ValueError("spec_depth > 1 needs overlap=True")
        if chunk_tokens is not None:
            if chunk_tokens < 1:
                raise ValueError("chunk_tokens must be >= 1")
            if not overlap:
                raise ValueError("chunk_tokens needs overlap=True")
            if not hasattr(engine, "prefill_resume"):
                raise ValueError(
                    "chunk_tokens needs an engine with prefill_resume "
                    "(resumable chunked prefill)"
                )
        self.spec_depth = spec_depth
        self.chunk_tokens = chunk_tokens
        # The one capacity surface (:class:`repro.serving.kv.KVView`):
        # engines expose it as ``engine.kv``; duck-typed bench/test engines
        # without one are consumed directly (they mirror the same names).
        self._kv = getattr(engine, "kv", None) or engine
        # Page-pressure evictions (paged engine oversubscription): the
        # engine notifies synchronously AT eviction time — before the freed
        # lane can be reallocated to a new request — so the victim's
        # running-table entry is cleared while it still refers to the
        # evicted request.  Engines without the hook never evict mid-decode.
        if hasattr(engine, "on_lane_evicted"):
            engine.on_lane_evicted = self._lane_evicted
        # Engines predating KV partitioning expose only the global n_free;
        # treat every template as drawing from one shared pool there.
        self._free_for = getattr(self._kv, "n_free_for",
                                 lambda tmpl: self._kv.n_free)
        # template -> pending requests; insertion-ordered for round-robin
        self.queues: "OrderedDict[str, deque[Request]]" = OrderedDict()
        self.running: dict[int, Request] = {}  # lane -> request
        self.stats = SchedulerStats()
        self.lane_timeout = lane_timeout
        self._lane_age: dict[int, int] = {}
        # Lanes with queued requests (same structure the runtime's workers
        # drain): FIFO pop + tail re-push is round-robin over busy lanes;
        # with a policy the pop is weighted-fair.  Single-threaded here, so
        # its lock is never contended.
        self._ready = ReadyLanes()
        self._warm_shapes: set = set()  # prefill buckets already compiled
        self._producer_done = False
        # The speculation pipeline: up to spec_depth in-flight bets,
        # oldest first (index 0 settles at the next tick boundary).
        self._staged: "deque[_SpecTask]" = deque()
        # Failure domain (resilience=Resilience(...)): breakers + retry
        # budgets per template, consecutive-failure records, and lanes
        # held in quarantine until a decode-tick deadline.
        self.resilience = resilience
        self._fd = (
            FailureDomain(resilience, on_trip=self._on_breaker_trip)
            if resilience is not None else None
        )
        self._lane_failures: dict[str, tuple] = {}  # tmpl -> (n, last exc)
        self._quarantine_release: dict[int, int] = {}  # lane -> release tick

    # ------------------------------------------------------------------ api
    def submit(self, request: Request) -> None:
        """Queue one request on its template's lane."""
        q = self.queues.get(request.template)
        if q is None:
            q = self.queues[request.template] = deque()
        q.append(request)
        self._ready.push(request.template)
        if self.policy is not None:
            self.policy.note_submit(request.template)

    @property
    def n_queued(self) -> int:
        """Requests waiting in lanes (staged/running not counted)."""
        return sum(len(q) for q in self.queues.values())

    def producer_done(self) -> None:
        """Signal that no more requests are coming (lets PureBatch-style
        strategies drain the tail)."""
        self._producer_done = True

    def run_until_drained(self, max_ticks: int = 100_000) -> list[Request]:
        """Tick until every submitted request has finished (or raise after
        ``max_ticks`` with a diagnosis of what is stuck)."""
        done: list[Request] = []
        for _ in range(max_ticks):
            if (not self.n_queued and not self.running
                    and not self._staged):
                if self._producer_done:
                    break
            done.extend(self.tick())
        else:
            if self.n_queued or self.running or self._staged:
                if self._lane_failures:
                    # An all-failing lane is a NAMED condition, not a
                    # generic stuck-lane timeout: surface which template
                    # is down and the exception its submissions die with.
                    tmpl, (n, exc) = max(self._lane_failures.items(),
                                         key=lambda kv: kv[1][0])
                    raise LaneFailedError(tmpl, n, exc)
                stuck_queued = {t: len(q) for t, q in self.queues.items() if q}
                stuck_running = {
                    lane: r.template for lane, r in sorted(self.running.items())
                }
                staged = (", staged spec prefills on "
                          f"{[t.template for t in self._staged]!r}"
                          if self._staged else "")
                raise RuntimeError(
                    f"run_until_drained exhausted max_ticks={max_ticks} with "
                    f"work still pending: queued per template {stuck_queued}, "
                    f"running lanes {stuck_running}{staged} "
                    f"({self.stats.completed} completed, "
                    f"{self.stats.requeued} requeued, "
                    f"{self.stats.spec_aborted} spec-aborted). A lane that "
                    "never finishes usually means the engine stopped emitting "
                    "tokens for it, max_new_tokens exceeds the tick budget, "
                    "or kv_shares leaves its template no admissible lane."
                )
        return done

    def _lane_evicted(self, lane: int, rid, template, spilled: bool) -> None:
        """Engine callback: ``lane``'s KV was evicted mid-decode by page
        pressure (oversubscribed paged pool).  The engine already spilled
        the KV to host (when a spill pool accepts it) and retired the
        lane; this hook re-queues the request at the head of its template
        lane — exactly the straggler re-queue path, minus the retire the
        engine performed itself.  With staged KV the re-admission restores
        and RESUMES; without, the partial generation is cleared and the
        re-admission re-prefills from scratch (greedy decode regenerates
        the same tokens, so end-to-end output is unchanged)."""
        r = self.running.pop(lane, None)
        if r is None:
            return
        if r.rid != rid:  # stale identity: not the request we were told of
            self.running[lane] = r
            return
        self._lane_age.pop(lane, None)
        if spilled:
            self.stats.kv_spilled += 1
        else:
            r.generated.clear()
        r.lane = None
        q = self.queues.get(r.template)
        if q is None:
            q = self.queues[r.template] = deque()
        q.appendleft(r)
        self._ready.push(r.template)
        self.stats.requeued += 1

    # ------------------------------------------------------- failure domain
    def _on_breaker_trip(self) -> None:
        self.stats.breaker_trips += 1

    def _record_lane_failure(self, tmpl, exc: BaseException) -> None:
        """Count a consecutive submission failure against ``tmpl`` (with
        its last exception, for the typed lane-down diagnosis)."""
        if tmpl is None:
            tmpl = "default"
        n, _ = self._lane_failures.get(tmpl, (0, None))
        self._lane_failures[tmpl] = (n + 1, exc)

    def _record_lane_success(self, tmpl) -> None:
        """A successful submission resets ``tmpl``'s consecutive-failure
        record."""
        self._lane_failures.pop(tmpl if tmpl is not None else "default", None)

    def _check_lane_health(self) -> None:
        """Raise a typed :class:`LaneFailedError` for any template whose
        consecutive submission failures crossed the threshold — the named
        all-failing-lane diagnosis, instead of requeueing forever and
        dying as a generic stuck-lane timeout."""
        if self.resilience is None:
            return
        limit = self.resilience.lane_fail_threshold
        if limit is None:
            return
        for tmpl, (n, exc) in self._lane_failures.items():
            if n >= limit:
                raise LaneFailedError(tmpl, n, exc)

    def _release_quarantine(self) -> None:
        """Return quarantined lanes whose cooldown (in decode ticks) has
        elapsed to their home pools."""
        if not self._quarantine_release:
            return
        unq = getattr(self._kv, "unquarantine", None)
        due = [lane for lane, t in self._quarantine_release.items()
               if self.stats.decode_ticks >= t]
        for lane in due:
            del self._quarantine_release[lane]
            if unq is not None:
                unq(lane)

    def _quarantine_lane(self, err: LaneError) -> None:
        """Crash-safe lane recovery: the device step raised for one lane.
        Salvage the request's KV through the spill pool when one exists
        (re-admission restores and RESUMES — no token restart), re-queue
        the request at the head of its lane, and hold the lane itself out
        of circulation for ``quarantine_ticks`` decode ticks so a
        lane-correlated fault (bad page, wedged stream) doesn't
        immediately poison the next admission."""
        lane = err.lane
        self.stats.quarantined += 1
        r = self.running.pop(lane, None)
        self._lane_age.pop(lane, None)
        if r is not None:
            spill = getattr(self.engine, "spill", None)
            if spill is not None:
                spilled = spill(lane, key=r.rid, template=r.template)
            else:
                self.engine.retire(lane)
                spilled = False
            if spilled:
                self.stats.kv_spilled += 1
            else:
                r.generated.clear()
            r.lane = None
            self._requeue_front(r.template, [r])
            self.stats.requeued += 1
            self._record_lane_failure(r.template, err)
        else:
            try:
                self.engine.retire(lane)
            except Exception:  # noqa: BLE001 — lane may already be free
                pass
        ticks = self.resilience.quarantine_ticks
        quarantine = getattr(self._kv, "quarantine", None)
        if ticks and quarantine is not None:
            try:
                quarantine(lane)
            except ValueError:
                return  # lane not free (engine state diverged): no holdout
            self._quarantine_release[lane] = self.stats.decode_ticks + ticks

    def _decode_with_recovery(self) -> dict:
        """One decode step under the failure domain: a
        :class:`LaneError` quarantines the named lane and re-runs the
        step for the surviving lanes (the crash consumed no tick — other
        requests lose no token); any other exception is retried with
        backoff while the policy allows, then propagates."""
        fd = self._fd
        if fd is None:
            return self.engine.decode_tick()
        policy = fd.retry
        crashes = 0
        attempt = 0
        while True:
            try:
                return self.engine.decode_tick()
            except LaneError as e:
                crashes += 1
                self.stats.decode_retries += 1
                self._quarantine_lane(e)
                if crashes > len(self.running) + 8:
                    raise  # runaway: every retry crashes a new lane
                continue
            except BaseException as e:  # noqa: BLE001 — bounded retry
                attempt += 1
                if (not policy.is_retryable(e)
                        or attempt >= max(1, policy.max_attempts)):
                    raise
                self.stats.decode_retries += 1
                policy.sleep_backoff(attempt, "decode")

    def _admit_with_retry(self, fresh: list, tmpl):
        """Synchronous admission under the failure domain: transient
        faults retry with backoff; success/failure feeds the template's
        breaker and consecutive-failure record.  Raises the last
        exception on final failure (the caller re-queues the batch)."""
        fd = self._fd
        if fd is None:
            return self.engine.admit(fresh, template=tmpl)
        policy = fd.retry
        breaker = fd.breaker(tmpl)
        last = None
        for attempt in range(max(1, policy.max_attempts)):
            if attempt > 0:
                self.stats.prefill_retries += 1
                policy.sleep_backoff(attempt, tmpl)
            try:
                shape = self.engine.admit(fresh, template=tmpl)
            except BaseException as e:  # noqa: BLE001 — bounded retry
                last = e
                if breaker is not None:
                    breaker.record_failure()
                if not policy.is_retryable(e):
                    break
                continue
            if breaker is not None:
                breaker.record_success()
            self._record_lane_success(tmpl)
            return shape
        self._record_lane_failure(tmpl, last)
        raise last

    # ------------------------------------------------- speculative pipeline
    def _strategy_for(self, tmpl: str) -> BatchingStrategy:
        return (self.policy.strategy_for(tmpl) if self.policy is not None
                else self.strategy)

    def _requeue_front(self, tmpl: str, batch: list) -> None:
        """Return an uncommitted speculative batch to the head of its lane
        (these requests were next in line; they must not lose their turn).
        The overlap markers are reset: metrics describe the attempt that
        finally lands, and this one did not."""
        q = self.queues.get(tmpl)
        if q is None:
            q = self.queues[tmpl] = deque()
        for r in reversed(batch):
            r.metrics.speculative = False
            q.appendleft(r)
        self._ready.push(tmpl)

    def _land_batch(self, tmpl: str, strat: BatchingStrategy, batch: list,
                    shape, duration: float) -> None:
        """Shared bookkeeping for a batch that just entered KV lanes —
        identical for synchronous admission and speculative commit, so the
        two paths cannot drift.

        Cost-model feedback is warm-shape guarded: the first dispatch of a
        padded bucket pays XLA compilation, an outlier that would blow up
        the learned fixed cost, so only steady-state durations are
        observed, sized by the bucket the device actually dispatched.
        ``duration`` is what the scheduler actually paid for the batch:
        the inline admit time on the synchronous path, dispatch + the
        commit-side materialization wait on the speculative one."""
        if shape in self._warm_shapes:
            strat.observe(shape[0], duration)
        else:
            self._warm_shapes.add(shape)
        if self.policy is not None:
            self.policy.charge(tmpl, len(batch))
        now = time.perf_counter()
        for r in batch:
            r.metrics.first_token = now  # prefill emits token 0
            self.running[r.lane] = r
            self._lane_age[r.lane] = 0
        self.stats.admission_trace.append((self.stats.decode_ticks, len(batch)))
        self.stats.lane_admissions.setdefault(tmpl, []).append(
            (self.stats.decode_ticks, len(batch)))

    def _reservation_covers(self, task: _SpecTask) -> bool:
        """Whether ``task``'s template's OWN reserved lanes can hold its
        whole batch right now — the survival test for a younger bet after
        an older bet missed: reserved lanes cannot be taken by any other
        template, so a covered bet is still a sound speculation.  Engines
        without per-template pools (no ``n_free_for``) report zero
        reserved lanes, so their younger bets abort on a miss —
        conservative, and settled the same way a depth-1 miss is."""
        reserved_free = self._free_for(task.template) - self._free_for(None)
        return len(task.batch) <= max(0, reserved_free)

    def _promised_against(self, tmpl: str) -> int:
        """Free-lane capacity already promised to in-flight staged bets
        that a new bet for ``tmpl`` must not count again.

        An older bet on the SAME template claims its whole batch from the
        pools ``tmpl`` draws on; an older bet on ANOTHER template claims
        only its spill-over into the shared pool (whatever its own
        reserved lanes cannot hold) — its reserved draw can never collide
        with ``tmpl``.  Engines without per-template pools see every claim
        as shared."""
        shared_free = self._free_for(None)
        n = 0
        for task in self._staged:
            if task.template == tmpl:
                n += len(task.batch)
            else:
                reserved_free = max(
                    0, self._free_for(task.template) - shared_free)
                n += max(0, len(task.batch) - reserved_free)
        return n

    def _abort_task(self, task: _SpecTask, requeues: list,
                    n_committed: int = 0) -> None:
        """Charge a missed bet and record its re-queue.

        The uncommitted requests are appended to ``requeues`` rather than
        re-queued immediately: the commit boundary settles bets
        oldest-first, and naive immediate ``appendleft`` would stack a
        younger same-template batch ON TOP of the older one it arrived
        behind — the caller flushes ``requeues`` youngest-first so the
        oldest aborted batch ends up at the very head.  A fully-wasted
        bet feeds its lane's ``observe_abort`` with the bet's accumulated
        dispatch time AND its pipeline depth (``age``): a bet that sat
        staged for d boundaries also held promised capacity for d ticks,
        so deep misses raise the lane's learned threshold faster.  A
        partial commit still used the dispatch — no penalty."""
        aborted = task.batch[n_committed:]
        if not aborted:
            return
        requeues.append((task.template, aborted))
        self.stats.spec_aborted += len(aborted)
        if n_committed == 0:
            depth = max(1, task.age)
            if self.policy is not None:
                self.policy.observe_abort(task.template, task.duration,
                                          depth=depth)
            else:
                self._strategy_for(task.template).observe_abort(
                    task.duration, depth=depth)

    def _flush_requeues(self, requeues: list) -> None:
        """Apply a boundary's aborted-bet re-queues YOUNGEST-first, so the
        oldest bet's requests (which arrived first) end at the queue
        head — FIFO arrival order survives a multi-bet abort cascade."""
        for tmpl, batch in reversed(requeues):
            self._requeue_front(tmpl, batch)

    def _commit_speculative(self) -> None:
        """Tick-boundary settlement of the speculation pipeline.

        Bets settle OLDEST-FIRST.  The oldest bet is joined (its dispatch
        had a full decode tick to finish) and committed once its whole
        batch fits; a bet whose capacity has not materialized yet may wait
        up to ``spec_depth`` boundaries (the horizon it was sized
        against), after which the shortfall is a MISS: the fitting prefix
        commits, the rest aborts to the head of its queue.  Younger bets
        may commit at the same boundary — but only after every older bet
        fully committed, and only if their own dispatch already finished
        (they are never waited on).  After a miss, a younger bet survives
        only while its template's reserved lanes still cover it
        (:meth:`_reservation_covers`); an uncovered bet aborts NOW,
        feeding ``observe_abort`` with its pipeline depth, instead of
        wasting further boundaries.  An incomplete chunked bet is advanced
        one chunk (overlapping the coming decode tick) and keeps its
        position; younger bets stay queued behind it."""
        if not self._staged:
            return
        tasks = list(self._staged)
        self._staged.clear()
        keep: list[_SpecTask] = []
        requeues: list = []  # (template, batch) per aborted bet, oldest first
        blocked = False  # an older bet is still in flight / mid-chunk
        missed = False   # an older bet aborted requests at this boundary
        for i, task in enumerate(tasks):
            task.age += 1
            if i == 0:
                task.join()
            if missed and not self._reservation_covers(task):
                self._abort_task(task, requeues)
                continue
            if blocked or (i > 0 and not task.finished):
                keep.append(task)
                blocked = True
                continue
            if task.error is not None:
                if self._fd is not None:
                    # Spec-thread crash: abort THIS bet cleanly (requests
                    # back to their queue head, abort-time charged to the
                    # lane's cost model, breaker fed) and keep settling —
                    # the pipeline must not wedge on one dead thread.
                    self.stats.spec_crashes += 1
                    breaker = self._fd.breaker(task.template)
                    if breaker is not None:
                        breaker.record_failure()
                    self._record_lane_failure(task.template, task.error)
                    self._abort_task(task, requeues)
                    missed = True
                    blocked = True
                    continue
                requeues.append((task.template, task.batch))
                self._flush_requeues(requeues)
                keep.extend(tasks[i + 1:])
                self._staged.extend(keep)
                raise task.error
            if not task.complete:  # chunked: fold the next chunk this tick
                # Fused megabatch first: a paged engine can adopt the next
                # chunk INTO this tick's decode dispatch (one device
                # program per boundary instead of decode + spec-thread
                # resume); engines without stage_chunk — or ticks it
                # declines (no active decode batch) — keep the
                # spec-thread resume path.
                stage = getattr(self.engine, "stage_chunk", None)
                if stage is None or not stage(task.staged):
                    task.advance(self.engine)
                self.stats.spec_chunks += 1
                keep.append(task)
                blocked = True
                continue
            tmpl = task.template
            fit = min(len(task.batch), self._free_for(tmpl))
            if fit < len(task.batch) and task.age < self.spec_depth:
                # The bet was sized against capacity materializing up to
                # spec_depth ticks out; within that horizon a shortfall is
                # "not yet", not a miss — wait for a later boundary rather
                # than splitting the batch or aborting.  (depth 1: age is
                # already 1 at the first boundary, so bets settle
                # immediately — the single-bet pipeline's semantics.)
                keep.append(task)
                blocked = True
                continue
            strat = self._strategy_for(tmpl)
            committed = task.batch[:fit]
            if committed:
                t0 = time.perf_counter()
                shape = self.engine.commit_prefill(task.staged, n=fit)
                commit_dt = time.perf_counter() - t0
                self._land_batch(tmpl, strat, committed, shape,
                                 task.duration + commit_dt)
                self.stats.spec_committed += fit
                if self._fd is not None:
                    breaker = self._fd.breaker(tmpl)
                    if breaker is not None:
                        breaker.record_success()
                    self._record_lane_success(tmpl)
            if fit < len(task.batch):
                self._abort_task(task, requeues, n_committed=fit)
                # Younger bets stop committing at this boundary: the
                # aborted requests are going back to their queue head, and
                # a younger same-template commit would overtake them.
                missed = True
                blocked = True
        self._flush_requeues(requeues)
        self._staged.extend(keep)

    def _dispatch_speculative(self, select) -> None:
        """Fill the speculation pipeline: pick speculable ready lanes
        (peek — a lane we decline keeps its queue position) and dispatch
        their prefills on spec threads until ``spec_depth`` bets are in
        flight, each sized against free lanes plus the lanes this tick's
        decode is about to retire MINUS the capacity already promised to
        older staged bets — the bets ``_commit_speculative`` settles
        oldest-first at later boundaries.

        The scan consults each ready lane at most once per tick, in the
        pick order admission would use (weighted-fair under a policy,
        FIFO otherwise), by filtering already-declined lanes out of the
        peek's candidate set — so one permanently-starved head lane
        cannot blind the speculator to dispatchable lanes behind it, in
        EITHER pick discipline, and declined lanes are never reordered.
        A lane whose head prompt exceeds ``chunk_tokens`` dispatches the
        whole run of consecutive oversized head prompts as one batched
        chunked bet (one resumable part per prompt); a lane whose next
        requests have spilled KV staged is declined (the admission-time
        restore is strictly cheaper than a re-prefill)."""
        ben = getattr(self._kv, "benefits",
                      getattr(self.engine, "lane_benefits", None))
        has_spill = getattr(self.engine, "has_spill", None)
        consulted: set = set()

        def next_candidate(keys: list):
            cand = [k for k in keys if k not in consulted]
            if not cand:
                return None  # peek passes this through: scan exhausted
            return cand[0] if select is None else select(cand)

        while len(self._staged) < self.spec_depth:
            tmpl = self._ready.peek(select=next_candidate)
            if tmpl is None or tmpl in consulted:
                # None: nothing ready / every ready lane declined.  A
                # consulted key can still surface via peek's single-entry
                # short-circuit (select is bypassed at len 1): same exit.
                return
            consulted.add(tmpl)
            q = self.queues.get(tmpl)
            if not q:
                # Stale entry (lane drained since the push): discard it —
                # the targeted pop removes exactly this key.
                self._ready.pop(select=lambda keys, t=tmpl: t, block=False)
                continue
            if self._fd is not None:
                breaker = self._fd.breaker(tmpl)
                if breaker is not None and breaker.allow() == "shed":
                    # Tripped breaker: no speculative bets on this
                    # template — it degrades to the synchronous admission
                    # path (whose successes/probes close the breaker).
                    continue
            # The speculative capacity: lanes free now, plus lanes whose
            # request reaches max_new_tokens within the pipeline's horizon
            # (``spec_depth`` decode ticks — a bet staged behind j older
            # bets commits ~j boundaries later, so a deeper pipeline may
            # bet on retirements further out) — counting only retirements
            # whose lane goes home to a pool this template can draw from
            # (engine.lane_benefits): a lane bound for another template's
            # reservation is a guaranteed miss, not a bet.  Lanes already
            # promised to older staged bets are subtracted
            # (``_promised_against``): an older bet claims its capacity
            # first, so a younger bet may only count what is left.  The
            # remaining optimism (a straggler that refuses to finish, an
            # engine that stops emitting, an engine without the
            # lane_benefits hint, a retirement double-counted across
            # bets) is what makes this a speculation, and the abort path
            # is what settles it.  Capacity is checked BEFORE the
            # strategy is consulted: decide() may be stateful
            # (AdaptiveCost's explore alternation), and a lane with no
            # speculative capacity must not consume a decision it cannot
            # act on.
            cap = (self._free_for(tmpl) + sum(
                1 for r in self.running.values()
                if (r.remaining <= self.spec_depth
                    and (ben is None or ben(r.lane, tmpl))))
                - self._promised_against(tmpl))
            if cap > 0:
                chunked = (self.chunk_tokens is not None
                           and len(q[0].prompt) > self.chunk_tokens)
                strat = self._strategy_for(tmpl)
                if chunked:
                    # Consecutive oversized head prompts admit as ONE
                    # batched chunk dispatch (each becomes its own
                    # resumable part; see StagedPrefill.parts) — an
                    # oversized burst no longer serializes one prompt
                    # per bet.  The run stops at the first prompt that
                    # fits a chunk so small prompts keep their ordinary
                    # padded-batch path.
                    n_over = 0
                    for r in q:
                        if len(r.prompt) > self.chunk_tokens:
                            n_over += 1
                        else:
                            break
                    take = min(strat.decide(len(q), self._producer_done),
                               n_over, cap)
                else:
                    take = min(strat.decide(len(q), self._producer_done),
                               len(q), cap)
                if take > 0 and has_spill is not None and any(
                        has_spill(q[i].rid) for i in range(take)):
                    take = 0  # restore at admission beats re-prefilling
                if take > 0:
                    self._dispatch_one(tmpl, q, take,
                                       chunked=chunked)
                    continue
            # Declined (strategy says wait / no capacity even
            # speculatively / spilled KV pending restore): leave the lane
            # exactly where it is and look at the next candidate.

    def _dispatch_one(self, tmpl: str, q: "deque[Request]", take: int,
                      chunked: bool) -> None:
        """Pop ``take`` requests off ``tmpl``'s lane and stage their
        prefill as one new speculation-pipeline bet."""
        self._ready.pop(select=lambda keys, t=tmpl: t, block=False)
        batch = [q.popleft() for _ in range(take)]
        if not q:
            del self.queues[tmpl]
        else:
            self._ready.push(tmpl)
        now = time.perf_counter()
        for r in batch:
            r.metrics.admitted = now
            r.metrics.speculative = True
        self._staged.append(_SpecTask(
            self.engine, tmpl, batch,
            chunk=self.chunk_tokens if chunked else None))
        self.stats.spec_dispatched += take

    # ----------------------------------------------------------------- tick
    def tick(self) -> list[Request]:
        """One scheduling round: commit the staged speculative prefill,
        admit per strategy (per lane), dispatch the next speculation, run
        one decode step."""
        # 0) failure domain first: quarantined lanes whose cooldown has
        # elapsed rejoin their pools before admission counts free lanes,
        # and a template whose submissions keep failing surfaces as a
        # typed LaneFailedError rather than spinning forever.
        self._release_quarantine()
        self._check_lane_health()
        # 0.5) tick boundary: the previous tick's speculative prefill lands
        # (or aborts) before admission sees the free-lane picture.
        if self.overlap:
            self._commit_speculative()

        # 1) admission — the paper's "how many requests does a free worker
        # take from the queue" decision.  Ready lanes are popped (weighted-
        # fair under a LanePolicy, round-robin otherwise) only while engine
        # slots remain free; each lane is consulted at most once per tick
        # and re-queued if it keeps a backlog, so a tick never scans lanes
        # with nothing to admit.
        # Weighted-fair selection costs a policy lock + O(n) scan per pop;
        # with uniform weights FIFO pop + tail re-push is equally fair
        # round-robin (same guard as the runtime worker's pop).
        select = (self.policy.lane_min
                  if self.policy is not None and self.policy.lane_weights
                  else None)
        has_spill = getattr(self.engine, "has_spill", None)
        consulted: set = set()
        repush: list = []
        while self._kv.n_free > 0:
            tmpl = self._ready.pop(select=select, block=False)
            if tmpl is None:
                break
            if tmpl in consulted:
                repush.append(tmpl)
                break
            consulted.add(tmpl)
            q = self.queues.get(tmpl)
            if not q:
                continue  # stale push: lane drained since
            if (self.chunk_tokens is not None
                    and len(q[0].prompt) > self.chunk_tokens
                    and not (has_spill is not None
                             and has_spill(q[0].rid))):
                # Oversized head prompt: admitting it inline is exactly the
                # stall chunking exists to avoid — leave the lane for the
                # chunked speculative dispatch (step 1.5) instead.  An
                # oversized request WITH staged spilled KV falls through:
                # its restore path pays no prefill at all, and skipping it
                # here while the spec path also declines spilled requests
                # would starve it forever.
                repush.append(tmpl)
                continue
            strat = self._strategy_for(tmpl)
            want = strat.decide(len(q), self._producer_done)
            # kv_shares: the batch is bounded by THIS template's admissible
            # lanes (reserved + shared), not the global free count.
            take = min(want, self._free_for(tmpl), len(q))
            if take <= 0:
                repush.append(tmpl)  # strategy says wait: stay scheduled
                continue
            batch = [q.popleft() for _ in range(take)]
            if not q:
                # GC drained lanes (mirrors the runtime): high-cardinality
                # template churn must not grow the bookkeeping.
                del self.queues[tmpl]
            else:
                repush.append(tmpl)
            # Host-KV restore first: a re-admitted request whose spilled
            # KV survived in the pool resumes decoding directly (no
            # prefill, no token restart); only the rest go through the
            # prefill batch.  A request whose entry was evicted (pool
            # LRU/budget) restarts from scratch — its stale partial
            # generation is cleared before the re-prefill.
            restore = getattr(self.engine, "try_restore", None)
            fresh: list = []
            n_restored = 0
            for r in batch:
                lane = restore(r.rid, tmpl) if restore is not None else None
                if lane is not None:
                    r.lane = lane
                    self.running[lane] = r
                    self._lane_age[lane] = 0
                    n_restored += 1
                    self.stats.kv_restored += 1
                else:
                    if r.generated:
                        r.generated.clear()  # spill entry lost: restart
                    if (self.chunk_tokens is not None
                            and len(r.prompt) > self.chunk_tokens):
                        # Oversized restart whose entry was evicted: back
                        # to the head — the chunk pipeline re-prefills it
                        # (its spill entry is gone, so the admission gate
                        # now routes it to the spec path, no starvation).
                        self._requeue_front(tmpl, [r])
                        continue
                    fresh.append(r)
            if n_restored and self.policy is not None:
                self.policy.charge(tmpl, n_restored)  # restored = service
            if not fresh:
                continue
            now = time.perf_counter()
            for r in fresh:
                r.metrics.admitted = now
            t0 = time.perf_counter()
            try:
                shape = self._admit_with_retry(fresh, tmpl)
            except BaseException:
                if self._fd is None:
                    raise
                # Persistent admission failure: the batch goes back to the
                # head of its lane (it was next in line) and the failure
                # record / breaker absorb the feedback — _check_lane_health
                # names the template if this never recovers.
                self._requeue_front(tmpl, fresh)
                self.stats.requeued += len(fresh)
                continue
            # Feedback goes to the deciding model (the lane's own under a
            # policy); warm-shape guarding and the landing bookkeeping are
            # shared with the speculative commit path.
            self._land_batch(tmpl, strat, fresh, shape,
                             time.perf_counter() - t0)
        for tmpl in repush:
            self._ready.push(tmpl)
        hits = getattr(self.engine, "prefix_hits", None)
        if hits is not None:
            self.stats.prefix_hits = hits

        # 1.5) speculation: while decode runs below, the next ready lanes'
        # prefills are already in flight on spec threads (up to
        # spec_depth staged bets).
        if self.overlap and len(self._staged) < self.spec_depth:
            self._dispatch_speculative(select)

        # 2) one batched decode step over all active lanes
        finished: list[Request] = []
        t0 = time.perf_counter()
        tokens = self._decode_with_recovery()
        decode_dt = time.perf_counter() - t0
        self.stats.decode_ticks += 1
        if self.policy is not None and tokens:
            # Per-lane decode feedback: every template with a request in this
            # tick's batch gets the tick duration — the per-token side of its
            # cost model, next to the prefill F + n·c fit.
            for tmpl in {r.template for r in self.running.values()}:
                self.policy.observe_decode(tmpl, decode_dt)
        for lane, tok in tokens.items():
            r = self.running.get(lane)
            if r is None:
                continue
            r.generated.append(tok)
            self._lane_age[lane] += 1
            if r.done:
                r.metrics.finished = time.perf_counter()
                self.engine.retire(lane)
                del self.running[lane]
                finished.append(r)
                self.stats.completed += 1
            elif self.lane_timeout and self._lane_age[lane] > self.lane_timeout:
                # Straggler: retire the lane, re-queue the request.  With
                # an engine spill pool the lane's KV is staged to host
                # memory and the partial generation is KEPT — re-admission
                # restores and resumes; without one (or if the entry is
                # later evicted) the re-admission re-prefills from scratch.
                spill = getattr(self.engine, "spill", None)
                if spill is not None:
                    spilled = spill(lane, key=r.rid, template=r.template)
                else:
                    self.engine.retire(lane)
                    spilled = False
                del self.running[lane]
                if spilled:
                    self.stats.kv_spilled += 1
                else:
                    r.generated.clear()
                r.lane = None
                rq = self.queues.get(r.template)
                if rq is None:  # lane may have been GC'd since admission
                    rq = self.queues[r.template] = deque()
                rq.appendleft(r)
                self._ready.push(r.template)
                self.stats.requeued += 1
        return finished
