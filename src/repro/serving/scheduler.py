"""Continuous batching scheduler — the paper's §5.2 as ML serving.

The decode loop has a *true-dependence cycle* (token t+1 needs token t), so
device-level Rule A cannot fission it — exactly the paper's inapplicable
case (§4.1).  The paper's answer is runtime **asynchronous batching**: keep
requests flowing through a queue and let free capacity decide, adaptively,
between latency (serve one now) and throughput (batch many).  Continuous
batching in LLM serving is that same decision made per engine tick, and the
paper's strategies transfer verbatim:

  admission per tick = strategy.decide(queue_length, producer_done)

  * PureAsync        → admit one request at a time (latency-optimal ttft
                       for the head of the queue, poor throughput)
  * OneOrAll         → admit everything waiting
  * LowerThreshold   → admit all only when the backlog exceeds bt (batch
                       setup — a prefill dispatch — costs ~3 decode ticks)
  * GrowingUpper     → cap admissions at a doubling threshold: small early
                       batches protect time-to-first-token, large late
                       batches protect throughput (Fig. 10's ramp)
  * AdaptiveCost     → learns prefill fixed-vs-per-item cost from observed
                       admit() durations and batches when it pays

Like the sharded :class:`~repro.core.runtime.AsyncQueryRuntime`, pending
requests are held in one lane per :attr:`Request.template`: each admission
batch is drawn from a single template's lane (homogeneous prompts bucket
tighter in the padded prefill), and mixed traffic classes stop head-of-line
blocking each other.  The strategy is consulted per lane; admission
round-robins over lanes while engine slots remain free.

With a :class:`~repro.core.lane_policy.LanePolicy` (``policy=``), each
template lane is asked its OWN strategy (hot templates learn a per-lane
AdaptiveCost model, cold ones stay pure-async), lanes are visited in
weighted-fair order instead of round-robin, and both prefill (admit) and
decode-tick durations feed back into that lane's cost model.  Admission
also passes the template to :meth:`InferenceEngine.admit`, which pins one
compiled prefill shape per template and — with ``kv_shares`` — bounds the
batch by that template's reserved + shared KV lanes
(:meth:`InferenceEngine.n_free_for`), so a burst on one template cannot
evict or starve the others' cache residency.

Admission consumes the same :class:`~repro.core.concurrency.ReadyLanes`
structure the lock-sharded runtime's workers drain: lanes with queued
requests sit in a duplicate-suppressed ready queue, each tick pops lanes
(weighted-fair under a policy, FIFO/round-robin otherwise) only while
engine slots remain free, and lanes with leftover backlog are re-queued —
a tick never scans lanes that have nothing to admit.

**Speculative prefill overlap** (``overlap=True``) — the paper's core
claim, applied to the tick loop itself: results should already be fetched
by the time they are consumed, so the *next* batch's prefill should be in
flight while the *current* decode tick runs, not after it.  Each tick
becomes a two-stage pipeline:

  commit(staged) → admit → speculate(dispatch next lane's prefill)
                                      ∥ decode tick t
  commit at tick t+1's boundary ──────┘

The scheduler peeks (without popping — :meth:`ReadyLanes.peek`) the next
ready lane, sizes a batch against the lanes that are free now *plus* the
lanes decode is about to retire (the speculation), and dispatches its
padded prefill on a separate thread through
:meth:`InferenceEngine.prefill_dispatch` while :meth:`decode_tick` runs.
At the next tick boundary the staged KV is committed into lanes
(:meth:`InferenceEngine.commit_prefill`).  If the bet missed — the lanes
it counted on were never freed, or freed into another template's
reservation — the uncommitted requests go back to the head of their queue
and the wasted prefill time feeds the lane's own cost model via
``observe_abort``, so chronically-missing lanes speculate less.

The scheduler records the per-tick admission trace (= Fig. 10 batch sizes,
also split per lane) and per-request ttft/latency (= Fig. 11
time-to-k-th-response).

Straggler mitigation: a lane whose request exceeds ``lane_timeout`` decode
ticks is force-retired and the request re-queued (re-submission, as in the
runtime's fetch-timeout path).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Optional

from repro.core.concurrency import ReadyLanes
from repro.core.lane_policy import LanePolicy
from repro.core.strategies import BatchingStrategy, PureAsync
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request

__all__ = ["ContinuousBatchingScheduler"]


@dataclasses.dataclass
class SchedulerStats:
    """Per-scheduler counters and traces (one instance per scheduler)."""

    admission_trace: list = dataclasses.field(default_factory=list)  # (tick, n)
    # per-template (tick, n) admission traces (runtime lane analogue)
    lane_admissions: dict = dataclasses.field(default_factory=dict)
    decode_ticks: int = 0
    completed: int = 0
    requeued: int = 0
    # speculative-prefill pipeline (overlap=True)
    spec_dispatched: int = 0  # requests whose prefill was dispatched early
    spec_committed: int = 0   # of those, committed into KV lanes
    spec_aborted: int = 0     # of those, re-queued (the bet missed)


class _SpecTask:
    """One in-flight speculative prefill.

    The dispatch runs on its own daemon thread so the host-side padding +
    device dispatch overlaps the main thread's decode tick; the main
    thread joins at the next tick boundary (commit).  One task is in
    flight at a time (the pipeline is two-stage), so a plain thread per
    dispatch costs nothing worth pooling."""

    __slots__ = ("template", "batch", "staged", "duration", "error", "_thread")

    def __init__(self, engine, template: str, batch: list):
        self.template = template
        self.batch = batch
        self.staged = None
        self.duration = 0.0
        self.error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._run, args=(engine,), daemon=True,
            name="cbs-spec-prefill",
        )
        self._thread.start()

    def _run(self, engine) -> None:
        t0 = time.perf_counter()
        try:
            self.staged = engine.prefill_dispatch(self.batch,
                                                  template=self.template)
        except BaseException as e:  # noqa: BLE001 — surfaced at commit
            self.error = e
        self.duration = time.perf_counter() - t0

    def join(self) -> None:
        """Block until the dispatch thread has finished (commit boundary)."""
        self._thread.join()


class ContinuousBatchingScheduler:
    """Per-template admission + one batched decode step per tick.

    Parameters
    ----------
    engine:
        The lane-holding engine.  Any object with the
        :class:`InferenceEngine` admission/decode surface works; the
        ``overlap=True`` pipeline additionally needs the split dispatch
        path (``prefill_dispatch`` / ``commit_prefill`` / ``n_free_for``).
    strategy / policy:
        One global :class:`BatchingStrategy`, or a per-lane
        :class:`LanePolicy` (mutually exclusive).
    lane_timeout:
        Decode ticks before a running request is force-retired and
        re-queued (straggler mitigation); ``None`` disables.
    overlap:
        Enable the speculative prefill/decode pipeline (module docstring).
    """

    def __init__(
        self,
        engine: InferenceEngine,
        strategy: Optional[BatchingStrategy] = None,
        lane_timeout: Optional[int] = None,
        policy: Optional[LanePolicy] = None,
        overlap: bool = False,
    ):
        if policy is not None and strategy is not None:
            raise ValueError(
                "pass either a global `strategy` or a per-lane `policy`, not both"
            )
        self.engine = engine
        self.policy = policy
        self.strategy = strategy or PureAsync()
        self.strategy.reset()
        self.overlap = overlap
        if overlap and not hasattr(engine, "prefill_dispatch"):
            raise ValueError(
                "overlap=True needs an engine with the split dispatch path "
                "(prefill_dispatch/commit_prefill/n_free_for)"
            )
        # Engines predating KV partitioning expose only the global n_free;
        # treat every template as drawing from one shared pool there.
        self._free_for = getattr(engine, "n_free_for",
                                 lambda tmpl: engine.n_free)
        # template -> pending requests; insertion-ordered for round-robin
        self.queues: "OrderedDict[str, deque[Request]]" = OrderedDict()
        self.running: dict[int, Request] = {}  # lane -> request
        self.stats = SchedulerStats()
        self.lane_timeout = lane_timeout
        self._lane_age: dict[int, int] = {}
        # Lanes with queued requests (same structure the runtime's workers
        # drain): FIFO pop + tail re-push is round-robin over busy lanes;
        # with a policy the pop is weighted-fair.  Single-threaded here, so
        # its lock is never contended.
        self._ready = ReadyLanes()
        self._warm_shapes: set = set()  # prefill buckets already compiled
        self._producer_done = False
        self._staged: Optional[_SpecTask] = None  # in-flight spec prefill

    # ------------------------------------------------------------------ api
    def submit(self, request: Request) -> None:
        """Queue one request on its template's lane."""
        q = self.queues.get(request.template)
        if q is None:
            q = self.queues[request.template] = deque()
        q.append(request)
        self._ready.push(request.template)
        if self.policy is not None:
            self.policy.note_submit(request.template)

    @property
    def n_queued(self) -> int:
        """Requests waiting in lanes (staged/running not counted)."""
        return sum(len(q) for q in self.queues.values())

    def producer_done(self) -> None:
        """Signal that no more requests are coming (lets PureBatch-style
        strategies drain the tail)."""
        self._producer_done = True

    def run_until_drained(self, max_ticks: int = 100_000) -> list[Request]:
        """Tick until every submitted request has finished (or raise after
        ``max_ticks`` with a diagnosis of what is stuck)."""
        done: list[Request] = []
        for _ in range(max_ticks):
            if (not self.n_queued and not self.running
                    and self._staged is None):
                if self._producer_done:
                    break
            done.extend(self.tick())
        else:
            if self.n_queued or self.running or self._staged is not None:
                stuck_queued = {t: len(q) for t, q in self.queues.items() if q}
                stuck_running = {
                    lane: r.template for lane, r in sorted(self.running.items())
                }
                staged = (f", staged spec prefill on "
                          f"{self._staged.template!r}" if self._staged else "")
                raise RuntimeError(
                    f"run_until_drained exhausted max_ticks={max_ticks} with "
                    f"work still pending: queued per template {stuck_queued}, "
                    f"running lanes {stuck_running}{staged} "
                    f"({self.stats.completed} completed, "
                    f"{self.stats.requeued} requeued, "
                    f"{self.stats.spec_aborted} spec-aborted). A lane that "
                    "never finishes usually means the engine stopped emitting "
                    "tokens for it, max_new_tokens exceeds the tick budget, "
                    "or kv_shares leaves its template no admissible lane."
                )
        return done

    # ------------------------------------------------- speculative pipeline
    def _strategy_for(self, tmpl: str) -> BatchingStrategy:
        return (self.policy.strategy_for(tmpl) if self.policy is not None
                else self.strategy)

    def _requeue_front(self, tmpl: str, batch: list) -> None:
        """Return an uncommitted speculative batch to the head of its lane
        (these requests were next in line; they must not lose their turn).
        The overlap markers are reset: metrics describe the attempt that
        finally lands, and this one did not."""
        q = self.queues.get(tmpl)
        if q is None:
            q = self.queues[tmpl] = deque()
        for r in reversed(batch):
            r.metrics.speculative = False
            q.appendleft(r)
        self._ready.push(tmpl)

    def _land_batch(self, tmpl: str, strat: BatchingStrategy, batch: list,
                    shape, duration: float) -> None:
        """Shared bookkeeping for a batch that just entered KV lanes —
        identical for synchronous admission and speculative commit, so the
        two paths cannot drift.

        Cost-model feedback is warm-shape guarded: the first dispatch of a
        padded bucket pays XLA compilation, an outlier that would blow up
        the learned fixed cost, so only steady-state durations are
        observed, sized by the bucket the device actually dispatched.
        ``duration`` is what the scheduler actually paid for the batch:
        the inline admit time on the synchronous path, dispatch + the
        commit-side materialization wait on the speculative one."""
        if shape in self._warm_shapes:
            strat.observe(shape[0], duration)
        else:
            self._warm_shapes.add(shape)
        if self.policy is not None:
            self.policy.charge(tmpl, len(batch))
        now = time.perf_counter()
        for r in batch:
            r.metrics.first_token = now  # prefill emits token 0
            self.running[r.lane] = r
            self._lane_age[r.lane] = 0
        self.stats.admission_trace.append((self.stats.decode_ticks, len(batch)))
        self.stats.lane_admissions.setdefault(tmpl, []).append(
            (self.stats.decode_ticks, len(batch)))

    def _commit_speculative(self) -> None:
        """Tick-boundary commit of the previous tick's speculative prefill.

        Joins the dispatch thread, commits as many staged requests as the
        template's pools can actually hold NOW, and aborts the rest: they
        return to the head of their queue and the wasted prefill time is
        charged to the lane's cost model (``observe_abort``)."""
        task = self._staged
        if task is None:
            return
        self._staged = None
        task.join()
        tmpl = task.template
        if task.error is not None:
            self._requeue_front(tmpl, task.batch)
            raise task.error
        strat = self._strategy_for(tmpl)
        fit = min(len(task.batch), self._free_for(tmpl))
        committed = task.batch[:fit]
        if committed:
            t0 = time.perf_counter()
            shape = self.engine.commit_prefill(task.staged, n=fit)
            commit_dt = time.perf_counter() - t0
            self._land_batch(tmpl, strat, committed, shape,
                             task.duration + commit_dt)
            self.stats.spec_committed += fit
        aborted = task.batch[fit:]
        if aborted:
            self._requeue_front(tmpl, aborted)
            self.stats.spec_aborted += len(aborted)
            if not committed:
                # The whole dispatch was wasted: charge the lane so it
                # demands a deeper backlog before speculating again.  A
                # partial commit still used the batch — no penalty.
                if self.policy is not None:
                    self.policy.observe_abort(tmpl, task.duration)
                else:
                    strat.observe_abort(task.duration)

    def _dispatch_speculative(self, select) -> None:
        """Pick the next speculable ready lane (peek — a lane we decline
        keeps its queue position) and dispatch its prefill on the spec
        thread, sized against free lanes plus the lanes this tick's decode
        is about to retire — the speculation ``_commit_speculative``
        settles at the next boundary.

        The scan consults each ready lane at most once, in the pick order
        admission would use (weighted-fair under a policy, FIFO
        otherwise), by filtering already-declined lanes out of the peek's
        candidate set — so one permanently-starved head lane cannot blind
        the speculator to dispatchable lanes behind it, in EITHER pick
        discipline, and declined lanes are never reordered."""
        ben = getattr(self.engine, "lane_benefits", None)
        consulted: set = set()

        def next_candidate(keys: list):
            cand = [k for k in keys if k not in consulted]
            if not cand:
                return None  # peek passes this through: scan exhausted
            return cand[0] if select is None else select(cand)

        while True:
            tmpl = self._ready.peek(select=next_candidate)
            if tmpl is None or tmpl in consulted:
                # None: nothing ready / every ready lane declined.  A
                # consulted key can still surface via peek's single-entry
                # short-circuit (select is bypassed at len 1): same exit.
                return
            consulted.add(tmpl)
            q = self.queues.get(tmpl)
            if not q:
                # Stale entry (lane drained since the push): discard it —
                # the targeted pop removes exactly this key.
                self._ready.pop(select=lambda keys, t=tmpl: t, block=False)
                continue
            # The speculative capacity: lanes free now, plus lanes whose
            # request reaches max_new_tokens on this very tick (decode is
            # about to retire them) — counting only retirements whose lane
            # goes home to a pool this template can draw from
            # (engine.lane_benefits): a lane bound for another template's
            # reservation is a guaranteed miss, not a bet.  The remaining
            # optimism (a straggler that refuses to finish, an engine that
            # stops emitting, an engine without the lane_benefits hint) is
            # what makes this a speculation, and the abort path is what
            # settles it.  Capacity is checked BEFORE the strategy is
            # consulted: decide() may be stateful (AdaptiveCost's explore
            # alternation), and a lane with no speculative capacity must
            # not consume a decision it cannot act on.
            cap = self._free_for(tmpl) + sum(
                1 for r in self.running.values()
                if r.remaining <= 1 and (ben is None or ben(r.lane, tmpl)))
            if cap > 0:
                strat = self._strategy_for(tmpl)
                take = min(strat.decide(len(q), self._producer_done),
                           len(q), cap)
                if take > 0:
                    break
            # Declined (strategy says wait / no capacity even
            # speculatively): leave the lane exactly where it is and look
            # at the next candidate.
        self._ready.pop(select=lambda keys, t=tmpl: t, block=False)
        batch = [q.popleft() for _ in range(take)]
        if not q:
            del self.queues[tmpl]
        else:
            self._ready.push(tmpl)
        now = time.perf_counter()
        for r in batch:
            r.metrics.admitted = now
            r.metrics.speculative = True
        self._staged = _SpecTask(self.engine, tmpl, batch)
        self.stats.spec_dispatched += take

    # ----------------------------------------------------------------- tick
    def tick(self) -> list[Request]:
        """One scheduling round: commit the staged speculative prefill,
        admit per strategy (per lane), dispatch the next speculation, run
        one decode step."""
        # 0) tick boundary: the previous tick's speculative prefill lands
        # (or aborts) before admission sees the free-lane picture.
        if self.overlap:
            self._commit_speculative()

        # 1) admission — the paper's "how many requests does a free worker
        # take from the queue" decision.  Ready lanes are popped (weighted-
        # fair under a LanePolicy, round-robin otherwise) only while engine
        # slots remain free; each lane is consulted at most once per tick
        # and re-queued if it keeps a backlog, so a tick never scans lanes
        # with nothing to admit.
        # Weighted-fair selection costs a policy lock + O(n) scan per pop;
        # with uniform weights FIFO pop + tail re-push is equally fair
        # round-robin (same guard as the runtime worker's pop).
        select = (self.policy.lane_min
                  if self.policy is not None and self.policy.lane_weights
                  else None)
        consulted: set = set()
        repush: list = []
        while self.engine.n_free > 0:
            tmpl = self._ready.pop(select=select, block=False)
            if tmpl is None:
                break
            if tmpl in consulted:
                repush.append(tmpl)
                break
            consulted.add(tmpl)
            q = self.queues.get(tmpl)
            if not q:
                continue  # stale push: lane drained since
            strat = self._strategy_for(tmpl)
            want = strat.decide(len(q), self._producer_done)
            # kv_shares: the batch is bounded by THIS template's admissible
            # lanes (reserved + shared), not the global free count.
            take = min(want, self._free_for(tmpl), len(q))
            if take <= 0:
                repush.append(tmpl)  # strategy says wait: stay scheduled
                continue
            batch = [q.popleft() for _ in range(take)]
            if not q:
                # GC drained lanes (mirrors the runtime): high-cardinality
                # template churn must not grow the bookkeeping.
                del self.queues[tmpl]
            else:
                repush.append(tmpl)
            now = time.perf_counter()
            for r in batch:
                r.metrics.admitted = now
            t0 = time.perf_counter()
            shape = self.engine.admit(batch, template=tmpl)
            # Feedback goes to the deciding model (the lane's own under a
            # policy); warm-shape guarding and the landing bookkeeping are
            # shared with the speculative commit path.
            self._land_batch(tmpl, strat, batch, shape,
                             time.perf_counter() - t0)
        for tmpl in repush:
            self._ready.push(tmpl)

        # 1.5) speculation: while decode runs below, the next ready lane's
        # prefill is already in flight on the spec thread.
        if self.overlap and self._staged is None:
            self._dispatch_speculative(select)

        # 2) one batched decode step over all active lanes
        finished: list[Request] = []
        t0 = time.perf_counter()
        tokens = self.engine.decode_tick()
        decode_dt = time.perf_counter() - t0
        self.stats.decode_ticks += 1
        if self.policy is not None and tokens:
            # Per-lane decode feedback: every template with a request in this
            # tick's batch gets the tick duration — the per-token side of its
            # cost model, next to the prefill F + n·c fit.
            for tmpl in {r.template for r in self.running.values()}:
                self.policy.observe_decode(tmpl, decode_dt)
        for lane, tok in tokens.items():
            r = self.running.get(lane)
            if r is None:
                continue
            r.generated.append(tok)
            self._lane_age[lane] += 1
            if r.done:
                r.metrics.finished = time.perf_counter()
                self.engine.retire(lane)
                del self.running[lane]
                finished.append(r)
                self.stats.completed += 1
            elif self.lane_timeout and self._lane_age[lane] > self.lane_timeout:
                # straggler: retire the lane, re-queue the request
                self.engine.retire(lane)
                del self.running[lane]
                r.generated.clear()
                r.lane = None
                rq = self.queues.get(r.template)
                if rq is None:  # lane may have been GC'd since admission
                    rq = self.queues[r.template] = deque()
                rq.appendleft(r)
                self._ready.push(r.template)
                self.stats.requeued += 1
        return finished
