"""Continuous batching scheduler — the paper's §5.2 as ML serving.

The decode loop has a *true-dependence cycle* (token t+1 needs token t), so
device-level Rule A cannot fission it — exactly the paper's inapplicable
case (§4.1).  The paper's answer is runtime **asynchronous batching**: keep
requests flowing through a queue and let free capacity decide, adaptively,
between latency (serve one now) and throughput (batch many).  Continuous
batching in LLM serving is that same decision made per engine tick, and the
paper's strategies transfer verbatim:

  admission per tick = strategy.decide(queue_length, producer_done)

  * PureAsync        → admit one request at a time (latency-optimal ttft
                       for the head of the queue, poor throughput)
  * OneOrAll         → admit everything waiting
  * LowerThreshold   → admit all only when the backlog exceeds bt (batch
                       setup — a prefill dispatch — costs ~3 decode ticks)
  * GrowingUpper     → cap admissions at a doubling threshold: small early
                       batches protect time-to-first-token, large late
                       batches protect throughput (Fig. 10's ramp)
  * AdaptiveCost     → learns prefill fixed-vs-per-item cost from observed
                       admit() durations and batches when it pays

Like the sharded :class:`~repro.core.runtime.AsyncQueryRuntime`, pending
requests are held in one lane per :attr:`Request.template`: each admission
batch is drawn from a single template's lane (homogeneous prompts bucket
tighter in the padded prefill), and mixed traffic classes stop head-of-line
blocking each other.  The strategy is consulted per lane; admission
round-robins over lanes while engine slots remain free.

With a :class:`~repro.core.lane_policy.LanePolicy` (``policy=``), each
template lane is asked its OWN strategy (hot templates learn a per-lane
AdaptiveCost model, cold ones stay pure-async), lanes are visited in
weighted-fair order instead of round-robin, and both prefill (admit) and
decode-tick durations feed back into that lane's cost model.  Admission
also passes the template to :meth:`InferenceEngine.admit`, which pins one
compiled prefill shape per template.

Admission consumes the same :class:`~repro.core.concurrency.ReadyLanes`
structure the lock-sharded runtime's workers drain: lanes with queued
requests sit in a duplicate-suppressed ready queue, each tick pops lanes
(weighted-fair under a policy, FIFO/round-robin otherwise) only while
engine slots remain free, and lanes with leftover backlog are re-queued —
a tick never scans lanes that have nothing to admit.

The scheduler records the per-tick admission trace (= Fig. 10 batch sizes,
also split per lane) and per-request ttft/latency (= Fig. 11
time-to-k-th-response).

Straggler mitigation: a lane whose request exceeds ``lane_timeout`` decode
ticks is force-retired and the request re-queued (re-submission, as in the
runtime's fetch-timeout path).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Optional

from repro.core.concurrency import ReadyLanes
from repro.core.lane_policy import LanePolicy
from repro.core.strategies import BatchingStrategy, PureAsync
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request

__all__ = ["ContinuousBatchingScheduler"]


@dataclasses.dataclass
class SchedulerStats:
    admission_trace: list = dataclasses.field(default_factory=list)  # (tick, n)
    # per-template (tick, n) admission traces (runtime lane analogue)
    lane_admissions: dict = dataclasses.field(default_factory=dict)
    decode_ticks: int = 0
    completed: int = 0
    requeued: int = 0


class ContinuousBatchingScheduler:
    def __init__(
        self,
        engine: InferenceEngine,
        strategy: Optional[BatchingStrategy] = None,
        lane_timeout: Optional[int] = None,
        policy: Optional[LanePolicy] = None,
    ):
        if policy is not None and strategy is not None:
            raise ValueError(
                "pass either a global `strategy` or a per-lane `policy`, not both"
            )
        self.engine = engine
        self.policy = policy
        self.strategy = strategy or PureAsync()
        self.strategy.reset()
        # template -> pending requests; insertion-ordered for round-robin
        self.queues: "OrderedDict[str, deque[Request]]" = OrderedDict()
        self.running: dict[int, Request] = {}  # lane -> request
        self.stats = SchedulerStats()
        self.lane_timeout = lane_timeout
        self._lane_age: dict[int, int] = {}
        # Lanes with queued requests (same structure the runtime's workers
        # drain): FIFO pop + tail re-push is round-robin over busy lanes;
        # with a policy the pop is weighted-fair.  Single-threaded here, so
        # its lock is never contended.
        self._ready = ReadyLanes()
        self._warm_shapes: set = set()  # prefill buckets already compiled
        self._producer_done = False

    # ------------------------------------------------------------------ api
    def submit(self, request: Request) -> None:
        q = self.queues.get(request.template)
        if q is None:
            q = self.queues[request.template] = deque()
        q.append(request)
        self._ready.push(request.template)
        if self.policy is not None:
            self.policy.note_submit(request.template)

    @property
    def n_queued(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def producer_done(self) -> None:
        self._producer_done = True

    def run_until_drained(self, max_ticks: int = 100_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_ticks):
            if not self.n_queued and not self.running:
                if self._producer_done:
                    break
            done.extend(self.tick())
        else:
            if self.n_queued or self.running:
                stuck_queued = {t: len(q) for t, q in self.queues.items() if q}
                stuck_running = {
                    lane: r.template for lane, r in sorted(self.running.items())
                }
                raise RuntimeError(
                    f"run_until_drained exhausted max_ticks={max_ticks} with "
                    f"work still pending: queued per template {stuck_queued}, "
                    f"running lanes {stuck_running} "
                    f"({self.stats.completed} completed, "
                    f"{self.stats.requeued} requeued). A lane that never "
                    "finishes usually means the engine stopped emitting "
                    "tokens for it or max_new_tokens exceeds the tick budget."
                )
        return done

    # ----------------------------------------------------------------- tick
    def tick(self) -> list[Request]:
        """One scheduling round: admit per strategy (per lane), one decode
        step."""
        # 1) admission — the paper's "how many requests does a free worker
        # take from the queue" decision.  Ready lanes are popped (weighted-
        # fair under a LanePolicy, round-robin otherwise) only while engine
        # slots remain free; each lane is consulted at most once per tick
        # and re-queued if it keeps a backlog, so a tick never scans lanes
        # with nothing to admit.
        # Weighted-fair selection costs a policy lock + O(n) scan per pop;
        # with uniform weights FIFO pop + tail re-push is equally fair
        # round-robin (same guard as the runtime worker's pop).
        select = (self.policy.lane_min
                  if self.policy is not None and self.policy.lane_weights
                  else None)
        consulted: set = set()
        repush: list = []
        while self.engine.n_free > 0:
            tmpl = self._ready.pop(select=select, block=False)
            if tmpl is None:
                break
            if tmpl in consulted:
                repush.append(tmpl)
                break
            consulted.add(tmpl)
            q = self.queues.get(tmpl)
            if not q:
                continue  # stale push: lane drained since
            strat = (self.policy.strategy_for(tmpl) if self.policy is not None
                     else self.strategy)
            want = strat.decide(len(q), self._producer_done)
            take = min(want, self.engine.n_free, len(q))
            if take <= 0:
                repush.append(tmpl)  # strategy says wait: stay scheduled
                continue
            if self.policy is not None:
                self.policy.charge(tmpl, take)
            batch = [q.popleft() for _ in range(take)]
            if not q:
                # GC drained lanes (mirrors the runtime): high-cardinality
                # template churn must not grow the bookkeeping.
                del self.queues[tmpl]
            else:
                repush.append(tmpl)
            now = time.perf_counter()
            for r in batch:
                r.metrics.admitted = now
            t0 = time.perf_counter()
            shape = self.engine.admit(batch, template=tmpl)
            dt = time.perf_counter() - t0
            # Adaptive feedback: the first admit of a bucket shape pays XLA
            # compilation — an outlier that would blow up a learned fixed
            # cost, so only steady-state admits are observed, sized by the
            # padded bucket the device actually dispatched.  Feedback goes
            # to the deciding model (the lane's own under a policy).
            if shape in self._warm_shapes:
                strat.observe(shape[0], dt)
            else:
                self._warm_shapes.add(shape)
            now = time.perf_counter()
            for r in batch:
                r.metrics.first_token = now  # prefill emits token 0
                self.running[r.lane] = r
                self._lane_age[r.lane] = 0
            self.stats.admission_trace.append((self.stats.decode_ticks, take))
            self.stats.lane_admissions.setdefault(tmpl, []).append(
                (self.stats.decode_ticks, take)
            )
        for tmpl in repush:
            self._ready.push(tmpl)

        # 2) one batched decode step over all active lanes
        finished: list[Request] = []
        t0 = time.perf_counter()
        tokens = self.engine.decode_tick()
        decode_dt = time.perf_counter() - t0
        self.stats.decode_ticks += 1
        if self.policy is not None and tokens:
            # Per-lane decode feedback: every template with a request in this
            # tick's batch gets the tick duration — the per-token side of its
            # cost model, next to the prefill F + n·c fit.
            for tmpl in {r.template for r in self.running.values()}:
                self.policy.observe_decode(tmpl, decode_dt)
        for lane, tok in tokens.items():
            r = self.running.get(lane)
            if r is None:
                continue
            r.generated.append(tok)
            self._lane_age[lane] += 1
            if r.done:
                r.metrics.finished = time.perf_counter()
                self.engine.retire(lane)
                del self.running[lane]
                finished.append(r)
                self.stats.completed += 1
            elif self.lane_timeout and self._lane_age[lane] > self.lane_timeout:
                # straggler: retire the lane, re-queue the request
                self.engine.retire(lane)
                del self.running[lane]
                r.generated.clear()
                r.lane = None
                rq = self.queues.get(r.template)
                if rq is None:  # lane may have been GC'd since admission
                    rq = self.queues[r.template] = deque()
                rq.appendleft(r)
                self._ready.push(r.template)
                self.stats.requeued += 1
        return finished
