"""Continuous batching scheduler — the paper's §5.2 as ML serving.

The decode loop has a *true-dependence cycle* (token t+1 needs token t), so
device-level Rule A cannot fission it — exactly the paper's inapplicable
case (§4.1).  The paper's answer is runtime **asynchronous batching**: keep
requests flowing through a queue and let free capacity decide, adaptively,
between latency (serve one now) and throughput (batch many).  Continuous
batching in LLM serving is that same decision made per engine tick, and the
paper's three strategies transfer verbatim:

  admission per tick = strategy.decide(queue_length, producer_done)

  * PureAsync        → admit one request at a time (latency-optimal ttft
                       for the head of the queue, poor throughput)
  * OneOrAll         → admit everything waiting
  * LowerThreshold   → admit all only when the backlog exceeds bt (batch
                       setup — a prefill dispatch — costs ~3 decode ticks)
  * GrowingUpper     → cap admissions at a doubling threshold: small early
                       batches protect time-to-first-token, large late
                       batches protect throughput (Fig. 10's ramp)

Admissions are also capped by free lanes (the thread pool size).  The
scheduler records the per-tick admission trace (= Fig. 10 batch sizes) and
per-request ttft/latency (= Fig. 11 time-to-k-th-response).

Straggler mitigation: a lane whose request exceeds ``lane_timeout`` decode
ticks is force-retired and the request re-queued (re-submission, as in the
runtime's fetch-timeout path).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.core.strategies import BatchingStrategy, PureAsync
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request

__all__ = ["ContinuousBatchingScheduler"]


@dataclasses.dataclass
class SchedulerStats:
    admission_trace: list = dataclasses.field(default_factory=list)  # (tick, n)
    decode_ticks: int = 0
    completed: int = 0
    requeued: int = 0


class ContinuousBatchingScheduler:
    def __init__(
        self,
        engine: InferenceEngine,
        strategy: Optional[BatchingStrategy] = None,
        lane_timeout: Optional[int] = None,
    ):
        self.engine = engine
        self.strategy = strategy or PureAsync()
        self.strategy.reset()
        self.queue: deque[Request] = deque()
        self.running: dict[int, Request] = {}  # lane -> request
        self.stats = SchedulerStats()
        self.lane_timeout = lane_timeout
        self._lane_age: dict[int, int] = {}
        self._producer_done = False

    # ------------------------------------------------------------------ api
    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def producer_done(self) -> None:
        self._producer_done = True

    def run_until_drained(self, max_ticks: int = 100_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_ticks):
            if not self.queue and not self.running:
                if self._producer_done:
                    break
            done.extend(self.tick())
        return done

    # ----------------------------------------------------------------- tick
    def tick(self) -> list[Request]:
        """One scheduling round: admit per strategy, one decode step."""
        # 1) admission — the paper's "how many requests does a free worker
        # take from the queue" decision.
        n_free = self.engine.n_free
        if n_free > 0 and self.queue:
            want = self.strategy.decide(len(self.queue), self._producer_done)
            take = min(want, n_free, len(self.queue))
            if take > 0:
                batch = [self.queue.popleft() for _ in range(take)]
                now = time.perf_counter()
                for r in batch:
                    r.metrics.admitted = now
                self.engine.admit(batch)
                now = time.perf_counter()
                for r in batch:
                    r.metrics.first_token = now  # prefill emits token 0
                    self.running[r.lane] = r
                    self._lane_age[r.lane] = 0
                self.stats.admission_trace.append((self.stats.decode_ticks, take))

        # 2) one batched decode step over all active lanes
        finished: list[Request] = []
        tokens = self.engine.decode_tick()
        self.stats.decode_ticks += 1
        for lane, tok in tokens.items():
            r = self.running.get(lane)
            if r is None:
                continue
            r.generated.append(tok)
            self._lane_age[lane] += 1
            if r.done:
                r.metrics.finished = time.perf_counter()
                self.engine.retire(lane)
                del self.running[lane]
                finished.append(r)
                self.stats.completed += 1
            elif self.lane_timeout and self._lane_age[lane] > self.lane_timeout:
                # straggler: retire the lane, re-queue the request
                self.engine.retire(lane)
                del self.running[lane]
                r.generated.clear()
                r.lane = None
                self.queue.appendleft(r)
                self.stats.requeued += 1
        return finished
