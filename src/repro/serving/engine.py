"""Inference engine: lane-based KV cache + jitted prefill/decode steps.

The engine owns ``n_lanes`` decode slots (the thread-pool "connections" of
the paper's Fig. 3, device edition).  Admission inserts a prefilled
request's KV into a free lane; every engine tick runs ONE batched decode
step over all lanes (inactive lanes are masked).  The admission policy —
how many queued requests to prefill together — is the scheduler's call
(:mod:`repro.serving.scheduler`), where the paper's §5.2 strategies live.

Prefill batches are padded to power-of-two buckets (bounded jit cache).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Arch

__all__ = ["InferenceEngine"]


def _bucket(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@dataclasses.dataclass
class InferenceEngine:
    arch: Arch
    params: object
    n_lanes: int = 8
    max_prompt_len: int = 64
    max_len: int = 128

    def __post_init__(self):
        self.cache = self.arch.init_cache(self.n_lanes, self.max_len)
        self.lengths = jnp.zeros((self.n_lanes,), jnp.int32)
        self.active = np.zeros((self.n_lanes,), bool)
        self.last_token = jnp.zeros((self.n_lanes,), jnp.int32)
        self.free_lanes = list(range(self.n_lanes))
        self.decode_steps = 0
        self.prefill_calls = 0
        # template -> pinned (batch, prompt) prefill bucket: each template
        # converges on ONE compiled prefill shape (monotone max of what it
        # has needed), so a template burst stops recompiling per batch size.
        self.template_shapes: dict[str, tuple[int, int]] = {}

        @partial(jax.jit, static_argnums=())
        def _decode(params, token, cache, lengths):
            logits, new_cache = self.arch.decode_step(params, token, cache, lengths)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_cache

        self._decode = _decode

        from repro.models import transformer as _tf

        @partial(jax.jit, static_argnums=(3,))
        def _prefill(params, tokens, plens, max_len):
            logits, cache = _tf.prefill(
                self.arch.cfg, params, tokens=tokens, max_len=max_len,
                return_all_logits=True,
            )
            last = jnp.take_along_axis(
                logits, (plens - 1)[:, None, None], axis=1
            )[:, 0]
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
            return nxt, cache

        self._prefill = _prefill

    # ------------------------------------------------------------- admission
    def admit(self, requests: Sequence, template: Optional[str] = None
              ) -> tuple[int, int]:
        """Prefill ``requests`` as ONE padded batch and insert into lanes.

        One prefill call for k requests is the set-oriented execution: one
        device dispatch amortized over the batch (vs k single dispatches) —
        the serving analogue of the paper's batched query.

        ``template`` keys the padding bucket to the lane: the batch/prompt
        bucket is pinned per template (monotone max), so every admission of
        a template after its first dispatches the SAME compiled shape.
        """
        if not requests:
            return (0, 0)
        assert len(requests) <= len(self.free_lanes), "admit() beyond free lanes"
        bsz = _bucket(len(requests))
        # Bucket the prompt axis to the batch's longest (truncated) prompt:
        # lane-homogeneous admission (scheduler groups by template) means
        # short-prompt classes prefill at e.g. 8 wide instead of always
        # max_prompt_len — right-padding + causal mask keeps logits exact.
        prompts = [r.prompt[-self.max_prompt_len:] for r in requests]
        plen = min(self.max_prompt_len, _bucket(max(len(p) for p in prompts)))
        if template is not None:
            pinned = self.template_shapes.get(template, (1, 1))
            bsz = max(bsz, pinned[0])
            plen = max(plen, pinned[1])
            self.template_shapes[template] = (bsz, plen)
        toks = np.zeros((bsz, plen), np.int32)
        plens = np.ones((bsz,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p  # right-pad; causal mask hides pad keys
            plens[i] = len(p)
        first, cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(plens), self.max_len
        )
        first = np.asarray(first)

        lanes = [self.free_lanes.pop(0) for _ in requests]
        self.cache = _insert_lanes(self.cache, cache, lanes)
        lt = np.array(self.last_token)
        ln = np.array(self.lengths)
        for i, (r, lane) in enumerate(zip(requests, lanes)):
            r.lane = lane
            r.generated.append(int(first[i]))
            lt[lane] = first[i]
            ln[lane] = plens[i]  # real prompt length; decode writes here next
            self.active[lane] = True
        self.last_token = jnp.asarray(lt)
        self.lengths = jnp.asarray(ln)
        self.prefill_calls += 1
        return bsz, plen  # padded bucket actually dispatched (cost feedback)

    # ----------------------------------------------------------------- tick
    def decode_tick(self) -> dict[int, int]:
        """One batched decode step over all lanes → {lane: token}."""
        if not self.active.any():
            return {}
        nxt, self.cache = self._decode(
            self.params, self.last_token, self.cache, self.lengths
        )
        self.lengths = jnp.where(
            jnp.asarray(self.active), jnp.minimum(self.lengths + 1, self.max_len - 1),
            self.lengths,
        )
        self.last_token = nxt
        self.decode_steps += 1
        out = np.asarray(nxt)
        return {lane: int(out[lane]) for lane in np.nonzero(self.active)[0]}

    def retire(self, lane: int) -> None:
        self.active[lane] = False
        self.free_lanes.append(lane)

    @property
    def n_free(self) -> int:
        return len(self.free_lanes)


def _insert_lanes(lane_cache, new_cache, lanes: list[int]):
    """Copy per-request cache entries (batch axis=1 after the layer axis)
    into lane slots.  Works on the nested {stack: {k,v,ssm,conv}} pytree."""
    idx = jnp.asarray(lanes)

    def one(dst, src):
        # dst: (L, B_lanes, ...); src: (L, B_new_bucket, ...)
        take = src[:, : len(lanes)]
        return dst.at[:, idx].set(take.astype(dst.dtype))

    return jax.tree_util.tree_map(one, lane_cache, new_cache)
