"""Inference engine: lane-based KV cache + jitted prefill/decode steps.

The engine owns ``n_lanes`` decode slots (the thread-pool "connections" of
the paper's Fig. 3, device edition).  Admission inserts a prefilled
request's KV into a free lane; every engine tick runs ONE batched decode
step over all lanes (inactive lanes are masked).  The admission policy —
how many queued requests to prefill together — is the scheduler's call
(:mod:`repro.serving.scheduler`), where the paper's §5.2 strategies live.

Two production mechanisms live at this layer:

* **Per-template KV partitioning** (``kv_shares={template: n_lanes}``).
  Lanes are a shared cache: without reservations a burst on one template
  can occupy every free lane and starve the others' cache residency (the
  serving analogue of one tenant evicting everyone's buffer pool).  A
  :class:`KVPartition` reserves a fixed lane count per named template;
  reserved lanes are only ever allocated to (and released back to) their
  owning template, the remainder form a shared pool any template may use.
  :func:`proportional_shares` derives a share map from
  :class:`~repro.core.lane_policy.LanePolicy` ``lane_weights``.
* **Split prefill dispatch** (:meth:`InferenceEngine.prefill_dispatch` /
  :meth:`InferenceEngine.commit_prefill`).  ``admit`` = dispatch + commit
  in one call; the split form lets the scheduler *dispatch* the next
  batch's padded prefill while the current decode tick runs (JAX dispatch
  is asynchronous — the jitted call returns before the device finishes)
  and *commit* the staged KV into lanes at the next tick boundary.
  Dispatch mutates no engine or request state, so an uncommitted
  :class:`StagedPrefill` can simply be dropped (speculation abort).

Two further mechanisms extend the split dispatch path:

* **Chunked prefill** (``prefill_dispatch(..., chunk=n)``).  One huge
  prompt dispatched as a single prefill stalls the next commit boundary
  for its full duration.  With ``chunk``, the prompt is processed as
  resumable chunks: the first ``chunk`` tokens go through the ordinary
  prefill, every later chunk extends the staged KV through the decode
  path (:meth:`InferenceEngine.prefill_resume`, one scan of
  ``decode_step`` per chunk) — so the scheduler can interleave decode
  ticks between chunks instead of stalling on one monolithic prefill.
  The staged result is bit-for-bit the computation the one-shot path
  performs (same causal attention, incrementally), just split in time.
* **Host KV spill** (``KVPartition(spill=HostSpillPool(...))``).
  Evicting a running request (straggler force-retire) normally drops its
  KV, so re-admission pays a full re-prefill AND restarts generation.
  With a spill pool the evicted lane's KV rows are staged to host memory
  (:meth:`InferenceEngine.spill`); re-admission of the same request
  restores the rows into a fresh lane (:meth:`InferenceEngine.try_restore`)
  and decode continues where it stopped.

Prefill batches are padded to power-of-two buckets (bounded jit cache).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from functools import partial
from typing import Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Arch

__all__ = ["HostSpillPool", "InferenceEngine", "KVPartition", "StagedPrefill",
           "proportional_shares"]

_SHARED = "__shared__"  # KVPartition pool key for unreserved lanes


def _bucket(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def proportional_shares(weights: Mapping[str, float], n_lanes: int,
                        reserve: float = 0.5) -> dict[str, int]:
    """Derive ``kv_shares`` from :class:`LanePolicy` ``lane_weights``.

    Distributes ``floor(n_lanes * reserve)`` reserved lanes across the
    weighted templates proportionally to their weights (largest-remainder
    rounding, name breaking ties), leaving the rest as the shared pool —
    so the templates the operator already marked as mattering
    (``lane_weights``) get KV residency guarantees in the same proportion
    as their service shares.  Zero-lane templates are dropped from the map
    (they use the shared pool like any unreserved template).
    """
    if not 0.0 <= reserve <= 1.0:
        raise ValueError("reserve must be in [0, 1]")
    budget = int(n_lanes * reserve)
    if not weights or budget <= 0:
        return {}
    for t, w in weights.items():
        if w <= 0:
            raise ValueError(f"weights[{t!r}] must be > 0, got {w}")
    total = float(sum(weights.values()))
    quotas = {t: budget * w / total for t, w in weights.items()}
    shares = {t: int(q) for t, q in quotas.items()}
    remaining = budget - sum(shares.values())
    for t in sorted(quotas, key=lambda t: (-(quotas[t] - shares[t]), t)):
        if remaining <= 0:
            break
        shares[t] += 1
        remaining -= 1
    return {t: s for t, s in shares.items() if s > 0}


class HostSpillPool:
    """Host-side LRU staging area for evicted decode-lane KV.

    Keys are request identities (the scheduler uses ``Request.rid``); each
    entry holds one lane's KV rows plus the decode cursor (length + last
    token), copied to host memory at eviction time.  ``max_entries``
    bounds the pool globally; ``budget_for`` (e.g.
    :meth:`~repro.core.lane_policy.LanePolicy.spill_budget_for`) bounds
    entries *per template*, so one template's straggler churn cannot evict
    everyone else's staged KV — the host-memory analogue of the lane
    reservations above.  Over-budget inserts evict the least-recently-used
    entry (of that template for the per-template bound, globally for
    ``max_entries``); a re-admitted request whose entry survived restores
    instead of re-prefilling.

    Thread-safe (a lock per op): the scheduler spills/restores from its
    tick loop, but introspection (stats, ``in``) may come from anywhere.

    ``on_drop`` is invoked (under the pool lock) for every entry the pool
    discards without a restore — stale duplicates, per-template budget
    evictions and global LRU evictions — with ``(key, template, entry)``.
    Entries may own resources beyond host bytes: a partial eviction's
    entry holds refcounts on the shared prefix pages it left resident in
    the device pool, and dropping the entry must release them or the
    pages leak.  ``take`` never triggers it (the restoring caller owns
    the entry's resources from then on).
    """

    def __init__(self, max_entries: int = 32,
                 budget_for: Optional[Callable[[Optional[str]],
                                               Optional[int]]] = None,
                 on_drop: Optional[Callable[[object, Optional[str], dict],
                                            None]] = None):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.budget_for = budget_for
        self.on_drop = on_drop
        self._lock = threading.Lock()
        self._lru: "OrderedDict[object, tuple[Optional[str], dict]]" = OrderedDict()
        self.spilled = 0    # entries accepted
        self.restored = 0   # entries taken back by a re-admission
        self.dropped = 0    # entries evicted (LRU / budget) before restore

    def _drop(self, key, template: Optional[str], entry: dict) -> None:
        """Account one discarded entry and release its resources."""
        self.dropped += 1
        if self.on_drop is not None:
            self.on_drop(key, template, entry)

    def accepts(self, template: Optional[str]) -> bool:
        """Whether a new entry for ``template`` would be stored at all —
        ``False`` only for a zero-budget (fenced) template.  Callers
        check this BEFORE paying the device→host KV copy; a positive
        budget always admits the new entry (evicting older ones)."""
        budget = self.budget_for(template) if self.budget_for else None
        return budget is None or budget > 0

    def put(self, key, template: Optional[str], entry: dict) -> bool:
        """Stage one evicted lane's KV under ``key`` (replacing any stale
        entry for the same key), evicting LRU entries that break the
        global or per-template budget.  Returns whether the entry was
        stored (``False`` for a zero-budget fenced template)."""
        with self._lock:
            if key in self._lru:
                stale_t, stale_e = self._lru.pop(key)
                self._drop(key, stale_t, stale_e)  # the new KV wins
            budget = self.budget_for(template) if self.budget_for else None
            if budget is not None and budget <= 0:
                self._drop(key, template, entry)  # template fenced out
                return False
            if budget is not None:
                mine = [k for k, (t, _) in self._lru.items() if t == template]
                while len(mine) >= budget:
                    victim = mine.pop(0)  # oldest of THIS template
                    v_t, v_e = self._lru.pop(victim)
                    self._drop(victim, v_t, v_e)
            while len(self._lru) >= self.max_entries:
                v_key, (v_t, v_e) = self._lru.popitem(last=False)
                self._drop(v_key, v_t, v_e)
            self._lru[key] = (template, entry)
            self.spilled += 1
            return True

    def take(self, key) -> Optional[dict]:
        """Remove and return ``key``'s staged entry (``None`` on miss)."""
        with self._lock:
            hit = self._lru.pop(key, None)
            if hit is None:
                return None
            self.restored += 1
            return hit[1]

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._lru

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def snapshot(self) -> dict:
        """Counters + occupancy (introspection/benchmark reporting)."""
        with self._lock:
            return {"entries": len(self._lru), "spilled": self.spilled,
                    "restored": self.restored, "dropped": self.dropped}


class KVPartition:
    """Per-template lane reservations over a fixed set of decode lanes.

    ``shares[template] = k`` pins ``k`` specific lanes to ``template``:
    they are allocated only to that template and return to its pool on
    release, so no burst elsewhere can take them.  Unreserved lanes form
    the shared pool; a reserved template drains its own pool first and
    then competes for shared lanes like everyone else, while a template
    with no reservation sees only the shared pool.

    Single-threaded by design (the scheduler tick loop): allocation and
    release happen on the scheduler thread only — the speculative prefill
    thread never touches the partition (dispatch is stateless; see
    :meth:`InferenceEngine.prefill_dispatch`).
    """

    def __init__(self, n_lanes: int, shares: Optional[Mapping[str, int]] = None,
                 spill: Optional[HostSpillPool] = None):
        self.spill = spill  # host-side LRU for evicted lanes' KV (optional)
        shares = dict(shares or {})
        for t, k in shares.items():
            if t == _SHARED:
                raise ValueError(f"{_SHARED!r} is a reserved pool name")
            if k < 0:
                raise ValueError(f"kv_shares[{t!r}] must be >= 0, got {k}")
        if sum(shares.values()) > n_lanes:
            raise ValueError(
                f"kv_shares reserve {sum(shares.values())} lanes but the "
                f"engine only has {n_lanes}")
        self.shares = {t: k for t, k in shares.items() if k > 0}
        lanes = list(range(n_lanes))
        self._home: dict[int, str] = {}
        self._free: dict[str, list[int]] = {}
        for t, k in self.shares.items():
            pool = [lanes.pop(0) for _ in range(k)]
            for lane in pool:
                self._home[lane] = t
            self._free[t] = pool
        self._free[_SHARED] = lanes
        self._quarantined: set[int] = set()

    def quarantine(self, lane: int) -> None:
        """Remove ``lane`` from circulation: it will not be allocated again
        until :meth:`unquarantine` returns it to its home pool.  Used by
        crash recovery — a lane whose device step faulted sits out a
        cooldown instead of immediately hosting the next request.  The
        lane must currently be free (retire/release it first)."""
        for pool in self._free.values():
            if lane in pool:
                pool.remove(lane)
                self._quarantined.add(lane)
                return
        if lane in self._quarantined:
            return
        raise ValueError(f"lane {lane} is not free; cannot quarantine")

    def unquarantine(self, lane: int) -> None:
        """Return a quarantined lane to its home pool (no-op otherwise)."""
        if lane in self._quarantined:
            self._quarantined.discard(lane)
            self.release(lane)

    @property
    def quarantined(self) -> frozenset:
        """Snapshot of lanes currently held out of circulation."""
        return frozenset(self._quarantined)

    @property
    def n_free(self) -> int:
        """Total free lanes across every pool."""
        return sum(len(p) for p in self._free.values())

    def n_free_for(self, template: Optional[str]) -> int:
        """Free lanes ``template`` may allocate right now: its own reserved
        pool (if any) plus the shared pool.  ``None`` (untemplated
        admission) sees only the shared pool."""
        n = len(self._free[_SHARED])
        if template is not None:
            n += len(self._free.get(template, ()))
        return n

    def alloc(self, template: Optional[str]) -> int:
        """Take one lane for ``template`` — its reserved pool first (keeps
        the shared pool liquid for everyone else), then shared.  Raises
        ``IndexError`` when neither pool has a free lane."""
        pool = self._free.get(template) if template is not None else None
        if not pool:
            pool = self._free[_SHARED]
        return pool.pop(0)

    def release(self, lane: int) -> None:
        """Return a lane to its home pool (owning template's reservation,
        or shared for unreserved lanes)."""
        self._free[self._home.get(lane, _SHARED)].append(lane)

    def benefits(self, lane: int, template: Optional[str]) -> bool:
        """Whether releasing ``lane`` would raise ``n_free_for(template)``:
        true for shared lanes and for ``template``'s own reserved lanes.
        The scheduler's speculative sizing uses this to bet only on
        retirements that can actually serve the speculated template —
        a lane going home to ANOTHER template's reservation is a
        guaranteed miss, not a speculation."""
        home = self._home.get(lane, _SHARED)
        return home == _SHARED or home == template

    @property
    def free_lanes(self) -> list[int]:
        """Sorted snapshot of every free lane (introspection/debugging)."""
        return sorted(lane for p in self._free.values() for lane in p)


@dataclasses.dataclass
class StagedPrefill:
    """A dispatched-but-uncommitted prefill batch.

    Produced by :meth:`InferenceEngine.prefill_dispatch`; holds the padded
    batch's device results (``first`` tokens + KV ``cache`` — possibly
    still being computed: JAX dispatch is asynchronous) and the request
    list, but no engine state.  :meth:`InferenceEngine.commit_prefill`
    materializes it into lanes; dropping it instead is a zero-cost abort
    (beyond the device work already paid, which the scheduler reports via
    ``observe_abort``).
    """

    template: Optional[str]
    requests: list
    first: object   # (bsz,) int32 device array — argmax token 0 per row
    cache: object   # KV pytree, batch axis sized to the padded bucket
    plens: np.ndarray
    shape: tuple[int, int]  # the padded (batch, prompt) bucket dispatched
    # Chunked dispatch state (``prefill_dispatch(..., chunk=)``): token
    # chunks not yet folded into the staged cache, and the device-side
    # lengths cursor the next :meth:`InferenceEngine.prefill_resume` call
    # extends from.  ``first`` stays ``None`` until the final chunk.
    pending: list = dataclasses.field(default_factory=list)
    lengths_dev: object = None
    # Batched-chunk dispatch (``prefill_dispatch([r0, r1, ...], chunk=)``):
    # one single-request staged prefill per prompt.  The parent is a pure
    # aggregate — ``cache``/``first`` stay ``None``; resume advances one
    # part-chunk per call, commit delegates to the parts in order.
    parts: list = dataclasses.field(default_factory=list)

    @property
    def complete(self) -> bool:
        """Whether every chunk has been processed (always true for the
        one-shot dispatch path) — only a complete staged prefill may be
        committed."""
        if self.parts:
            return all(p.complete for p in self.parts)
        return not self.pending


@dataclasses.dataclass
class InferenceEngine:
    """Lane-based KV cache + jitted prefill/decode (see module docstring).

    ``kv_shares`` reserves decode lanes per template
    (:class:`KVPartition`); the default ``None`` keeps every lane in the
    shared pool (pre-partitioning behaviour).  ``kv_spill`` attaches a
    :class:`HostSpillPool` so evicted lanes stage their KV to host memory
    (:meth:`spill` / :meth:`try_restore`) instead of dropping it.
    """

    arch: Arch
    params: object
    n_lanes: int = 8
    max_prompt_len: int = 64
    max_len: int = 128
    kv_shares: Optional[Mapping[str, int]] = None
    kv_spill: Optional[HostSpillPool] = None

    def __post_init__(self):
        self.cache = self.arch.init_cache(self.n_lanes, self.max_len)
        self.lengths = jnp.zeros((self.n_lanes,), jnp.int32)
        self.active = np.zeros((self.n_lanes,), bool)
        self.last_token = jnp.zeros((self.n_lanes,), jnp.int32)
        self.partition = KVPartition(self.n_lanes, self.kv_shares,
                                     spill=self.kv_spill)
        self.decode_steps = 0
        self.prefill_calls = 0
        # KV bytes copied across the device boundary (spill + restore).
        # The dense engine moves whole lanes (max_len rows regardless of
        # how many are valid); the paged engine moves only valid pages —
        # this counter is what the Part 8 A/B compares.
        self.kv_bytes_moved = 0
        # Jitted model-step device programs launched (decode ticks, prefill
        # batches, chunk extends, fused ticks).  The fused-dispatch gate
        # asserts a paged decode tick that also folds a staged prefill
        # chunk raises this by exactly 1 — one device program, not two.
        # Lock-guarded: the speculative prefill thread dispatches too.
        self.dispatches = 0
        self._dispatch_lock = threading.Lock()
        # template -> pinned (batch, prompt) prefill bucket: each template
        # converges on ONE compiled prefill shape (monotone max of what it
        # has needed), so a template burst stops recompiling per batch size.
        self.template_shapes: dict[str, tuple[int, int]] = {}

        @partial(jax.jit, static_argnums=())
        def _decode(params, token, cache, lengths):
            logits, new_cache = self.arch.decode_step(params, token, cache, lengths)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_cache

        self._decode = _decode

        from repro.models import transformer as _tf

        @partial(jax.jit, static_argnums=(3,))
        def _prefill(params, tokens, plens, max_len):
            logits, cache = _tf.prefill(
                self.arch.cfg, params, tokens=tokens, max_len=max_len,
                return_all_logits=True,
            )
            last = jnp.take_along_axis(
                logits, (plens - 1)[:, None, None], axis=1
            )[:, 0]
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
            return nxt, cache

        self._prefill = _prefill

        @partial(jax.jit, static_argnums=())
        def _extend(params, cache, toks, lengths):
            # toks: (B, C) — C further prompt tokens per row, fed through
            # the decode path one position at a time (a lax.scan, ONE
            # compiled dispatch per chunk shape).  Exactly the computation
            # prefill performs for those positions, split in time.
            def step(carry, tok):
                c, ln = carry
                logits, c = self.arch.decode_step(params, tok, c, ln)
                return (c, ln + 1), logits

            (cache, lengths), logits = jax.lax.scan(
                step, (cache, lengths), jnp.swapaxes(toks, 0, 1))
            return logits[-1], cache, lengths

        self._extend = _extend

    def _count_dispatch(self, n: int = 1) -> None:
        """Record ``n`` jitted model-step dispatches (thread-safe)."""
        with self._dispatch_lock:
            self.dispatches += n

    # ------------------------------------------------------------- admission
    def admit(self, requests: Sequence, template: Optional[str] = None
              ) -> tuple[int, int]:
        """Prefill ``requests`` as ONE padded batch and insert into lanes.

        One prefill call for k requests is the set-oriented execution: one
        device dispatch amortized over the batch (vs k single dispatches) —
        the serving analogue of the paper's batched query.

        ``template`` keys the padding bucket to the lane: the batch/prompt
        bucket is pinned per template (monotone max), so every admission of
        a template after its first dispatches the SAME compiled shape.
        With ``kv_shares``, ``template`` also selects which lane pools the
        batch may draw from (:meth:`n_free_for` bounds the batch size).

        Equivalent to :meth:`prefill_dispatch` immediately followed by
        :meth:`commit_prefill` — the synchronous path, paying the prefill
        inline; the scheduler's overlap mode uses the split form instead.
        """
        if not requests:
            return (0, 0)
        assert len(requests) <= self.n_free_for(template), \
            "admit() beyond this template's free lanes"
        return self.commit_prefill(self.prefill_dispatch(requests, template))

    def prefill_dispatch(self, requests: Sequence,
                         template: Optional[str] = None,
                         chunk: Optional[int] = None) -> StagedPrefill:
        """Dispatch (but do not commit) one padded prefill batch.

        Builds the padded token batch and issues the jitted prefill — an
        *asynchronous* device dispatch: the call returns as soon as the
        computation is enqueued, so the caller can overlap it with a decode
        tick and commit at the next tick boundary.  No engine or request
        state is mutated (the only write is the per-template shape pin,
        a GIL-atomic dict store), so this is safe to call from the
        scheduler's speculative-dispatch thread while :meth:`decode_tick`
        runs on the main thread, and an uncommitted result can be dropped.

        ``chunk`` enables resumable chunked prefill for ONE oversized
        prompt (the scheduler dispatches such prompts alone): the first
        ``chunk`` tokens prefill now, the rest stay ``pending`` on the
        returned staged object for :meth:`prefill_resume` to fold in one
        chunk at a time — each resume is one compiled dispatch the caller
        can overlap with a decode tick.  Prompts that fit in one chunk
        fall through to the ordinary path.  Chunk shapes compile per
        distinct (final-remainder) width; steady traffic converges on two
        compiled shapes (``chunk`` and its remainder bucket).
        """
        if chunk is not None and chunk >= 1:
            cprompts = [np.asarray(r.prompt[-(self.max_len - 1):], np.int32)
                        for r in requests]
            if len(requests) == 1:
                if len(cprompts[0]) > chunk:
                    return self._chunked_dispatch(
                        requests[0], cprompts[0], template, chunk)
                # A prompt that fits one chunk: ordinary one-shot below.
            elif any(len(p) > chunk for p in cprompts):
                # A BATCH of oversized prompts: one single-request chunked
                # part per prompt under an aggregate parent, so resumable
                # chunking no longer forces oversized prompts to dispatch
                # alone — the scheduler admits them as one unit and
                # interleaves decode ticks between every part's chunks.
                parts = [self._chunked_dispatch(r, p, template, chunk)
                         for r, p in zip(requests, cprompts)]
                return StagedPrefill(
                    template, list(requests), None, None,
                    np.concatenate([pt.plens for pt in parts]),
                    (len(requests), int(max(len(p) for p in cprompts))),
                    parts=parts)
        bsz = _bucket(len(requests))
        # Bucket the prompt axis to the batch's longest (truncated) prompt:
        # lane-homogeneous admission (scheduler groups by template) means
        # short-prompt classes prefill at e.g. 8 wide instead of always
        # max_prompt_len — right-padding + causal mask keeps logits exact.
        prompts = [r.prompt[-self.max_prompt_len:] for r in requests]
        plen = min(self.max_prompt_len, _bucket(max(len(p) for p in prompts)))
        if template is not None:
            pinned = self.template_shapes.get(template, (1, 1))
            bsz = max(bsz, pinned[0])
            plen = max(plen, pinned[1])
            self.template_shapes[template] = (bsz, plen)
        toks = np.zeros((bsz, plen), np.int32)
        plens = np.ones((bsz,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p  # right-pad; causal mask hides pad keys
            plens[i] = len(p)
        first, cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(plens), self.max_len
        )
        self._count_dispatch()
        return StagedPrefill(template, list(requests), first, cache,
                             plens, (bsz, plen))

    def _chunked_dispatch(self, r, prompt: np.ndarray,
                          template: Optional[str], chunk: int) -> StagedPrefill:
        """Chunked-path dispatch: prefill the first chunk, stage the rest.

        The staged cache is batch-1 and already padded to ``max_len``;
        later chunks extend it in place through the decode path (positions
        ``chunk..S-1``), so the committed KV matches what a one-shot
        prefill of the full prompt would have produced.  A prompt that
        fits one chunk degenerates to a batch-1 one-shot (complete
        immediately) so batched-chunk parents may mix sizes.  The
        per-template shape pin is NOT consulted: chunk shapes are their
        own (bounded) compile family, and a huge prompt must not widen
        the template's pinned batch bucket."""
        S = len(prompt)
        c0 = min(chunk, S)
        first, cache = self._prefill(
            self.params, jnp.asarray(prompt[None, :c0]),
            jnp.asarray([c0], jnp.int32), self.max_len)
        self._count_dispatch()
        pending = [prompt[None, i: i + chunk] for i in range(c0, S, chunk)]
        return StagedPrefill(
            template, [r], None if pending else first, cache,
            np.asarray([S], np.int32), (1, S),
            pending=pending, lengths_dev=jnp.asarray([c0], jnp.int32))

    def prefill_resume(self, staged: StagedPrefill) -> bool:
        """Fold the next pending chunk into a chunked staged prefill.

        One compiled dispatch (a ``lax.scan`` of ``decode_step`` over the
        chunk's positions) extends the staged KV and advances the length
        cursor; the final chunk also yields the first generated token,
        making the staged prefill :attr:`~StagedPrefill.complete` and
        commit-eligible.  Returns completeness.  Like ``prefill_dispatch``
        this mutates only the staged object, never engine or request
        state — safe on the scheduler's speculation thread.

        A batched-chunk parent advances ONE chunk of its first incomplete
        part per call — the one-dispatch-per-resume contract the
        scheduler's tick interleaving relies on is preserved."""
        if staged.complete:
            return True
        if staged.parts:
            for part in staged.parts:
                if not part.complete:
                    self.prefill_resume(part)
                    break
            return staged.complete
        toks = staged.pending.pop(0)
        logits, staged.cache, staged.lengths_dev = self._extend(
            self.params, staged.cache, jnp.asarray(toks), staged.lengths_dev)
        self._count_dispatch()
        if not staged.pending:
            staged.first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return staged.complete

    def commit_prefill(self, staged: StagedPrefill,
                       n: Optional[int] = None) -> tuple[int, int]:
        """Materialize a staged prefill into decode lanes.

        Commits the first ``n`` requests of ``staged`` (default: all),
        blocking until the device results are ready, allocating each a
        lane from its template's pools and splicing its KV rows into the
        lane cache.  The caller bounds ``n`` by :meth:`n_free_for` —
        requests beyond ``n`` are the caller's to re-queue (speculation
        abort: the rows were computed but never inserted).  Returns the
        padded ``(batch, prompt)`` bucket actually dispatched (cost-model
        feedback, same as :meth:`admit`).
        """
        assert staged.complete, \
            "commit_prefill() of a chunked staged prefill with pending chunks"
        if staged.parts:
            take = len(staged.requests) if n is None else n
            for part in staged.parts:
                k = min(len(part.requests), take)
                if k <= 0:
                    break
                self.commit_prefill(part, k)
                take -= k
            return staged.shape
        reqs = staged.requests if n is None else staged.requests[:n]
        assert len(reqs) <= self.n_free_for(staged.template), \
            "commit_prefill() beyond this template's free lanes"
        if not reqs:
            return staged.shape
        first = np.asarray(staged.first)  # materializes the async dispatch
        lanes = [self.partition.alloc(staged.template) for _ in reqs]
        self._insert_staged(staged, lanes)
        lt = np.array(self.last_token)
        ln = np.array(self.lengths)
        for i, (r, lane) in enumerate(zip(reqs, lanes)):
            r.lane = lane
            r.generated.append(int(first[i]))
            lt[lane] = first[i]
            ln[lane] = staged.plens[i]  # real prompt length; decode writes here
            self.active[lane] = True
        self.last_token = jnp.asarray(lt)
        self.lengths = jnp.asarray(ln)
        self.prefill_calls += 1
        return staged.shape

    def _insert_staged(self, staged: StagedPrefill, lanes: list[int]) -> None:
        """Splice the staged batch's cache into ``lanes`` — the KV-motion
        hook the paged engine overrides.  The dense engine always moves
        full lanes (all ``max_len`` rows, valid or not) and accounts them
        against :attr:`kv_bytes_moved`."""
        self.cache = _insert_lanes(self.cache, staged.cache, lanes)
        for a in jax.tree_util.tree_leaves(staged.cache):
            self.kv_bytes_moved += (a.dtype.itemsize * a.shape[0] * len(lanes)
                                    * int(np.prod(a.shape[2:])))

    # ----------------------------------------------------------------- tick
    def decode_tick(self) -> dict[int, int]:
        """One batched decode step over all lanes → ``{lane: token}``."""
        if not self.active.any():
            return {}
        nxt, self.cache = self._decode(
            self.params, self.last_token, self.cache, self.lengths
        )
        self._count_dispatch()
        self.lengths = jnp.where(
            jnp.asarray(self.active), jnp.minimum(self.lengths + 1, self.max_len - 1),
            self.lengths,
        )
        self.last_token = nxt
        self.decode_steps += 1
        out = np.asarray(nxt)
        return {lane: int(out[lane]) for lane in np.nonzero(self.active)[0]}

    def retire(self, lane: int) -> None:
        """Free a lane (request finished or force-retired); the lane
        returns to its home pool — a reserved lane back to its template's
        reservation, a shared lane back to the shared pool."""
        self.active[lane] = False
        self.partition.release(lane)

    # ---------------------------------------------------------------- spill
    def spill(self, lane: int, key, template: Optional[str] = None) -> bool:
        """Retire ``lane``, staging its KV to the host spill pool.

        Copies the lane's cache rows plus the decode cursor (length, last
        token) to host memory under ``key`` (the request identity) before
        releasing the lane, so a later re-admission of the same request
        can :meth:`try_restore` instead of re-prefilling.  Returns whether
        the KV was actually staged — ``False`` (plain retire) when no
        pool is configured or the template is fenced out of it
        (zero spill budget, checked BEFORE paying the device→host copy);
        an LRU/budget eviction later is the pool's business."""
        pool = self.partition.spill
        if pool is None or not pool.accepts(template):
            self.retire(lane)
            return False
        entry = {
            "rows": jax.tree_util.tree_map(
                lambda a: np.asarray(a[:, lane]), self.cache),
            "length": int(np.asarray(self.lengths)[lane]),
            "last": int(np.asarray(self.last_token)[lane]),
        }
        self.kv_bytes_moved += sum(
            a.nbytes for a in jax.tree_util.tree_leaves(entry["rows"]))
        staged = pool.put(key, template, entry)
        self.retire(lane)
        return staged

    def has_spill(self, key) -> bool:
        """Whether ``key`` currently has staged KV in the spill pool (the
        scheduler's cue to restore at admission instead of re-prefilling
        — and to keep the request out of speculative prefill batches)."""
        pool = self.partition.spill
        return pool is not None and key in pool

    def try_restore(self, key, template: Optional[str] = None) -> Optional[int]:
        """Restore ``key``'s spilled KV into a fresh lane, if possible.

        On a pool hit with a free lane admissible for ``template``, the
        staged rows are spliced back, the decode cursor resumes where the
        eviction stopped, and the lane index is returned — generation
        continues with no re-prefill and no token restart.  Returns
        ``None`` on a pool miss (entry evicted or never spilled) or when
        the template has no admissible free lane (the entry stays staged
        for a later attempt)."""
        pool = self.partition.spill
        if pool is None or key not in pool or self.n_free_for(template) <= 0:
            return None
        entry = pool.take(key)
        if entry is None:  # raced away (defensive: tick loop is 1-threaded)
            return None
        lane = self.partition.alloc(template)
        rows = entry["rows"]
        self.kv_bytes_moved += sum(
            np.asarray(a).nbytes for a in jax.tree_util.tree_leaves(rows))
        self.cache = jax.tree_util.tree_map(
            lambda dst, src: dst.at[:, lane].set(
                jnp.asarray(src).astype(dst.dtype)),
            self.cache, rows)
        ln = np.array(self.lengths)
        lt = np.array(self.last_token)
        ln[lane] = entry["length"]
        lt[lane] = entry["last"]
        self.lengths = jnp.asarray(ln)
        self.last_token = jnp.asarray(lt)
        self.active[lane] = True
        return lane

    @property
    def kv(self):
        """The engine's :class:`~repro.serving.kv.KVView` — the one
        capacity/placement surface the scheduler consumes.  Dense engines
        expose their :class:`KVPartition`; the paged engine overrides
        this with a page-budget-bounded view."""
        return self.partition

    @property
    def n_free(self) -> int:
        """Total free lanes across every pool."""
        return self.partition.n_free

    def n_free_for(self, template: Optional[str]) -> int:
        """Free lanes admissible for ``template`` right now (its reserved
        pool plus the shared pool; see :class:`KVPartition`)."""
        return self.partition.n_free_for(template)

    def lane_benefits(self, lane: int, template: Optional[str]) -> bool:
        """Whether retiring ``lane`` would free capacity ``template`` can
        use (:meth:`KVPartition.benefits`) — the scheduler's speculative
        sizing hint."""
        return self.partition.benefits(lane, template)

    @property
    def free_lanes(self) -> list[int]:
        """Sorted snapshot of every free lane (introspection/debugging)."""
        return self.partition.free_lanes


def _insert_lanes(lane_cache, new_cache, lanes: list[int]):
    """Copy per-request cache entries (batch axis=1 after the layer axis)
    into lane slots.  Works on the nested {stack: {k,v,ssm,conv}} pytree."""
    idx = jnp.asarray(lanes)

    def one(dst, src):
        # dst: (L, B_lanes, ...); src: (L, B_new_bucket, ...)
        take = src[:, : len(lanes)]
        return dst.at[:, idx].set(take.astype(dst.dtype))

    return jax.tree_util.tree_map(one, lane_cache, new_cache)
