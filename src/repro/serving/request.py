"""Serving request objects + per-request latency accounting."""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestMetrics"]


@dataclasses.dataclass
class RequestMetrics:
    """Wall-clock milestones for one request's trip through the scheduler.

    ``admitted`` is when the request left its queue for a prefill batch —
    for a speculatively-prefilled request that is the *dispatch* time (the
    prefill started while the previous decode tick was still running), and
    ``speculative`` records that the request took the overlap path.  A
    speculative request whose bet missed is re-queued and may be admitted
    again; the timestamps always describe the attempt that finally landed.
    """

    arrival: float = 0.0
    admitted: float = 0.0
    first_token: float = 0.0
    finished: float = 0.0
    speculative: bool = False  # prefill overlapped a decode tick

    @property
    def ttft(self) -> float:
        """Time to first token (paper: time to k-th response)."""
        return self.first_token - self.arrival

    @property
    def latency(self) -> float:
        """Total arrival → finished wall time."""
        return self.finished - self.arrival


@dataclasses.dataclass
class Request:
    """One generation request (prompt in, ``max_new_tokens`` tokens out)."""

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    metrics: RequestMetrics = dataclasses.field(default_factory=RequestMetrics)
    lane: Optional[int] = None
    # Query template (runtime lane key): requests sharing a template are
    # admitted/prefilled together, so heterogeneous traffic (chat vs embed vs
    # summarize) batches per class instead of head-of-line blocking.
    template: str = "default"
    # Per-request sampling params, carried per LANE through the decode
    # megabatch (one dispatch covers all templates).  temperature 0 is
    # greedy argmax — the bit-identity default; > 0 samples under a
    # counter-based key derived from (sample_seed, position), so draws
    # reproduce across spill/restore and batch composition changes.
    temperature: float = 0.0
    sample_seed: int = 0

    def __post_init__(self):
        if self.metrics.arrival == 0.0:
            self.metrics.arrival = time.perf_counter()

    @property
    def done(self) -> bool:
        """Whether the token budget is spent."""
        return len(self.generated) >= self.max_new_tokens

    @property
    def remaining(self) -> int:
        """Tokens still owed (0 once :attr:`done`)."""
        return max(0, self.max_new_tokens - len(self.generated))
