"""Serving request objects + per-request latency accounting."""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestMetrics"]


@dataclasses.dataclass
class RequestMetrics:
    arrival: float = 0.0
    admitted: float = 0.0
    first_token: float = 0.0
    finished: float = 0.0

    @property
    def ttft(self) -> float:  # time to first token (paper: time to k-th response)
        return self.first_token - self.arrival

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    metrics: RequestMetrics = dataclasses.field(default_factory=RequestMetrics)
    lane: Optional[int] = None
    # Query template (runtime lane key): requests sharing a template are
    # admitted/prefilled together, so heterogeneous traffic (chat vs embed vs
    # summarize) batches per class instead of head-of-line blocking.
    template: str = "default"

    def __post_init__(self):
        if self.metrics.arrival == 0.0:
            self.metrics.arrival = time.perf_counter()

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens
