"""KVView: the one capacity/placement interface the scheduler consumes.

The scheduler used to reach into the engine through a hand-delegated
quartet (``n_free``/``n_free_for``/``free_lanes``/``lane_benefits``), each
mirrored on :class:`~repro.serving.engine.InferenceEngine` as a
pass-through to its :class:`~repro.serving.engine.KVPartition`.  That
duplication is what made swapping the KV backend invasive: a paged pool
would have to re-mirror four methods on the engine.

:class:`KVView` names the contract once.  Both backends implement it —
the dense lane partition (:class:`~repro.serving.engine.KVPartition`)
and the paged pool's capacity view
(:class:`~repro.serving.paged_kv.PagedKVView`) — and engines expose it as
``engine.kv``.  The scheduler binds ``engine.kv`` when present and falls
back to the engine itself, so duck-typed bench/test engines keep working
unchanged.

The contract (all in *allocation units* — lanes today; a paged backend
reports lane-equivalents bounded by its instantaneous page budget, and
under oversubscription ``n_free_for`` additionally subtracts the pages
still owed to other templates' quotas — a reservation is a floor on
*pages*, not just lanes, so a shared-pool burst can never starve a
reserved template's page budget):

* ``n_free`` — total free units.
* ``n_free_for(template)`` — units ``template`` may allocate right now
  (its reservation plus the shared pool).
* ``alloc(template)`` / ``release(unit)`` — take/return one unit.
* ``benefits(unit, template)`` — would releasing ``unit`` raise
  ``n_free_for(template)``?  (Speculative-sizing hint.)
* ``free_lanes`` — sorted snapshot of free units (introspection).
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

__all__ = ["KVView"]


@runtime_checkable
class KVView(Protocol):
    """Structural protocol for KV capacity/placement backends."""

    @property
    def n_free(self) -> int:
        """Total free allocation units across every pool."""
        ...

    def n_free_for(self, template: Optional[str]) -> int:
        """Units ``template`` may allocate right now."""
        ...

    def alloc(self, template: Optional[str]) -> int:
        """Take one unit for ``template`` (reserved pool first)."""
        ...

    def release(self, unit: int) -> None:
        """Return a unit to its home pool."""
        ...

    def benefits(self, unit: int, template: Optional[str]) -> bool:
        """Whether releasing ``unit`` raises ``n_free_for(template)``."""
        ...

    @property
    def free_lanes(self) -> list[int]:
        """Sorted snapshot of every free unit (introspection)."""
        ...
